//! Overload-control regression suite: bounded admission, the graceful
//! degradation ladder (shed precision → shed prefetch → reject), and the
//! bounded connection pool — the server must degrade *accuracy* under
//! pressure before it degrades *availability*.
//!
//! Everything here runs artifact-free on a synthesized model
//! (`model::synth`) through the pure-Rust reference executor, like
//! `chunked_prefill.rs`: the loader, cache, residency facade, scheduler,
//! TCP front-end, and the open-loop workload harness are all the real
//! ones, so this suite gates CI without the AOT compile step.
//!
//! Coverage:
//! * admission control: a full bounded queue answers *every* client's
//!   channel with the typed rejection — no request is silently dropped
//!   and no connection hangs;
//! * bounded worker pool: over-capacity connects get a one-line rejection
//!   from the acceptor instead of an unbounded thread spawn, and the
//!   configurable `--client-timeout-ms` reaps idle readers;
//! * ladder ordering: at moderate overload the precision stage engages
//!   (progressive low-first loads observed, shed rounds counted) while
//!   prefetch shed and admission rejection stay at zero;
//! * availability: a sustained ~2x open-loop overload sheds load through
//!   typed rejections, keeps the queue at its bound, completes every
//!   admitted request, and never wedges;
//! * light load is undegraded: with the ladder armed but the queue far
//!   from its thresholds, outputs are bit-identical to a no-ladder run
//!   and every shed/reject counter stays zero;
//! * the scheduler's stall query is O(1) in the live-set size
//!   (`stall_scan_ops` counts exactly one op per call at any population).

use std::io::{BufRead, BufReader, Read};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use hobbit::config::{HardwareConfig, ModelConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::{Engine, EngineOptions};
use hobbit::model::synth::{tiny_model_config, write_synth_model};
use hobbit::server::{client_request, Server};
use hobbit::workload::{self, DriveOptions, WorkloadConfig};

const SEED: u64 = 0x0E71_0AD;

fn big_cfg(name: &str) -> ModelConfig {
    let mut cfg = tiny_model_config(name);
    cfg.max_seq = 512;
    cfg
}

fn synth_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hobbit_overload_{name}"));
    let cfg = big_cfg(name);
    write_synth_model(&dir, &cfg, SEED).expect("synth model");
    dir
}

fn fast_hw() -> HardwareConfig {
    HardwareConfig {
        name: "overload-fast".into(),
        load_bw: 1e9,
        load_latency: 0.0,
        hi_cache_experts: 12,
        lo_cache_experts: 12,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Offload-bound: small cache + a link slow enough (~3ms per f32 expert)
/// that service time dwarfs arrival spacing — the overload regime.
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "overload-slow".into(),
        load_bw: 2e6,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Deterministic outputs: dynamic loading off + hi-pinned fetches, so the
/// ladder A/B runs can be compared token-for-token.
fn quality_policy() -> PolicyConfig {
    PolicyConfig {
        dynamic_loading: false,
        prefetch_depth: 2,
        pin_precision: Some(hobbit::Precision::F32),
        ..PolicyConfig::default()
    }
}

/// Progressive low-bits-first streaming on: the precision stage of the
/// ladder has a lower tier to shed *to*.
fn progressive_policy() -> PolicyConfig {
    PolicyConfig { progressive: true, prefetch_depth: 2, ..PolicyConfig::default() }
}

fn mk_engine(name: &str, dir: &Path, hw: HardwareConfig, policy: PolicyConfig) -> Engine {
    Engine::new_reference(dir, big_cfg(name), EngineOptions::new(hw, policy))
        .expect("reference engine")
}

// ---------------------------------------------------------------------
// Admission control answers every channel
// ---------------------------------------------------------------------

/// Six clients race GENs at a server whose admission queue holds one
/// request (one more decoding). Every client must get a JSON answer —
/// some the generation, at least one the typed "admission queue full"
/// rejection — and the server must drain cleanly afterwards.
#[test]
fn admission_rejection_answers_every_channel() {
    const CLIENTS: usize = 6;
    let name = "admit";
    let dir = synth_dir(name);
    let eng = mk_engine(name, &dir, offload_hw(), quality_policy());
    let mut coord = Coordinator::interleaved(eng);
    coord.max_active = 1;
    coord.overload.queue_limit = Some(1);

    let mut server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let clients: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let addr = addr.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                // short prompt + budget: the whole (possibly queued)
                // generation must finish well inside the client
                // transport's per-attempt read deadline, or the client
                // would retry on a fresh connection and break the
                // max_conns accounting
                client_request(&addr, &format!("GEN 4 0 storm{i}"))
                    .expect("every channel gets a JSON line")
            })
        })
        .collect();

    server.serve_concurrent(&mut coord, Some(CLIENTS)).unwrap();
    let responses: Vec<_> = clients.into_iter().map(|c| c.join().unwrap()).collect();

    let mut ok = 0usize;
    let mut rejected = 0usize;
    for r in &responses {
        match r.get("error") {
            None => {
                // a success line always carries the tokens field (the
                // count itself may be 0 if greedy decode hits EOS first)
                assert!(r.get("tokens").unwrap().as_f64().unwrap() >= 0.0);
                ok += 1;
            }
            Some(e) => {
                let msg = e.as_str().unwrap();
                assert!(
                    msg.contains("admission queue full"),
                    "unexpected error kind: {msg}"
                );
                rejected += 1;
            }
        }
    }
    assert_eq!(ok + rejected, CLIENTS, "every channel answered exactly once");
    assert!(ok >= 1, "an empty queue must admit");
    assert!(
        rejected >= 1,
        "six simultaneous requests against a 1-deep queue must shed"
    );
    assert_eq!(coord.scheduler_stats().admission_rejects, rejected as u64);
    assert!(coord.take_failures().is_empty());
}

// ---------------------------------------------------------------------
// Bounded connection pool + configurable client timeout
// ---------------------------------------------------------------------

/// With one reader-thread slot taken by a silent connection, the next
/// connect is answered by the acceptor with the capacity rejection (no
/// thread spawned, no hang), and the idle reader itself is reaped by the
/// configured `--client-timeout-ms` instead of the legacy hard 30 s.
#[test]
fn conn_pool_rejects_over_capacity_and_reaps_idle_readers() {
    let name = "pool";
    let dir = synth_dir(name);
    let eng = mk_engine(name, &dir, fast_hw(), quality_policy());
    let mut coord = Coordinator::interleaved(eng);

    let mut server = Server::bind("127.0.0.1:0").unwrap();
    server.set_max_conn_threads(1);
    server.set_client_timeout(Duration::from_millis(500));
    let addr = server.local_addr().unwrap();

    let client = std::thread::spawn(move || {
        // A: occupies the single reader slot, sends nothing
        let a = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(150));
        // B: over capacity — the acceptor must answer and close. B never
        // writes, so the rejection line can't be lost to an RST race.
        let b = TcpStream::connect(addr).unwrap();
        b.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut line = String::new();
        BufReader::new(b.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(
            line.contains("connection capacity"),
            "over-capacity connect must get the pool rejection: {line:?}"
        );
        // A: the 500ms read timeout must reap the idle reader — observed
        // as A's socket closing (EOF or reset) well before the old 30 s
        a.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let t0 = Instant::now();
        let mut buf = [0u8; 64];
        let reaped = matches!(a.try_clone().unwrap().read(&mut buf), Ok(0) | Err(_));
        assert!(reaped, "idle connection was not closed");
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "idle reader outlived the configured client timeout"
        );
    });

    // two accepted connections: A (reader) + B (rejected by the acceptor)
    server.serve_concurrent(&mut coord, Some(2)).unwrap();
    client.join().unwrap();
}

// ---------------------------------------------------------------------
// Ladder ordering: precision sheds first, requests are not refused
// ---------------------------------------------------------------------

/// Moderate overload (queue well past the precision threshold, short of
/// the prefetch one): the coordinator must publish queue pressure so
/// hi-pool misses stream low-bits-first, while prefetch shedding and
/// admission rejection never fire — and every request still completes.
#[test]
fn precision_ladder_engages_before_shedding_requests() {
    let name = "ladder";
    let dir = synth_dir(name);
    let eng = mk_engine(name, &dir, offload_hw(), progressive_policy());
    let mut coord = Coordinator::interleaved(eng);
    coord.max_active = 2;
    coord.overload.queue_limit = Some(8);
    coord.overload.precision_frac = 0.25;
    coord.overload.prefetch_frac = 0.95;
    coord.overload.validate().unwrap();

    const REQS: usize = 8;
    for i in 0..REQS {
        let req = Request::new(i as u64 + 1, workload::prompt_text(24, i as u64), 4);
        coord.try_submit(req).expect("under the queue limit: no rejection");
    }
    let results = coord.drain().expect("drain");
    assert_eq!(results.len(), REQS, "every queued request completes");
    assert!(coord.take_failures().is_empty());

    let sch = coord.scheduler_stats();
    assert!(
        sch.shed_precision_rounds > 0,
        "a 6/8-deep queue (>= 25% fill) must engage the precision stage"
    );
    assert_eq!(
        sch.shed_prefetch_rounds, 0,
        "fill stayed below the prefetch threshold: stage 2 must not fire"
    );
    assert_eq!(
        sch.admission_rejects, 0,
        "the ladder must absorb moderate overload without refusing anyone"
    );
    let loads = coord.engine.residency.loader_stats();
    assert!(
        loads.progressive_loads > 0,
        "precision shed must materialize as low-bits-first streamed misses"
    );
}

// ---------------------------------------------------------------------
// Availability under sustained open-loop overload
// ---------------------------------------------------------------------

/// An open-loop trace offering far more than the engine can serve, against
/// a 2-deep admission queue: the server sheds through typed rejections,
/// the queue never exceeds its bound, every admitted request completes,
/// and the replay drains instead of wedging.
#[test]
fn availability_under_sustained_overload() {
    let name = "avail";
    let dir = synth_dir(name);
    let eng = mk_engine(name, &dir, offload_hw(), progressive_policy());
    let mut coord = Coordinator::interleaved(eng);
    coord.max_active = 2;
    coord.overload.queue_limit = Some(2);

    let cfg = WorkloadConfig {
        mean_rps: 60.0,
        burstiness: 0.3,
        diurnal_period_s: 2.0,
        duration_s: 1.0,
        prompt_mean: 6.0,
        prompt_sigma: 0.4,
        prompt_max: 16,
        output_mean: 3.0,
        output_sigma: 0.3,
        output_max: 8,
        seed: 0xde5_10ad,
    };
    cfg.validate().unwrap();
    let trace = workload::generate_trace(&cfg);
    assert!(trace.len() >= 30, "the trace must actually offer overload");

    let opts = DriveOptions { max_wall: Duration::from_secs(120), ..Default::default() };
    let rep = workload::drive(&mut coord, &trace, &opts).expect("drive");

    assert!(!rep.hit_wall, "overload must not wedge the scheduler");
    assert_eq!(rep.submitted + rep.rejected, trace.len(), "every arrival accounted");
    assert!(rep.rejected >= 1, "sustained overload against a 2-deep queue must shed");
    assert_eq!(rep.failed, 0, "admitted requests must not fail under load");
    assert_eq!(rep.results.len(), rep.submitted, "every admitted request completes");
    assert!(rep.max_queue_depth <= 2, "the admission bound held");
    assert_eq!(coord.scheduler_stats().admission_rejects, rep.rejected as u64);
}

// ---------------------------------------------------------------------
// Light load: the armed ladder is bit-inert
// ---------------------------------------------------------------------

/// With the ladder armed but the queue far below every threshold, tokens
/// must be bit-identical to a ladder-off run and all overload counters
/// zero — degradation is something overload *causes*, not a standing tax.
#[test]
fn light_load_is_bit_identical_to_no_ladder() {
    let name = "light";
    let dir = synth_dir(name);
    let prompts: Vec<String> = (0..3).map(|i| workload::prompt_text(20, i)).collect();

    let run = |ladder: bool| {
        let eng = mk_engine(name, &dir, offload_hw(), quality_policy());
        let mut coord = Coordinator::interleaved(eng);
        coord.overload.queue_limit = Some(64);
        coord.overload.ladder = ladder;
        for (i, p) in prompts.iter().enumerate() {
            coord.try_submit(Request::new(i as u64 + 1, p.clone(), 5)).unwrap();
        }
        let mut results = coord.drain().expect("drain");
        assert!(coord.take_failures().is_empty());
        results.sort_by_key(|r| r.id);
        let sch = coord.scheduler_stats();
        assert_eq!(sch.admission_rejects, 0);
        assert_eq!(sch.shed_precision_rounds, 0, "light load must not shed (ladder={ladder})");
        assert_eq!(sch.shed_prefetch_rounds, 0);
        results.into_iter().map(|r| (r.id, r.tokens)).collect::<Vec<_>>()
    };

    let with_ladder = run(true);
    let without = run(false);
    assert_eq!(with_ladder, without, "armed ladder changed light-load outputs");
}

// ---------------------------------------------------------------------
// O(1) stall query at any live population
// ---------------------------------------------------------------------

/// `all_stalled` must cost exactly one scan op per call whether 2 or 12
/// sequences are live — the incrementally-maintained counts, observable
/// through `stall_scan_ops`.
#[test]
fn stall_query_cost_is_flat_in_live_set_size() {
    let cost_at = |n: usize| {
        let name = format!("scan{n}");
        let dir = synth_dir(&name);
        let eng = mk_engine(&name, &dir, offload_hw(), quality_policy());
        let mut coord = Coordinator::interleaved(eng);
        coord.max_active = 16;
        for i in 0..n {
            coord.submit(Request::new(i as u64 + 1, workload::prompt_text(40, i as u64), 3));
        }
        // a few non-blocking rounds: admission + first prefill slices
        for _ in 0..4 {
            let _ = coord.step_nonblocking().expect("step");
        }
        assert_eq!(coord.pending(), 0, "all {n} sequences admitted");
        let before = coord.stall_scan_ops();
        for _ in 0..1000 {
            let _ = coord.all_stalled();
        }
        let ops = coord.stall_scan_ops() - before;
        let _ = coord.abort_all();
        ops
    };
    let small = cost_at(2);
    let large = cost_at(12);
    assert_eq!(small, 1000, "2 live sequences: one op per query");
    assert_eq!(large, 1000, "12 live sequences: one op per query, not O(n)");
}
