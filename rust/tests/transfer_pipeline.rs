//! Transfer-pipeline regression suite: the chunked, multi-lane,
//! bandwidth-arbitrated expert loader (preemptible prefetches, shared
//! fair-share link, no-slot re-acquire, and the TTFT-deadline scheduler
//! policy that rides on it).
//!
//! Everything here is artifact-free: loader-level tests synthesize a tiny
//! expert store on disk (like `residency.rs`), the coordinator-level test
//! drives the pure-Rust reference engine over a `model::synth` weight
//! directory (like `chunked_prefill.rs`). Timing assertions use modeled
//! link sleeps in the hundreds of milliseconds with generous slack, so
//! they hold in debug and release CI alike.
//!
//! Coverage (the pipeline's contract):
//! * chunked transfers are byte-identical to monolithic ones;
//! * an on-demand task issued mid-prefetch becomes ready within ~one
//!   chunk + its own transfer instead of waiting out the prefetch;
//! * concurrent lanes split — never multiply — the link bandwidth;
//! * a preempted transfer's slot stays `Loading` (never committed
//!   partial) and resumes to a byte-identical commit;
//! * `promote_to_ondemand` re-prioritizes *started* prefetches;
//! * a no-slot completion is counted and the residency facade re-acquires
//!   instead of waking waiters onto a non-resident expert;
//! * `--policy deadline` serves bit-identically to the FCFS reference.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::{HardwareConfig, IoConfig, ModelConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request, SchedPolicy};
use hobbit::engine::{Engine, EngineOptions};
use hobbit::loader::scorer::Class;
use hobbit::loader::{ExpertLoader, TaskKind};
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::synth::{
    tiny_model_config, tiny_store_config, write_synth_expert_store, write_synth_model,
};
use hobbit::model::ExpertStore;
use hobbit::predictor::Predictor;
use hobbit::residency::ExpertResidency;
use hobbit::{ExpertKey, Precision};

fn tiny_cfg() -> ModelConfig {
    tiny_store_config("pipeline-test")
}

/// Synthetic expert store (every expert at every precision) so the loader
/// has real bytes to move without the AOT compile step.
fn synth_store(cfg: &ModelConfig, dir: &Path) -> Arc<ExpertStore> {
    write_synth_expert_store(dir, cfg).expect("synth store");
    Arc::new(ExpertStore::load(dir, cfg).unwrap())
}

struct Rig {
    loader: ExpertLoader,
    cache: Arc<Mutex<CacheManager>>,
    copier: Arc<ThrottledCopier>,
    store: Arc<ExpertStore>,
}

/// Loader over a synthetic store with explicit pipeline knobs; `bw`
/// throttles the link so transfers stay observable mid-flight.
fn mk_loader(hi_cap: usize, lo_cap: usize, bw: f64, io: IoConfig, name: &str) -> Rig {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join(format!("hobbit_pipeline_{name}"));
    let store = synth_store(&cfg, &dir);
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        hi_cap,
        cfg.bytes_for(Precision::F32),
        lo_cap,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let loader = ExpertLoader::start_with(store.clone(), cache.clone(), copier.clone(), io);
    Rig { loader, cache, copier, store }
}

/// Residency facade over a synthetic store with explicit pipeline knobs.
fn mk_residency(
    hi_cap: usize,
    lo_cap: usize,
    bw: f64,
    io: IoConfig,
    name: &str,
) -> ExpertResidency {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join(format!("hobbit_pipeline_{name}"));
    let store = synth_store(&cfg, &dir);
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        hi_cap,
        cfg.bytes_for(Precision::F32),
        lo_cap,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    ExpertResidency::with_io(store, cache, copier, predictor, Precision::F32, Precision::Q8, io)
}

// ---------------------------------------------------------------------
// (a) byte equivalence: chunking changes WHEN bytes arrive, never what
// ---------------------------------------------------------------------

#[test]
fn chunked_transfer_is_byte_identical_to_monolithic() {
    // fine chunking (4096-byte records in 128-byte chunks) across 2 lanes
    let chunked = mk_loader(
        8,
        8,
        1e8,
        IoConfig { lanes: 2, chunk_bytes: 128, ..IoConfig::default() },
        "bytes_chunked",
    );
    // one lane, chunk >= record: the pre-pipeline monolithic transfer
    let mono = mk_loader(
        8,
        8,
        1e8,
        IoConfig { lanes: 1, chunk_bytes: usize::MAX, ..IoConfig::default() },
        "bytes_mono",
    );
    let picks = [
        (ExpertKey::new(0, 0), Precision::F32, Pool::Hi),
        (ExpertKey::new(1, 2), Precision::F32, Pool::Hi),
        (ExpertKey::new(2, 1), Precision::Q8, Pool::Lo),
        (ExpertKey::new(3, 3), Precision::Q8, Pool::Lo),
    ];
    for rig in [&chunked, &mono] {
        let mut ids = Vec::new();
        for &(key, prec, pool) in &picks {
            if let Some(id) = rig.loader.submit(key, prec, pool, TaskKind::OnDemand, key.layer)
            {
                ids.push(id);
            }
        }
        rig.loader.wait(&ids);
    }
    for &(key, prec, pool) in &picks {
        let want = chunked.store.record(key, prec).to_vec();
        for rig in [&chunked, &mono] {
            let cache = rig.cache.lock().unwrap();
            let pool_ref = match pool {
                Pool::Hi => &cache.hi,
                Pool::Lo => &cache.lo,
            };
            assert!(pool_ref.contains_ready(key), "{key:?} not committed");
            let buf = pool_ref.buffer(key).unwrap();
            let got = buf.lock().unwrap();
            assert_eq!(&got[..], &want[..], "bytes diverged for {key:?}");
        }
    }
    // accounting: both moved exactly the same bytes
    assert_eq!(chunked.copier.bytes_moved(), mono.copier.bytes_moved());
    assert_eq!(chunked.copier.transfers(), mono.copier.transfers());
}

// ---------------------------------------------------------------------
// (b) preemption bound: the misprediction penalty is O(one chunk)
// ---------------------------------------------------------------------

/// One f32 record (4096 B) at 1e4 B/s takes ~410 ms; a 256-byte chunk
/// ~26 ms. The bound below would be violated by the old non-preemptible
/// loader (~350 ms of leftover prefetch + ~410 ms own transfer ≈ 760 ms).
#[test]
fn ondemand_issued_mid_prefetch_ready_within_one_chunk_plus_own_transfer() {
    let rig = mk_loader(
        8,
        8,
        1e4,
        IoConfig { lanes: 1, chunk_bytes: 256, ..IoConfig::default() },
        "preempt_bound",
    );
    let wrong = ExpertKey::new(0, 0); // the mispredicted prefetch
    let miss = ExpertKey::new(1, 1); // the on-demand miss behind it
    let pf = rig
        .loader
        .submit(wrong, Precision::F32, Pool::Hi, TaskKind::Prefetch, 0)
        .expect("prefetch submitted");
    // let the transfer get well underway (~2 chunks in)
    std::thread::sleep(Duration::from_millis(60));
    let t0 = Instant::now();
    let od = rig
        .loader
        .submit(miss, Precision::F32, Pool::Hi, TaskKind::OnDemand, 1)
        .expect("on-demand submitted");
    rig.loader.wait(&[od]);
    let wait = t0.elapsed();
    // one chunk (~26 ms) + own transfer (~410 ms) + generous slack
    assert!(
        wait < Duration::from_millis(620),
        "on-demand waited {wait:?} behind an in-flight prefetch (preemption broken)"
    );
    let st = rig.loader.stats.lock().unwrap().clone();
    assert!(st.preemptions >= 1, "no preemption recorded");
    drop(st);
    // the preempted prefetch still completes, byte-identical
    rig.loader.wait(&[pf]);
    let cache = rig.cache.lock().unwrap();
    for key in [wrong, miss] {
        let buf = cache.hi.buffer(key).expect("committed");
        let got = buf.lock().unwrap();
        assert_eq!(&got[..], rig.store.record(key, Precision::F32));
    }
    drop(cache);
    assert_eq!(rig.copier.bytes_moved(), 2 * 4096, "work conservation");
    assert_eq!(rig.copier.transfers(), 2);
}

// ---------------------------------------------------------------------
// (c) bandwidth conservation: lanes split the link, never multiply it
// ---------------------------------------------------------------------

#[test]
fn lanes_conserve_total_link_bandwidth() {
    // two records at 4e4 B/s = ~102 ms each at full rate, ~205 ms serial.
    // Two lanes move them concurrently at half rate each: the drain must
    // still take ~the serial time (each lane would finish in ~102 ms if
    // lanes multiplied bandwidth — the bug this pins against).
    let rig = mk_loader(
        8,
        8,
        4e4,
        IoConfig { lanes: 2, chunk_bytes: 256, ..IoConfig::default() },
        "conserve",
    );
    let serial = Duration::from_secs_f64(2.0 * 4096.0 / 4e4);
    let t0 = Instant::now();
    let a = rig
        .loader
        .submit(ExpertKey::new(0, 0), Precision::F32, Pool::Hi, TaskKind::OnDemand, 0)
        .unwrap();
    let b = rig
        .loader
        .submit(ExpertKey::new(0, 1), Precision::F32, Pool::Hi, TaskKind::OnDemand, 0)
        .unwrap();
    rig.loader.wait(&[a, b]);
    let wall = t0.elapsed();
    assert!(
        wall.as_secs_f64() >= 0.75 * serial.as_secs_f64(),
        "two lanes drained 2 records in {wall:?} — lanes are multiplying bandwidth \
         (serial time {serial:?})"
    );
    assert!(
        wall.as_secs_f64() <= 2.0 * serial.as_secs_f64(),
        "two lanes took {wall:?} for {serial:?} of work — arbiter over-throttles"
    );
    assert_eq!(rig.copier.bytes_moved(), 2 * 4096);
}

// ---------------------------------------------------------------------
// (d) partial progress: a preempted slot stays Loading, never committed
// ---------------------------------------------------------------------

#[test]
fn preempted_transfer_keeps_slot_incoming_and_resumes_to_identical_commit() {
    let rig = mk_loader(
        8,
        8,
        1e4,
        IoConfig { lanes: 1, chunk_bytes: 256, ..IoConfig::default() },
        "partial",
    );
    let pf_key = ExpertKey::new(2, 0);
    let od_key = ExpertKey::new(3, 1);
    let pf = rig
        .loader
        .submit(pf_key, Precision::F32, Pool::Hi, TaskKind::Prefetch, 2)
        .expect("prefetch submitted");
    std::thread::sleep(Duration::from_millis(60)); // mid-transfer
    let od = rig
        .loader
        .submit(od_key, Precision::F32, Pool::Hi, TaskKind::OnDemand, 3)
        .expect("on-demand submitted");
    // while the on-demand transfer runs (~410 ms), the preempted prefetch
    // must be parked partial: reserved (Loading) but NOT readable
    std::thread::sleep(Duration::from_millis(150));
    {
        let cache = rig.cache.lock().unwrap();
        assert!(
            !cache.hi.contains_ready(pf_key),
            "a partially transferred slot surfaced as Ready"
        );
        assert!(
            cache.hi.is_loading(pf_key),
            "the preempted transfer lost its reservation"
        );
        assert!(cache.hi.buffer(pf_key).is_none(), "partial buffer readable");
    }
    rig.loader.wait(&[od, pf]);
    let cache = rig.cache.lock().unwrap();
    assert!(cache.hi.contains_ready(pf_key));
    let buf = cache.hi.buffer(pf_key).unwrap();
    let got = buf.lock().unwrap();
    assert_eq!(
        &got[..],
        rig.store.record(pf_key, Precision::F32),
        "resumed transfer committed different bytes"
    );
}

// ---------------------------------------------------------------------
// (e) started-prefetch promotion
// ---------------------------------------------------------------------

#[test]
fn promote_reprioritizes_a_started_prefetch() {
    let rig = mk_loader(
        8,
        8,
        1e4,
        IoConfig { lanes: 1, chunk_bytes: 256, ..IoConfig::default() },
        "promote_started",
    );
    let key = ExpertKey::new(1, 3);
    let id = rig
        .loader
        .submit(key, Precision::F32, Pool::Hi, TaskKind::Prefetch, 1)
        .expect("prefetch submitted");
    std::thread::sleep(Duration::from_millis(60)); // well into the transfer
    assert!(
        rig.loader.promote_to_ondemand(id),
        "promotion of a STARTED prefetch must succeed (it re-prioritizes \
         the remaining chunks)"
    );
    rig.loader.wait(&[id]);
    let st = rig.loader.stats.lock().unwrap().clone();
    assert_eq!(st.inflight_promotions, 1, "promotion not applied mid-flight");
    assert_eq!(st.ondemand_loads.iter().sum::<u64>(), 1, "committed as on-demand");
    assert_eq!(st.prefetch_loads.iter().sum::<u64>(), 0);
    // promotion of a completed task reports false
    assert!(!rig.loader.promote_to_ondemand(id));
}

// ---------------------------------------------------------------------
// (f) no-slot drops: counted, and the facade re-acquires
// ---------------------------------------------------------------------

#[test]
fn noslot_drop_is_counted_and_facade_reacquires() {
    // hi pool of ONE slot: once A is resident and pinned, B's load has no
    // evictable victim
    let resid = mk_residency(
        1,
        4,
        1e8,
        IoConfig { lanes: 1, chunk_bytes: 1024, ..IoConfig::default() },
        "noslot",
    );
    let a = ExpertKey::new(0, 0);
    let b = ExpertKey::new(0, 1);
    let (_ua, wa) = resid.acquire(0, vec![(a, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&wa);
    assert!(resid.buffer(a, Pool::Hi).is_some());

    // B: probe misses, the load finds every slot pinned -> NoSlot drops
    // (counted once per re-acquire attempt), ticket resolves unfulfilled
    let (_ub, wb) = resid.acquire(0, vec![(b, Class::Hi, vec![1.0], 0.0)], None);
    assert_eq!(wb.len(), 1);
    resid.wait(&wb);
    let t = &wb.tickets()[0];
    assert!(t.is_ready(), "waiters must wake even without a slot");
    assert!(
        !t.is_fulfilled(),
        "a no-slot completion must not claim the expert resident"
    );
    assert!(
        resid.buffer(b, Pool::Hi).is_none(),
        "no bytes were moved; executing would read a stale slot"
    );
    let st = resid.loader_stats();
    assert!(
        st.noslot_drops >= 2,
        "every re-acquire attempt must be counted (got {})",
        st.noslot_drops
    );

    // drop the pins (the barrier's release path) and re-acquire: the slot
    // frees and the load now lands
    resid.release(a, Pool::Hi);
    resid.release(b, Pool::Hi);
    let (_ub2, wb2) = resid.acquire(1, vec![(b, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&wb2);
    assert!(wb2.is_empty() || wb2.tickets()[0].is_fulfilled());
    assert!(
        resid.buffer(b, Pool::Hi).is_some(),
        "re-acquire after pin release must load the expert"
    );
    resid.release(b, Pool::Hi);
}

// ---------------------------------------------------------------------
// (g) deadline policy: scheduling must never change results
// ---------------------------------------------------------------------

const SHORT_PROMPTS: [&str; 3] =
    ["alpha request one", "bravo request two", "charlie request three"];

fn big_cfg(name: &str) -> ModelConfig {
    let mut cfg = tiny_model_config(name);
    cfg.max_seq = 512;
    cfg
}

fn mk_engine(name: &str, dir: &Path, load_bw: f64) -> Engine {
    let hw = HardwareConfig {
        name: name.into(),
        load_bw,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    // dynamic loading off: logits depend only on token history, so
    // scheduling policy must not change them. The fetch precision is
    // pinned to the hi format: this equivalence suite compares byte
    // streams, so the per-acquire precision choice must be frozen.
    let policy = PolicyConfig {
        dynamic_loading: false,
        prefetch_depth: 2,
        pin_precision: Some(Precision::F32),
        ..PolicyConfig::default()
    };
    Engine::new_reference(dir, big_cfg(name), EngineOptions::new(hw, policy))
        .expect("reference engine")
}

#[test]
fn deadline_policy_serves_bit_identically_to_fcfs() {
    let name = "deadline_equiv";
    let dir: PathBuf = std::env::temp_dir().join(format!("hobbit_pipeline_{name}"));
    write_synth_model(&dir, &big_cfg(name), 0xD34D11).expect("synth model");
    let max_new = 5usize;
    let long_prompt = "x".repeat(299); // 300 tokens with BOS

    // FCFS batch-1 ground truth
    let mut reference = Vec::new();
    {
        let eng = mk_engine(name, &dir, 1e9);
        let mut coord = Coordinator::new(eng);
        for (i, p) in SHORT_PROMPTS.iter().enumerate() {
            reference
                .push(coord.generate(&Request::new(i as u64 + 1, *p, max_new)).unwrap().tokens);
        }
        let long_req = Request::new(99, long_prompt.clone(), max_new);
        reference.push(coord.generate(&long_req).unwrap().tokens);
    }

    // interleaved + deadline policy, offload-bound, tight TTFT budget so
    // the urgency path genuinely engages for the long admission
    let eng = mk_engine(name, &dir, 2e6);
    let mut coord = Coordinator::interleaved(eng);
    coord.sched_policy = SchedPolicy::Deadline;
    coord.ttft_deadline = Duration::from_millis(1);
    coord.max_active = 4;
    for (i, p) in SHORT_PROMPTS.iter().enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, max_new));
    }
    coord.submit(Request::new(99, long_prompt, max_new));
    let mut results = coord.drain().expect("drain");
    assert!(coord.take_failures().is_empty(), "no request may fail");
    assert_eq!(results.len(), SHORT_PROMPTS.len() + 1);
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert_eq!(
            &r.tokens, want,
            "request {}: deadline-policy serving diverged from the FCFS reference",
            r.id
        );
    }
    // the long admission's prefill really was sliced under the policy
    let sch = coord.scheduler_stats().clone();
    assert!(sch.prefill_slices >= 16, "only {} prefill slices", sch.prefill_slices);
    assert_eq!(sch.prefill_failures, 0);
}
