//! THE cross-language end-to-end correctness test: the rust PJRT engine
//! (L3 over AOT-compiled L2/L1 artifacts) must reproduce the pure-JAX
//! oracle's logits on identical weights, token by token.
//!
//! Requires `make artifacts` (skips cleanly otherwise).

use std::path::{Path, PathBuf};

use hobbit::config::{HardwareConfig, PolicyConfig};
use hobbit::engine::{Capture, Engine, EngineOptions};
use hobbit::util::json::Json;

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts(model: &str) -> bool {
    artifacts_root().join(model).join("manifest.json").exists()
        && artifacts_root().join("weights").join(model).join("reference_logits.json").exists()
}

fn quality_hw() -> HardwareConfig {
    HardwareConfig {
        name: "test".into(),
        load_bw: 64e9,
        load_latency: 0.0,
        hi_cache_experts: 256,
        lo_cache_experts: 8,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

fn load_reference(model: &str) -> (Vec<u32>, Vec<Vec<f64>>) {
    let path = artifacts_root().join("weights").join(model).join("reference_logits.json");
    let j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let tokens: Vec<u32> = j
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    let logits: Vec<Vec<f64>> = j
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
        .collect();
    (tokens, logits)
}

fn check_model(model: &str) {
    if !have_artifacts(model) {
        eprintln!("skipping {model}: artifacts not built");
        return;
    }
    let (tokens, ref_logits) = load_reference(model);
    // pure high-precision config: logits must match the f32 oracle
    let policy = PolicyConfig { dynamic_loading: false, ..PolicyConfig::default() };
    let mut opts = EngineOptions::new(quality_hw(), policy);
    opts.capture = Capture::none();
    let mut eng = Engine::new(&artifacts_root(), model, opts).expect("engine");

    let mut kv = eng.new_sequence();
    let mut got = Vec::with_capacity(tokens.len());
    got.push(eng.prefill(&mut kv, &tokens[..1]).unwrap());
    for &t in &tokens[1..] {
        got.push(eng.decode_step(&mut kv, t).unwrap());
    }

    let mut worst = 0.0f64;
    for (pos, (g, r)) in got.iter().zip(&ref_logits).enumerate() {
        assert_eq!(g.len(), r.len(), "vocab mismatch at {pos}");
        // compare argmax and normalized error
        let scale = r.iter().map(|v| v.abs()).fold(0.0f64, f64::max).max(1e-6);
        for (i, (a, b)) in g.iter().zip(r).enumerate() {
            let err = ((*a as f64) - b).abs() / scale;
            worst = worst.max(err);
            assert!(
                err < 2e-3,
                "{model} pos {pos} vocab {i}: engine {a} vs reference {b} (rel {err:.2e})"
            );
        }
    }
    eprintln!("{model}: {} positions, worst relative error {worst:.2e}", got.len());
}

#[test]
fn mixtral_tiny_matches_reference() {
    check_model("mixtral-tiny");
}

#[test]
fn phi_tiny_matches_reference() {
    check_model("phi-tiny");
}

/// Chunked prefill must agree with token-by-token decode (exercises the
/// s16/s128 artifacts + padding path against the s1 path).
#[test]
fn chunked_prefill_matches_decode_path() {
    let model = "mixtral-tiny";
    if !have_artifacts(model) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (tokens, _) = load_reference(model);
    let policy = PolicyConfig { dynamic_loading: false, ..PolicyConfig::default() };
    let mk = || {
        Engine::new(
            &artifacts_root(),
            model,
            EngineOptions::new(quality_hw(), policy.clone()),
        )
        .unwrap()
    };
    // path A: prefill all tokens at once (chunks of 16 + 1s)
    let mut ea = mk();
    let mut kva = ea.new_sequence();
    let la = ea.prefill(&mut kva, &tokens).unwrap();
    // path B: prefill 1, then decode the rest
    let mut eb = mk();
    let mut kvb = eb.new_sequence();
    let mut lb = eb.prefill(&mut kvb, &tokens[..1]).unwrap();
    for &t in &tokens[1..] {
        lb = eb.decode_step(&mut kvb, t).unwrap();
    }
    let scale = lb.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-6);
    for (i, (a, b)) in la.iter().zip(&lb).enumerate() {
        assert!(
            (a - b).abs() / scale < 2e-3,
            "vocab {i}: chunked {a} vs stepwise {b}"
        );
    }
    let _ = Path::new("");
}
