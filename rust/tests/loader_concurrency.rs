//! Loader/scheduler-thread integration: on-demand priority, prefetch
//! generations, waiting semantics, and the loader's interaction with the
//! cache under churn. Uses the real expert store (skips if artifacts are
//! not built) with an aggressive (fast) link so tests stay quick.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::ModelConfig;
use hobbit::loader::{ExpertLoader, TaskKind};
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::ExpertStore;
use hobbit::runtime::Manifest;
use hobbit::util::rng::Rng;
use hobbit::{ExpertKey, Precision};

struct Setup {
    cfg: ModelConfig,
    loader: ExpertLoader,
    cache: Arc<Mutex<CacheManager>>,
    copier: Arc<ThrottledCopier>,
    store: Arc<ExpertStore>,
}

fn setup(hi_cap: usize, lo_cap: usize, bw: f64) -> Option<Setup> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mdir = root.join("mixtral-tiny");
    if !mdir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest =
        Manifest::parse(&std::fs::read_to_string(mdir.join("manifest.json")).unwrap()).unwrap();
    let cfg = ModelConfig::from_manifest(&manifest.model_json()).unwrap();
    let store = Arc::new(ExpertStore::load(&root.join("weights/mixtral-tiny"), &cfg).unwrap());
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        hi_cap,
        cfg.bytes_for(Precision::F32),
        lo_cap,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let loader = ExpertLoader::start(store.clone(), cache.clone(), copier.clone());
    Some(Setup { cfg, loader, cache, copier, store })
}

#[test]
fn ondemand_load_completes_and_data_matches_store() {
    let Some(s) = setup(8, 8, 8e9) else { return };
    let key = ExpertKey::new(2, 3);
    let id = s
        .loader
        .submit(key, Precision::F32, Pool::Hi, TaskKind::OnDemand, 2)
        .expect("task submitted");
    s.loader.wait(&[id]);
    let cache = s.cache.lock().unwrap();
    assert!(cache.hi.contains_ready(key));
    let buf = cache.hi.buffer(key).unwrap();
    let got = buf.lock().unwrap();
    assert_eq!(&got[..], s.store.record(key, Precision::F32));
    assert_eq!(s.copier.transfers(), 1);
}

#[test]
fn duplicate_submit_is_deduped() {
    let Some(s) = setup(8, 8, 8e9) else { return };
    let key = ExpertKey::new(0, 1);
    let id = s.loader.submit(key, Precision::F32, Pool::Hi, TaskKind::OnDemand, 0).unwrap();
    s.loader.wait(&[id]);
    // resident now: second submit is a no-op
    assert!(s.loader.submit(key, Precision::F32, Pool::Hi, TaskKind::OnDemand, 0).is_none());
    assert_eq!(s.copier.transfers(), 1);
}

#[test]
fn stale_prefetch_generation_dropped() {
    let Some(s) = setup(8, 8, 2e8) else { return }; // slow link: queue builds
    // saturate the link with one on-demand first so prefetches stay queued
    let busy =
        s.loader.submit(ExpertKey::new(0, 0), Precision::F32, Pool::Hi, TaskKind::OnDemand, 0);
    let mut pf_ids = Vec::new();
    for e in 1..5 {
        if let Some(id) = s.loader.submit(
            ExpertKey::new(1, e),
            Precision::Q8,
            Pool::Lo,
            TaskKind::Prefetch,
            0,
        ) {
            pf_ids.push((e, id));
        }
    }
    // invalidate everything queued
    s.loader.bump_prefetch_generation();
    // waiting must still terminate (stale tasks are marked done, not lost)
    let ids: Vec<u64> = pf_ids.iter().map(|(_, id)| *id).collect();
    if let Some(b) = busy {
        s.loader.wait(&[b]);
    }
    s.loader.wait(&ids);
}

#[test]
fn concurrent_submits_from_many_keys_all_complete() {
    let Some(s) = setup(16, 16, 8e9) else { return };
    let mut rng = Rng::new(7);
    let mut ids = Vec::new();
    for _ in 0..40 {
        let key = ExpertKey::new(
            rng.below(s.cfg.n_layers as usize) as u32,
            rng.below(s.cfg.n_experts as usize) as u32,
        );
        let (p, pool) = if rng.below(2) == 0 {
            (Precision::F32, Pool::Hi)
        } else {
            (Precision::Q8, Pool::Lo)
        };
        if let Some(id) = s.loader.submit(key, p, pool, TaskKind::OnDemand, key.layer) {
            ids.push(id);
        }
    }
    s.loader.wait(&ids);
    let cache = s.cache.lock().unwrap();
    assert!(cache.hi.len() <= 16 && cache.lo.len() <= 16);
    let st = s.loader.stats.lock().unwrap();
    let loads: u64 = st.ondemand_loads.iter().sum();
    assert_eq!(loads, s.copier.transfers());
    assert!(st.bytes_loaded > 0);
}

#[test]
fn eviction_under_pressure_keeps_capacity_bound() {
    let Some(s) = setup(4, 2, 8e9) else { return };
    let mut ids = Vec::new();
    for l in 0..s.cfg.n_layers {
        for e in 0..s.cfg.n_experts {
            if let Some(id) = s.loader.submit(
                ExpertKey::new(l, e),
                Precision::F32,
                Pool::Hi,
                TaskKind::OnDemand,
                l,
            ) {
                ids.push(id);
            }
        }
    }
    s.loader.wait(&ids);
    let cache = s.cache.lock().unwrap();
    assert!(cache.hi.len() <= 4, "hi pool overflow: {}", cache.hi.len());
    assert!(cache.stats.evictions >= 60, "evictions {}", cache.stats.evictions);
}

#[test]
fn is_idle_false_while_transfer_in_flight() {
    // regression: is_idle only checked the two queue lanes, so a popped
    // task still copying made the loader claim idle mid-transfer
    let Some(s) = setup(8, 8, 1e7) else { return }; // ~150ms per f32 expert
    assert!(s.loader.is_idle());
    let key = ExpertKey::new(1, 1);
    let id = s
        .loader
        .submit(key, Precision::F32, Pool::Hi, TaskKind::OnDemand, 1)
        .expect("task submitted");
    // give the scheduler thread time to pop the task: the lanes are empty
    // again but the throttled copy is still running
    std::thread::sleep(std::time::Duration::from_millis(30));
    assert!(!s.loader.is_idle(), "mid-transfer loader claimed idle");
    s.loader.wait(&[id]);
    assert!(s.loader.is_idle(), "loader not idle after wait returned");
    assert!(s.cache.lock().unwrap().hi.contains_ready(key));
}

#[test]
fn try_wait_polls_without_blocking() {
    let Some(s) = setup(8, 8, 1e7) else { return }; // slow link
    let key = ExpertKey::new(2, 0);
    let id = s
        .loader
        .submit(key, Precision::F32, Pool::Hi, TaskKind::OnDemand, 2)
        .expect("task submitted");
    assert!(!s.loader.try_wait(&[id]), "150ms load reported complete instantly");
    while !s.loader.try_wait(&[id]) {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    // the empty set is trivially complete
    assert!(s.loader.try_wait(&[]));
    assert!(s.cache.lock().unwrap().hi.contains_ready(key));
}

#[test]
fn completion_callback_fires_exactly_once_per_registration() {
    let Some(s) = setup(8, 8, 8e9) else { return };
    let id = s
        .loader
        .submit(ExpertKey::new(0, 2), Precision::F32, Pool::Hi, TaskKind::OnDemand, 0)
        .expect("task submitted");
    let (tx, rx) = std::sync::mpsc::channel();
    s.loader.on_complete(id, move |done| {
        let _ = tx.send(done);
    });
    let got = rx.recv_timeout(std::time::Duration::from_secs(10)).expect("callback fired");
    assert_eq!(got, id);
    assert!(rx.try_recv().is_err(), "callback fired twice");
    // registering after completion fires immediately (id not yet consumed)
    let (tx2, rx2) = std::sync::mpsc::channel();
    s.loader.on_complete(id, move |done| {
        let _ = tx2.send(done);
    });
    assert_eq!(rx2.try_recv().unwrap(), id);
}

#[test]
fn loader_drop_joins_cleanly_with_pending_work() {
    let Some(s) = setup(8, 8, 1e8) else { return }; // slow
    for e in 0..6 {
        let _ = s.loader.submit(
            ExpertKey::new(3, e),
            Precision::F32,
            Pool::Hi,
            TaskKind::Prefetch,
            3,
        );
    }
    drop(s.loader); // must not hang or panic
}
