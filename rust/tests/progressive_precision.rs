//! Progressive low-bits-first streaming regression suite: a hi-pool miss
//! may stream its lo-precision record first (the ticket resolves and the
//! expert is usable the moment the lo tier lands) while the hi record
//! upgrades the slot in place from the prefetch lane.
//!
//! Everything here is artifact-free: a synthetic expert store on disk
//! (like `residency.rs` / `transfer_pipeline.rs`) gives the loader real
//! bytes to move, and a throttled link keeps transfers observable
//! mid-flight. Timing assertions use modeled link sleeps in the hundreds
//! of milliseconds with generous slack, so they hold in debug and
//! release CI alike.
//!
//! Coverage (the progressive contract):
//! * a tolerant hi-pool miss is usable within the LO-record stall bound,
//!   at the lo tier, with exactly the store's lo bytes — while the hi
//!   upgrade still streams in the background;
//! * the background upgrade commits bytes identical to a direct hi load,
//!   without any further acquire;
//! * an upgrade orphaned by eviction aborts without touching the slot's
//!   new occupant, and the pin ledger stays balanced;
//! * `--pin-precision` freezes the choice: pinning the hi format is
//!   byte-identical to the legacy non-progressive stream (same bytes,
//!   same transfer count, zero staged loads) even when progressive mode
//!   is requested, and pinning a narrower format streams exactly that
//!   record;
//! * a critical miss (low unimportance score) on an idle link still
//!   streams hi directly — progressive never taxes the critical path;
//! * TTFT-deadline urgency lowers the fetch floor even for critical
//!   misses.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::{IoConfig, ModelConfig};
use hobbit::loader::scorer::Class;
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::synth::{tiny_store_config, write_synth_expert_store};
use hobbit::model::ExpertStore;
use hobbit::predictor::Predictor;
use hobbit::residency::ExpertResidency;
use hobbit::{ExpertKey, Precision};

/// On-wire record sizes of `tiny_store_config`: F32 = 4096 B, Q8 = 1024 B,
/// Q4 = 512 B (pinned by `model::synth`).
fn tiny_cfg() -> ModelConfig {
    tiny_store_config("progressive-test")
}

/// Synthetic expert store (every expert at every precision) so the loader
/// has real bytes to move without the AOT compile step.
fn synth_store(cfg: &ModelConfig, dir: &Path) -> Arc<ExpertStore> {
    write_synth_expert_store(dir, cfg).expect("synth store");
    Arc::new(ExpertStore::load(dir, cfg).unwrap())
}

/// Residency facade in an explicit precision mode; `bw` throttles the
/// link so transfers stay observable mid-flight.
fn mk_residency(
    hi_cap: usize,
    bw: f64,
    pin: Option<Precision>,
    progressive: bool,
    name: &str,
) -> (ExpertResidency, Arc<ThrottledCopier>, Arc<ExpertStore>) {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join(format!("hobbit_progressive_{name}"));
    let store = synth_store(&cfg, &dir);
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        hi_cap,
        cfg.bytes_for(Precision::F32),
        4,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    let resid = ExpertResidency::with_io(
        store.clone(),
        cache,
        copier.clone(),
        predictor,
        Precision::F32,
        Precision::Q8,
        IoConfig { lanes: 2, chunk_bytes: 256, ..IoConfig::default() },
    )
    .with_precision_mode(pin, progressive, 0.6);
    (resid, copier, store)
}

/// Spin until the loader drains (including upgrade continuations, which
/// hold the prefetch queue / in-flight count until they land).
fn drain(resid: &ExpertResidency) {
    let t0 = Instant::now();
    while !resid.is_idle() {
        assert!(t0.elapsed() < Duration::from_secs(30), "loader never drained");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------
// (a) time-to-first-usable is bounded by the LO record, and the
//     background upgrade commits bytes identical to a direct hi load
// ---------------------------------------------------------------------

/// At 1e4 B/s the lo record (1024 B) takes ~102 ms and the hi record
/// (4096 B) ~410 ms. The old hi-only loader could not resolve the ticket
/// under ~410 ms; the progressive one must do it in ~lo time.
#[test]
fn tolerant_miss_usable_within_lo_record_stall_bound_then_upgrades() {
    let (resid, copier, store) = mk_residency(8, 1e4, None, true, "ttfu");
    let key = ExpertKey::new(0, 1);
    let t0 = Instant::now();
    // unimportance score 1.0 > 0.5 * T1: squarely in the tolerant band
    let (uses, waits) = resid.acquire(0, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
    assert_eq!(uses.len(), 1);
    assert_eq!(waits.len(), 1, "the miss must submit a load");
    resid.wait(&waits);
    let ttfu = t0.elapsed();
    assert!(
        ttfu < Duration::from_millis(300),
        "time-to-first-usable {ttfu:?} is not bounded by the ~102 ms lo record \
         (the ~410 ms hi-only stall is back)"
    );

    // usable NOW, at the lo tier, with exactly the store's lo bytes —
    // while the hi upgrade is still streaming in the background
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("resident at the lo tier");
    assert_eq!(tier, Precision::Q8, "the floor tier must be the lo precision");
    assert_eq!(&bytes[..], store.record(key, Precision::Q8), "lo tier bytes diverged");
    assert_eq!(resid.loader_stats().progressive_loads, 1);

    // the upgrade lands on its own — no further acquire — and the slot
    // then holds the hi record bit-for-bit
    drain(&resid);
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("still resident");
    assert_eq!(tier, Precision::F32, "background upgrade never flipped the tier");
    assert_eq!(
        &bytes[..],
        store.record(key, Precision::F32),
        "upgraded bytes differ from a direct hi load"
    );
    let st = resid.loader_stats();
    assert_eq!(st.upgrades_committed, 1);
    assert_eq!(st.upgrades_aborted, 0);
    // lo record + hi upgrade, nothing more
    assert_eq!(copier.bytes_moved(), 1024 + 4096);
    resid.release(key, Pool::Hi);
}

// ---------------------------------------------------------------------
// (b) an upgrade orphaned by eviction aborts; the new occupant and the
//     pin ledger stay intact
// ---------------------------------------------------------------------

#[test]
fn orphaned_upgrade_aborts_without_touching_the_new_occupant() {
    // ONE hi slot at 1e5 B/s: A's lo record lands in ~10 ms, its ~41 ms
    // hi upgrade is still streaming when B steals the slot
    let (resid, _copier, store) = mk_residency(1, 1e5, None, true, "orphan");
    let a = ExpertKey::new(0, 0);
    let b = ExpertKey::new(0, 1);
    let (_ua, wa) = resid.acquire(0, vec![(a, Class::Hi, vec![1.0], 1.0)], None);
    resid.wait(&wa);
    assert_eq!(
        resid.resident_record(a, Pool::Hi).expect("A resident").0,
        Precision::Q8,
        "A must be usable at the lo tier while its upgrade streams"
    );
    resid.release(a, Pool::Hi);

    // B evicts A from the only slot mid-upgrade
    let (_ub, wb) = resid.acquire(0, vec![(b, Class::Hi, vec![1.0], 1.0)], None);
    resid.wait(&wb);
    drain(&resid);

    let st = resid.loader_stats();
    assert_eq!(st.progressive_loads, 2, "both misses staged lo-first");
    assert_eq!(st.upgrades_aborted, 1, "A's orphaned upgrade must abort");
    assert_eq!(st.upgrades_committed, 1, "B's own upgrade must still land");
    assert!(resid.buffer(a, Pool::Hi).is_none(), "evicted expert resurfaced");
    let (tier, bytes) = resid.resident_record(b, Pool::Hi).expect("B resident");
    assert_eq!(tier, Precision::F32);
    assert_eq!(&bytes[..], store.record(b, Precision::F32), "the abort tore B's slot");
    resid.release(b, Pool::Hi);
    let cache = resid.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count() + c.lo.pinned_count(), 0, "leaked pins");
}

// ---------------------------------------------------------------------
// (c) --pin-precision freezes the choice
// ---------------------------------------------------------------------

/// Pinning the hi format reproduces the legacy non-progressive byte
/// stream bit-for-bit — even when progressive mode is *requested* (the
/// pin wins; `PolicyConfig::validate` rejects the combination upstream,
/// the facade coerces it defensively).
#[test]
fn pin_hi_is_byte_identical_to_the_legacy_stream() {
    let (pinned, cp_pin, store) = mk_residency(8, 1e6, Some(Precision::F32), true, "pin_hi");
    let (legacy, cp_leg, _) = mk_residency(8, 1e6, None, false, "legacy");
    let key = ExpertKey::new(1, 2);
    // a maximally tolerant score: progressive mode WOULD stage lo-first
    for r in [&pinned, &legacy] {
        let (_u, w) = r.acquire(1, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
        r.wait(&w);
        drain(r);
    }
    let (tier_p, bytes_p) = pinned.resident_record(key, Pool::Hi).expect("pinned resident");
    let (tier_l, bytes_l) = legacy.resident_record(key, Pool::Hi).expect("legacy resident");
    assert_eq!(tier_p, Precision::F32);
    assert_eq!(tier_l, Precision::F32);
    assert_eq!(bytes_p, bytes_l, "pinned-hi bytes diverged from the legacy stream");
    assert_eq!(&bytes_p[..], store.record(key, Precision::F32));
    for (r, cp) in [(&pinned, &cp_pin), (&legacy, &cp_leg)] {
        let st = r.loader_stats();
        assert_eq!(st.progressive_loads, 0, "a pinned fetch must never stage");
        assert_eq!(st.upgrades_committed + st.upgrades_aborted, 0);
        assert_eq!(cp.bytes_moved(), 4096, "exactly the hi record, once");
        assert_eq!(cp.transfers(), 1);
        r.release(key, Pool::Hi);
    }
}

/// Pinning a narrower format streams exactly that record into the hi
/// pool's (native-sized) slots — no staging, no upgrade.
#[test]
fn pin_narrow_streams_exactly_the_pinned_record() {
    let (resid, copier, store) = mk_residency(8, 1e6, Some(Precision::Q4), true, "pin_q4");
    let key = ExpertKey::new(2, 0);
    let (_u, w) = resid.acquire(2, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
    resid.wait(&w);
    drain(&resid);
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("resident");
    assert_eq!(tier, Precision::Q4);
    assert_eq!(&bytes[..], store.record(key, Precision::Q4));
    let st = resid.loader_stats();
    assert_eq!(st.progressive_loads, 0);
    assert_eq!(st.upgrades_committed + st.upgrades_aborted, 0);
    assert_eq!(copier.bytes_moved(), 512, "exactly the q4 record");
    resid.release(key, Pool::Hi);
}

// ---------------------------------------------------------------------
// (d) the per-acquire floor decision: criticality and deadline slack
// ---------------------------------------------------------------------

/// A critical miss (score 0, idle link, no deadline pressure) streams the
/// hi record directly: progressive mode must never tax the critical path
/// with a staged load it does not need.
#[test]
fn critical_miss_on_idle_link_streams_hi_directly() {
    let (resid, copier, store) = mk_residency(8, 1e6, None, true, "critical");
    let key = ExpertKey::new(2, 3);
    let (_u, w) = resid.acquire(2, vec![(key, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&w);
    drain(&resid);
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("resident");
    assert_eq!(tier, Precision::F32, "a critical miss must land at the hi tier");
    assert_eq!(&bytes[..], store.record(key, Precision::F32));
    let st = resid.loader_stats();
    assert_eq!(st.progressive_loads, 0, "no staged load on the critical path");
    assert_eq!(st.upgrades_committed + st.upgrades_aborted, 0);
    assert_eq!(copier.bytes_moved(), 4096);
    resid.release(key, Pool::Hi);
}

/// TTFT-deadline urgency lowers the fetch floor even for a critical
/// score: under deadline pressure, first-usable beats first-exact.
#[test]
fn deadline_urgency_lowers_the_fetch_floor() {
    let (resid, _copier, _store) = mk_residency(8, 1e6, None, true, "urgent");
    resid.set_deadline_urgent(true);
    let key = ExpertKey::new(3, 0);
    let (_u, w) = resid.acquire(3, vec![(key, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&w);
    drain(&resid);
    let st = resid.loader_stats();
    assert_eq!(st.progressive_loads, 1, "deadline urgency must stage lo-first");
    assert_eq!(st.upgrades_committed, 1, "the upgrade still lands in the background");
    // urgency is a latch the coordinator publishes per step; clearing it
    // restores the hi-direct default
    resid.set_deadline_urgent(false);
    let key2 = ExpertKey::new(3, 1);
    let (_u2, w2) = resid.acquire(3, vec![(key2, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&w2);
    drain(&resid);
    assert_eq!(resid.loader_stats().progressive_loads, 1, "cleared urgency staged again");
    resid.release(key, Pool::Hi);
    resid.release(key2, Pool::Hi);
}
