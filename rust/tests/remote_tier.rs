//! Remote expert tier regression suite: multi-node expert sharding with
//! peer fetch over the modeled network link class.
//!
//! Everything except the final test is artifact-free and in-process: a
//! synthetic expert store on disk, a real [`ShardServer`] on localhost,
//! and the real residency/loader stack over a [`TieredStore`]. The final
//! test is the multi-process acceptance run: two `hobbit shard-serve`
//! child processes serve disjoint shards to a reference engine whose
//! local shard is empty, and the generated logits must be bit-identical
//! to a single-node local-store run — including when one peer is killed
//! mid-generation (disk-tier failover).
//!
//! Coverage:
//! * a peer-owned expert acquired through the residency stack is
//!   byte-identical to the local store, and counted in `remote_fetches`;
//! * a silent (accept-then-hang) peer is bounded by the connect/read
//!   timeouts and bounded retry — the fetch falls to disk, never wedges;
//! * a dead peer breaks the circuit: later fetches skip straight to
//!   disk, fast, with `peer_failovers` counting the degradation;
//! * cross-tier prefetch: `stage_async` (and the predictor's
//!   `plan_prefetch` staging pass) pulls peer records into the staged
//!   side-cache ahead of demand;
//! * the network link class accounts its bytes independently of PCIe —
//!   peer traffic never shows up as PCIe bytes;
//! * the multi-process bit-identity + failover acceptance test.

use std::io::BufRead;
use std::io::BufReader;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::{HardwareConfig, IoConfig, ModelConfig, PeerSpec, PolicyConfig, RemoteConfig};
use hobbit::engine::{Engine, EngineOptions};
use hobbit::loader::scorer::Class;
use hobbit::memory::{LinkModel, ThrottledCopier, ONDEMAND_WEIGHT};
use hobbit::model::synth::{
    tiny_model_config, tiny_store_config, write_store_manifest, write_synth_expert_store,
    write_synth_model,
};
use hobbit::model::ExpertStore;
use hobbit::predictor::Predictor;
use hobbit::remote::{FetchTier, RetryPolicy, ShardServer, ShardSpec, TieredStore};
use hobbit::residency::ExpertResidency;
use hobbit::tokenizer::BOS;
use hobbit::{ExpertKey, Precision};

/// Synthetic store on disk (`tiny_store_config`: 4 layers x 4 experts,
/// flat indices 0-15, f32 record 4096 B).
fn synth_store(name: &str) -> (ModelConfig, PathBuf, Arc<ExpertStore>) {
    let cfg = tiny_store_config(name);
    let dir = std::env::temp_dir().join(format!("hobbit_remote_tier_{name}"));
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
    (cfg, dir, store)
}

/// Remote config with localhost-grade timeouts and a fast modeled link.
fn remote_cfg(peers: Vec<PeerSpec>, local: &str) -> RemoteConfig {
    RemoteConfig {
        local_shard: ShardSpec::parse(local).unwrap(),
        peers,
        net_bw: 1e9,
        net_latency: 0.0,
        retry: RetryPolicy::fast(),
        cooldown: Duration::from_millis(300),
        ..RemoteConfig::default()
    }
}

/// The real residency facade (loader lanes + cache + predictor) over a
/// tiered store; `bw` is the modeled PCIe bandwidth.
fn mk_residency(tiered: Arc<TieredStore>, bw: f64) -> (ExpertResidency, Arc<ThrottledCopier>) {
    let cfg = tiered.config().clone();
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        8,
        cfg.bytes_for(Precision::F32),
        4,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    let resid = ExpertResidency::with_tiered(
        tiered,
        cache,
        copier.clone(),
        predictor,
        Precision::F32,
        Precision::Q8,
        IoConfig { lanes: 2, chunk_bytes: 1024, ..IoConfig::default() },
    );
    (resid, copier)
}

/// A live in-process shard server owning the top half of the flat space.
fn top_half_server(store: Arc<ExpertStore>) -> (String, ShardSpec) {
    let shard = ShardSpec::parse("8-15").unwrap();
    let server = ShardServer::bind("127.0.0.1:0", store, shard.clone(), 4096).unwrap();
    (server.serve_background().to_string(), shard)
}

// ---------------------------------------------------------------------
// (a) byte-identity through the residency/loader stack
// ---------------------------------------------------------------------

#[test]
fn remote_acquire_is_byte_identical_through_the_loader_stack() {
    let (cfg, dir, store) = synth_store("bitident");
    let (addr, shard) = top_half_server(store.clone());
    let rc = remote_cfg(vec![PeerSpec { addr, shard }], "0-7");
    let tiered = Arc::new(TieredStore::from_config(store.clone(), &rc, &dir).unwrap());
    let (resid, _copier) = mk_residency(tiered, 1e9);

    // remote half (flat 13): crosses the wire, byte-identical on arrival
    let remote_key = ExpertKey::new(3, 1);
    let (_u, w) = resid.acquire(3, vec![(remote_key, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&w);
    let (tier, bytes) = resid.resident_record(remote_key, Pool::Hi).expect("resident");
    assert_eq!(tier, Precision::F32);
    assert_eq!(&bytes[..], store.record(remote_key, Precision::F32), "remote bytes diverged");
    let st = resid.loader_stats();
    assert_eq!(st.remote_fetches, 1);
    assert_eq!(st.remote_bytes, cfg.bytes_for(Precision::F32) as u64);
    assert_eq!(st.peer_failovers, 0);
    resid.release(remote_key, Pool::Hi);

    // local half: a DRAM borrow, no extra network traffic
    let local_key = ExpertKey::new(0, 2);
    let (_u, w) = resid.acquire(0, vec![(local_key, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&w);
    let (_, bytes) = resid.resident_record(local_key, Pool::Hi).expect("resident");
    assert_eq!(&bytes[..], store.record(local_key, Precision::F32));
    assert_eq!(resid.loader_stats().remote_fetches, 1, "a local fetch crossed the network");
    resid.release(local_key, Pool::Hi);
}

// ---------------------------------------------------------------------
// (b) a silent peer is time-bounded: timeouts + bounded retry + failover
// ---------------------------------------------------------------------

#[test]
fn silent_peer_times_out_and_fails_over_within_budget() {
    let (_cfg, dir, store) = synth_store("silent");
    // a peer that accepts the connection and then never writes a byte —
    // the exact shape that used to hang clients forever
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let mut held = Vec::new();
        while let Ok((s, _)) = listener.accept() {
            held.push(s);
        }
    });
    let mut rc =
        remote_cfg(vec![PeerSpec { addr, shard: ShardSpec::parse("8-15").unwrap() }], "0-7");
    rc.retry = RetryPolicy {
        io_timeout: Duration::from_millis(150),
        attempts: 2,
        backoff: Duration::from_millis(10),
        ..RetryPolicy::fast()
    };
    let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();
    let key = ExpertKey::new(2, 0); // flat 8: peer-owned
    let t0 = Instant::now();
    let rec = tiered.fetch(key, Precision::Q8, ONDEMAND_WEIGHT);
    let elapsed = t0.elapsed();
    assert_eq!(rec.as_slice(), store.record(key, Precision::Q8), "failover bytes diverged");
    // 2 attempts x 150 ms read timeout + 10 ms backoff, with slack
    assert!(elapsed < Duration::from_secs(3), "silent peer not time-bounded: {elapsed:?}");
    let c = tiered.counters();
    assert_eq!(c.peer_failovers, 1);
    assert_eq!(c.disk_fetches, 1);
    assert_eq!(c.remote_fetches, 0);
}

// ---------------------------------------------------------------------
// (c) dead peer: circuit breaker + disk tier, degraded but never wedged
// ---------------------------------------------------------------------

#[test]
fn dead_peer_circuit_breaks_and_serves_every_record_from_disk() {
    let (cfg, dir, store) = synth_store("deadpeer");
    // bind-then-drop guarantees a port with no listener
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let rc =
        remote_cfg(vec![PeerSpec { addr: dead, shard: ShardSpec::parse("8-15").unwrap() }], "0-7");
    let tiered = TieredStore::from_config(store.clone(), &rc, &dir).unwrap();

    // the first miss pays the bounded retries and breaks the circuit
    let first = ExpertKey::new(2, 0);
    assert_eq!(
        tiered.fetch(first, Precision::F32, ONDEMAND_WEIGHT).as_slice(),
        store.record(first, Precision::F32),
    );
    // every further peer-owned record: straight to disk, fast, correct
    let t0 = Instant::now();
    for flat in 9..16u32 {
        let key = ExpertKey::new(flat / cfg.n_experts, flat % cfg.n_experts);
        assert_eq!(
            tiered.fetch(key, Precision::F32, ONDEMAND_WEIGHT).as_slice(),
            store.record(key, Precision::F32),
            "disk failover bytes diverged at flat {flat}"
        );
    }
    assert!(t0.elapsed() < Duration::from_secs(2), "circuit breaker did not skip the dead peer");
    let c = tiered.counters();
    assert_eq!(c.peer_failovers, 8, "every degraded fetch must be counted");
    assert_eq!(c.disk_fetches, 8);
    assert_eq!(c.remote_fetches, 0);
}

// ---------------------------------------------------------------------
// (d) cross-tier prefetch: peer -> local DRAM ahead of demand
// ---------------------------------------------------------------------

#[test]
fn cross_tier_prefetch_stages_peer_records_ahead_of_demand() {
    let (cfg, dir, store) = synth_store("stage");
    let (addr, shard) = top_half_server(store.clone());
    let rc = remote_cfg(vec![PeerSpec { addr, shard }], "0-7");
    let tiered = Arc::new(TieredStore::from_config(store.clone(), &rc, &dir).unwrap());

    // direct staging: the stager thread pulls the record at prefetch
    // weight; the demand fetch then hits the staged side-cache
    let key = ExpertKey::new(2, 1); // flat 9
    assert_eq!(tiered.tier_of(key, Precision::F32), FetchTier::Peer);
    tiered.stage_async(key, Precision::F32);
    let t0 = Instant::now();
    while !tiered.is_staged(key, Precision::F32) {
        assert!(t0.elapsed() < Duration::from_secs(10), "stager never landed the record");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(tiered.tier_of(key, Precision::F32), FetchTier::Staged);
    let rec = tiered.fetch(key, Precision::F32, ONDEMAND_WEIGHT);
    assert_eq!(rec.as_slice(), store.record(key, Precision::F32));
    let c = tiered.counters();
    assert_eq!(c.staged_hits, 1, "the demand fetch must hit the staged copy");
    assert_eq!(c.remote_fetches, 1, "the stager's pull is the only network fetch");

    // the predictor drives the same staging across the whole horizon:
    // strongly gate (3, 2) [flat 14] in the stacked probs for layer 3
    let (mut resid, _copier) = mk_residency(tiered.clone(), 1e9);
    let horizon_key = ExpertKey::new(3, 2);
    let mut probs = vec![0.0f32; cfg.n_experts as usize];
    probs[2] = 1.0;
    let stacked = vec![vec![0.25f32; cfg.n_experts as usize], probs];
    resid.plan_prefetch(0, 2, cfg.n_layers, &stacked);
    let t0 = Instant::now();
    while !tiered.is_staged(horizon_key, Precision::F32) {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "plan_prefetch never staged the peer-resident candidate"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------
// (e) the network is a second link class, independent of PCIe
// ---------------------------------------------------------------------

#[test]
fn network_link_class_is_independent_of_pcie() {
    let (cfg, dir, store) = synth_store("linkclass");
    let (addr, shard) = top_half_server(store.clone());
    let rc = remote_cfg(vec![PeerSpec { addr, shard }], "0-7");
    let tiered_remote = Arc::new(TieredStore::from_config(store.clone(), &rc, &dir).unwrap());
    let tiered_local = Arc::new(TieredStore::local_only(store.clone()));

    let (resid_remote, pcie_remote) = mk_residency(tiered_remote.clone(), 1e8);
    let (resid_local, pcie_local) = mk_residency(tiered_local.clone(), 1e8);
    let key = ExpertKey::new(3, 3); // flat 15: peer-owned in the remote rig
    for r in [&resid_remote, &resid_local] {
        let (_u, w) = r.acquire(3, vec![(key, Class::Hi, vec![1.0], 0.0)], None);
        r.wait(&w);
        r.release(key, Pool::Hi);
    }
    // both rigs moved exactly one f32 record across PCIe — the network
    // leg never shows up as PCIe traffic
    let rec = cfg.bytes_for(Precision::F32) as u64;
    assert_eq!(pcie_remote.bytes_moved(), pcie_local.bytes_moved());
    assert_eq!(pcie_remote.bytes_moved(), rec);
    // and the peer leg is charged on the network link class alone
    let net = tiered_remote.net_copier().expect("remote rig has a network link");
    assert_eq!(net.bytes_moved(), rec);
    assert_eq!(net.transfers(), 1);
    assert!(tiered_local.net_copier().is_none(), "local-only rig must have no network link");
}

// ---------------------------------------------------------------------
// (f) multi-process acceptance: real shard servers, bit-identical
//     logits, and mid-run peer death
// ---------------------------------------------------------------------

const MP_STEPS: usize = 16;

/// Kills the children on scope exit (panic included) so a failing test
/// never leaks shard-server processes.
struct KillOnDrop(Vec<Child>);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Spawn `hobbit shard-serve` on an OS-assigned port and parse the bound
/// address from its banner line.
fn spawn_shard_server(dir: &Path, shard: &str) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_hobbit"))
        .args([
            "shard-serve",
            "--weights",
            dir.to_str().unwrap(),
            "--shard",
            shard,
            "--addr",
            "127.0.0.1:0",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn shard-serve");
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().expect("child stdout"))
        .read_line(&mut line)
        .expect("read shard-serve banner");
    let addr = line
        .trim()
        .strip_prefix("shard-serve listening on ")
        .unwrap_or_else(|| panic!("unexpected shard-serve banner: {line:?}"))
        .to_string();
    (child, addr)
}

/// Reference engine over the synthesized model. Pinned precision + a
/// cache smaller than the 12-expert working set, so demand fetches keep
/// flowing all run long and every run is bit-deterministic.
fn reference_engine(dir: &Path, remote: Option<RemoteConfig>) -> Engine {
    let cfg = tiny_model_config("remote-mp");
    let hw = HardwareConfig {
        name: "remote-mp".into(),
        load_bw: 64e9,
        load_latency: 0.0,
        hi_cache_experts: 4,
        lo_cache_experts: 4,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    let policy = PolicyConfig {
        dynamic_loading: false,
        pin_precision: Some(Precision::F32),
        prefetch_depth: 0,
        ..PolicyConfig::default()
    };
    let mut opts = EngineOptions::new(hw, policy);
    opts.remote = remote;
    Engine::new_reference(dir, cfg, opts).expect("reference engine")
}

fn mp_token(i: usize) -> u32 {
    (65 + (i * 7) % 50) as u32
}

fn generate_logits(eng: &mut Engine) -> Vec<Vec<f32>> {
    let mut kv = eng.new_sequence();
    let mut out = Vec::with_capacity(MP_STEPS + 1);
    out.push(eng.prefill(&mut kv, &[BOS, 72, 101]).expect("prefill"));
    for i in 0..MP_STEPS {
        out.push(eng.decode_step(&mut kv, mp_token(i)).expect("decode"));
    }
    out
}

#[test]
fn multi_process_shard_servers_match_local_run_and_survive_peer_death() {
    let dir = std::env::temp_dir().join("hobbit_remote_tier_mp");
    let cfg = tiny_model_config("remote-mp");
    write_synth_model(&dir, &cfg, 0xC0FFEE).expect("synth model");
    write_store_manifest(&dir, &cfg).expect("manifest");

    // single-node baseline: every expert from the local store
    let mut local = reference_engine(&dir, None);
    let want = generate_logits(&mut local);

    // two real shard-server processes partitioning the 12-expert space
    let (c1, a1) = spawn_shard_server(&dir, "0-5");
    let (c2, a2) = spawn_shard_server(&dir, "6-11");
    let mut guard = KillOnDrop(vec![c1, c2]);
    let peers = || {
        vec![
            PeerSpec { addr: a1.clone(), shard: ShardSpec::parse("0-5").unwrap() },
            PeerSpec { addr: a2.clone(), shard: ShardSpec::parse("6-11").unwrap() },
        ]
    };

    // empty local shard: every expert crosses a process boundary — the
    // generated logits must be bit-identical to the single-node run
    let mut remote = reference_engine(&dir, Some(remote_cfg(peers(), "none")));
    let got = generate_logits(&mut remote);
    assert_eq!(want, got, "remote-tier logits diverged from the single-node run");
    let st = remote.residency.loader_stats();
    assert!(st.remote_fetches > 0, "nothing was fetched over the network");
    assert_eq!(st.peer_failovers, 0, "both peers were live; nothing may degrade");

    // kill one peer mid-generation: the run completes via disk-tier
    // failover, still bit-identical, and the degradation is counted
    let mut rc = remote_cfg(peers(), "none");
    rc.staged_capacity = 1; // keep the side-cache from masking the death
    let mut failover = reference_engine(&dir, Some(rc));
    let mut kv = failover.new_sequence();
    let mut got = Vec::with_capacity(MP_STEPS + 1);
    got.push(failover.prefill(&mut kv, &[BOS, 72, 101]).expect("prefill"));
    for i in 0..MP_STEPS {
        if i == MP_STEPS / 2 {
            let dead = &mut guard.0[1];
            let _ = dead.kill();
            let _ = dead.wait();
        }
        got.push(failover.decode_step(&mut kv, mp_token(i)).expect("decode after peer death"));
    }
    assert_eq!(want, got, "peer death changed the generated logits");
    let st = failover.residency.loader_stats();
    assert!(st.peer_failovers > 0, "the dead peer's records never failed over");
}
