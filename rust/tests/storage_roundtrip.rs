//! Cross-language storage parity: the packed expert records written by
//! python/compile/gen_weights.py must be byte-identical to what rust's
//! quantizer produces from the f32 records, at every precision — the
//! layout contract both sides implement (python/tests/test_weights.py
//! checks the same from the python end).

use std::path::PathBuf;

use hobbit::config::ModelConfig;
use hobbit::model::synth::{tiny_store_config, write_store_manifest, write_synth_expert_store};
use hobbit::model::{verify_weights_dir, ExpertStore, IntegrityTable};
use hobbit::quant;
use hobbit::runtime::Manifest;
use hobbit::util::json::Json;
use hobbit::util::proptest_mini::{self, Config};
use hobbit::{ExpertKey, Precision};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load(model: &str) -> Option<(ModelConfig, ExpertStore)> {
    let mdir = artifacts_root().join(model);
    let wdir = artifacts_root().join("weights").join(model);
    if !mdir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest =
        Manifest::parse(&std::fs::read_to_string(mdir.join("manifest.json")).unwrap()).unwrap();
    let cfg = ModelConfig::from_manifest(&manifest.model_json()).unwrap();
    let store = ExpertStore::load(&wdir, &cfg).unwrap();
    Some((cfg, store))
}

fn f32_mats(cfg: &ModelConfig, rec: &[u8]) -> Vec<(usize, usize, Vec<f32>)> {
    let (d, ff) = (cfg.d_model, cfg.d_ff);
    let floats: Vec<f32> = rec
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let n1 = d * ff;
    vec![
        (d, ff, floats[..n1].to_vec()),
        (d, ff, floats[n1..2 * n1].to_vec()),
        (ff, d, floats[2 * n1..].to_vec()),
    ]
}

#[test]
fn quantized_records_match_rust_quantizer() {
    let Some((cfg, store)) = load("mixtral-tiny") else { return };
    let g = cfg.quant_group;
    for key in [ExpertKey::new(0, 0), ExpertKey::new(3, 5), ExpertKey::new(7, 7)] {
        let f32_rec = store.record(key, Precision::F32);
        let mats = f32_mats(&cfg, f32_rec);
        for p in [Precision::Q8, Precision::Q4, Precision::Q2] {
            let qrec = store.record(key, p);
            let mut off = 0usize;
            for (rows, cols, w) in &mats {
                let (packed, scales) = quant::quantize(w, *rows, *cols, g, p);
                assert_eq!(
                    &qrec[off..off + packed.len()],
                    &packed[..],
                    "{key:?} {p:?}: packed bytes differ"
                );
                off += packed.len();
                let scale_bytes: Vec<u8> =
                    scales.iter().flat_map(|s| s.to_le_bytes()).collect();
                assert_eq!(
                    &qrec[off..off + scale_bytes.len()],
                    &scale_bytes[..],
                    "{key:?} {p:?}: scales differ"
                );
                off += scale_bytes.len();
            }
            assert_eq!(off, qrec.len(), "{p:?} record fully consumed");
        }
    }
}

#[test]
fn record_sizes_match_manifest() {
    let Some((cfg, store)) = load("mixtral-tiny") else { return };
    for p in Precision::ALL {
        assert_eq!(store.record_bytes(p), cfg.bytes_for(p), "{p:?}");
    }
}

#[test]
fn dequantized_records_approximate_f32() {
    let Some((cfg, store)) = load("mixtral-tiny") else { return };
    let g = cfg.quant_group;
    let key = ExpertKey::new(1, 2);
    let mats = f32_mats(&cfg, store.record(key, Precision::F32));
    let mut prev_err = 0.0f64;
    for p in [Precision::Q8, Precision::Q4, Precision::Q2] {
        let qrec = store.record(key, p);
        let mut off = 0usize;
        let mut total_err = 0.0f64;
        let mut count = 0usize;
        for (rows, cols, w) in &mats {
            let nb = quant::packed_bytes(*rows, *cols, p);
            let packed = &qrec[off..off + nb];
            off += nb;
            let ns = quant::scale_count(*rows, *cols, g);
            let scales: Vec<f32> = qrec[off..off + ns * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            off += ns * 4;
            let wd = quant::dequantize(packed, &scales, *rows, *cols, g, p);
            for (a, b) in wd.iter().zip(w) {
                total_err += ((a - b).abs()) as f64;
                count += 1;
            }
        }
        let mean = total_err / count as f64;
        assert!(mean > prev_err, "{p:?} must be coarser than the previous format");
        assert!(mean < 0.05, "{p:?} mean err {mean} too large for 0.06-scale weights");
        prev_err = mean;
    }
}

// ---------------------------------------------------------------------
// Record integrity: manifest checksums round-trip through the store
// writer and loader, and any on-disk damage is a typed error (these are
// artifact-free — they run on the synthetic store).
// ---------------------------------------------------------------------

fn synth_dir(name: &str) -> (ModelConfig, PathBuf) {
    let cfg = tiny_store_config(name);
    let dir = std::env::temp_dir().join(format!("hobbit_storage_{name}"));
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    write_store_manifest(&dir, &cfg).expect("manifest");
    (cfg, dir)
}

#[test]
fn synth_store_checksums_roundtrip_through_writer_and_loader() {
    let (cfg, dir) = synth_dir("cksum_roundtrip");
    // load verifies every record against the manifest's integrity table
    let _store = ExpertStore::load(&dir, &cfg).expect("clean store must verify");
    let report = verify_weights_dir(&dir).expect("verify scan");
    assert!(report.all_ok(), "clean store must pass the scan: {report:?}");
    let n = (cfg.n_layers * cfg.n_experts) as usize * Precision::ALL.len();
    assert_eq!(report.records.len(), n, "one verdict per (expert, tier)");
    assert_eq!(report.passed, n);
}

#[test]
fn on_disk_bit_flip_is_a_typed_load_error() {
    let (cfg, dir) = synth_dir("cksum_flip");
    let path = dir.join("experts_q8.bin");
    let mut bytes = std::fs::read(&path).unwrap();
    let rb = cfg.bytes_for(Precision::Q8);
    bytes[rb * 3 + 11] ^= 0x04; // one bit of one q8 record
    std::fs::write(&path, &bytes).unwrap();

    let err = ExpertStore::load(&dir, &cfg).expect_err("corrupt store must not load");
    assert!(
        format!("{err:#}").contains("fails its manifest checksum"),
        "want the typed integrity error, got: {err:#}"
    );
    let report = verify_weights_dir(&dir).expect("scan still runs");
    assert_eq!(report.failed, 1, "exactly one record was flipped");
}

#[test]
fn truncated_record_file_is_a_typed_load_error() {
    let (cfg, dir) = synth_dir("cksum_trunc");
    let path = dir.join("experts_f32.bin");
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(ExpertStore::load(&dir, &cfg).is_err(), "truncated store must not load");
}

/// Fuzz the manifest parsing stack: truncations, junk bytes, and bit
/// flips over a valid manifest must produce `Err`, never a panic.
#[test]
fn mutated_manifests_never_panic_the_parsers() {
    let (_cfg, dir) = synth_dir("cksum_fuzz");
    let valid = std::fs::read(dir.join("manifest.json")).unwrap();
    proptest_mini::check_cfg(
        "mutated manifests parse to Ok or Err",
        Config { cases: 128, ..Config::default() },
        |rng| {
            let mut bytes = valid.clone();
            match rng.below(3) {
                0 => bytes.truncate(rng.below(bytes.len() + 1)),
                1 => {
                    for _ in 0..1 + rng.below(8) {
                        let i = rng.below(bytes.len());
                        bytes[i] = (rng.next_u64() & 0xff) as u8;
                    }
                }
                _ => {
                    let i = rng.below(bytes.len());
                    let junk = b"\x00{]\"integrity\":";
                    let mut out = bytes[..i].to_vec();
                    out.extend_from_slice(junk);
                    out.extend_from_slice(&bytes[i..]);
                    bytes = out;
                }
            }
            let text = String::from_utf8_lossy(&bytes).into_owned();
            // every layer of the stack: Ok or Err, never a panic
            let _ = Manifest::parse(&text);
            if let Ok(j) = Json::parse(&text) {
                let _ = ModelConfig::from_manifest(&j);
                if let Some(sec) = j.get("integrity") {
                    let _ = IntegrityTable::from_json(sec);
                }
            }
            Ok(())
        },
    );
}
