//! Residency-facade integration: the cross-sequence shared wait-set
//! (one load task per shared miss, both sequences resume), per-sequence
//! prefetch-generation scoping (one sequence's token advance must not
//! invalidate another's queued prefetch), on-demand promotion of queued
//! prefetches, ticket wakeups, RAII session retirement, and the batched
//! scheduler's merged acquire (exactly one load per unique cache-miss
//! expert; dedup accounting covers every in-batch duplicate).
//!
//! These tests synthesize a tiny expert store on disk, so they run — and
//! gate CI — without the AOT artifacts the engine tests need.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::ModelConfig;
use hobbit::loader::scorer::Class;
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::ExpertStore;
use hobbit::predictor::Predictor;
use hobbit::prop_assert;
use hobbit::residency::{ExpertResidency, MergedUse};
use hobbit::util::proptest_mini::{check_cfg, Config};
use hobbit::{ExpertKey, Precision};

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "residency-test".into(),
        n_layers: 4,
        d_model: 8,
        d_ff: 16,
        n_experts: 4,
        top_k: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab: 64,
        max_seq: 32,
        quant_group: 8,
        // synthetic on-wire record sizes (only consistency matters here)
        expert_bytes: [4096, 1024, 512, 256],
    }
}

/// Write a synthetic expert store (every expert at every precision) so the
/// loader has real bytes to move without the AOT compile step.
fn synth_store(cfg: &ModelConfig, dir: &Path) -> Arc<ExpertStore> {
    std::fs::create_dir_all(dir).unwrap();
    for p in Precision::ALL {
        let n = cfg.bytes_for(p) * cfg.total_experts();
        let bytes: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        std::fs::write(dir.join(format!("experts_{}.bin", p.name())), bytes).unwrap();
    }
    Arc::new(ExpertStore::load(dir, cfg).unwrap())
}

/// Residency facade over a synthetic store; `bw` throttles the link so
/// transfers stay observable mid-flight.
fn mk_residency(
    cfg: &ModelConfig,
    hi_cap: usize,
    lo_cap: usize,
    bw: f64,
    name: &str,
) -> (ExpertResidency, Arc<ThrottledCopier>) {
    let dir = std::env::temp_dir().join(format!("hobbit_residency_{name}"));
    let store = synth_store(cfg, &dir);
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        hi_cap,
        cfg.bytes_for(Precision::F32),
        lo_cap,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: bw, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    let resid = ExpertResidency::new(
        store,
        cache,
        copier.clone(),
        predictor,
        Precision::F32,
        Precision::Q8,
    );
    (resid, copier)
}

/// Gate distribution sharply peaked on `hot`: rank-0 is Hi, rank-1 scores
/// ~0.98 > T2 and is skipped, so each plan submits exactly one prefetch.
fn hot_probs(hot: usize, e: usize) -> Vec<f32> {
    let mut p = vec![0.02f32; e];
    p[hot] = 0.9;
    let s: f32 = p.iter().sum();
    p.iter().map(|x| x / s).collect()
}

fn drain(resid: &ExpertResidency) {
    while !resid.is_idle() {
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn shared_miss_produces_single_load_and_both_sequences_resume() {
    let cfg = tiny_cfg();
    // ~200ms per f32 expert: the transfer is still in flight when the
    // second sequence misses on it
    let (resid, copier) = mk_residency(&cfg, 4, 4, 2e4, "sharedmiss");
    let sa = resid.begin_session();
    let sb = resid.begin_session();
    assert_eq!(resid.live_sequences(), 2);

    let key = ExpertKey::new(0, 1);
    let (uses_a, waits_a) = resid.acquire(0, vec![(key, Class::Hi, vec![1.0], 0.0)], Some(sa.id()));
    assert_eq!(uses_a.len(), 1);
    assert_eq!(waits_a.len(), 1, "first miss must submit a load");
    let (uses_b, waits_b) = resid.acquire(0, vec![(key, Class::Hi, vec![1.0], 0.0)], Some(sb.id()));
    assert_eq!(uses_b.len(), 1);
    assert_eq!(
        waits_b.len(),
        1,
        "second sequence must subscribe to the in-flight load, not bounce off"
    );

    // both barriers resolve off the same transfer
    resid.wait(&waits_a);
    resid.wait(&waits_b);
    assert!(waits_a.all_ready() && waits_b.all_ready());
    assert_eq!(copier.transfers(), 1, "a shared miss must move bytes exactly once");
    let st = resid.loader_stats();
    assert_eq!(st.dedup_total, 2);
    assert_eq!(st.dedup_hits, 1);

    // both sequences execute from the shared copy and release their pins
    assert!(resid.buffer(key, Pool::Hi).is_some());
    resid.note_use(key, Pool::Hi, Some(sa.id()));
    resid.release(key, Pool::Hi);
    resid.note_use(key, Pool::Hi, Some(sb.id()));
    resid.release(key, Pool::Hi);

    // RAII retirement: dropping the sessions releases their records
    drop(sa);
    drop(sb);
    assert_eq!(resid.live_sequences(), 0);
}

#[test]
fn token_advance_does_not_invalidate_other_sequences_prefetch() {
    let cfg = tiny_cfg();
    let (mut resid, copier) = mk_residency(&cfg, 8, 8, 2e4, "genscope");
    let sa = resid.begin_session();
    let sb = resid.begin_session();

    // occupy the link so both prefetches stay *queued*
    let blocker = ExpertKey::new(0, 3);
    let (_u, od_waits) =
        resid.acquire(0, vec![(blocker, Class::Hi, vec![1.0], 0.0)], Some(sa.id()));
    assert_eq!(od_waits.len(), 1);

    // A plans a prefetch for layer 1 expert 0; B for layer 2 expert 2
    let e = cfg.n_experts as usize;
    resid.plan_prefetch(sa.id(), 0, cfg.n_layers, &[hot_probs(3, e), hot_probs(0, e)]);
    resid.plan_prefetch(sb.id(), 1, cfg.n_layers, &[hot_probs(3, e), hot_probs(2, e)]);

    // A's next token arrives: bumps ONLY A's generation (a length-1 stack
    // plans nothing; the bump still invalidates A's queued prefetches)
    resid.plan_prefetch(sa.id(), 1, cfg.n_layers, &[hot_probs(3, e)]);

    resid.wait(&od_waits);
    drain(&resid);

    // B's queued prefetch survived A's token advance...
    assert!(
        resid.buffer(ExpertKey::new(2, 2), Pool::Hi).is_some(),
        "sequence B's queued prefetch was invalidated by sequence A's token advance"
    );
    // ...while A's own stale prefetch was dropped without moving bytes
    assert!(resid.buffer(ExpertKey::new(1, 0), Pool::Hi).is_none());
    assert_eq!(copier.transfers(), 2, "blocker + B's prefetch only");
    drop(sa);
    drop(sb);
}

#[test]
fn replanned_prefetch_joins_its_queued_task_and_survives_own_bump() {
    // regression: token t queues a prefetch for E; token t+1 bumps the
    // scope's generation and re-predicts E. The new request joins the
    // queued task — which must be re-stamped fresh, not left to die as
    // stale (that would silently lose every correlated prefetch while the
    // link is busy, exactly when prefetching matters).
    let cfg = tiny_cfg();
    let (mut resid, copier) = mk_residency(&cfg, 8, 8, 2e4, "replan");
    let sa = resid.begin_session();

    let blocker = ExpertKey::new(0, 3);
    let (_u, od_waits) =
        resid.acquire(0, vec![(blocker, Class::Hi, vec![1.0], 0.0)], Some(sa.id()));
    let e = cfg.n_experts as usize;
    // token t: prefetch (1, 0) queued behind the blocker
    resid.plan_prefetch(sa.id(), 0, cfg.n_layers, &[hot_probs(3, e), hot_probs(0, e)]);
    // token t+1: generation bump + the same prediction again
    resid.plan_prefetch(sa.id(), 0, cfg.n_layers, &[hot_probs(3, e), hot_probs(0, e)]);

    resid.wait(&od_waits);
    drain(&resid);
    assert!(
        resid.buffer(ExpertKey::new(1, 0), Pool::Hi).is_some(),
        "re-planned prefetch was dropped as stale instead of re-stamped"
    );
    assert_eq!(copier.transfers(), 2, "blocker + exactly one prefetch transfer");
    drop(sa);
}

#[test]
fn ondemand_join_promotes_queued_prefetch_to_priority_lane() {
    let cfg = tiny_cfg();
    let (mut resid, copier) = mk_residency(&cfg, 8, 8, 2e4, "promote");
    let sa = resid.begin_session();
    let sb = resid.begin_session();

    // occupy the link, then queue B's prefetch for (2, 2)
    let blocker = ExpertKey::new(0, 3);
    let (_u, od_waits) =
        resid.acquire(0, vec![(blocker, Class::Hi, vec![1.0], 0.0)], Some(sa.id()));
    let e = cfg.n_experts as usize;
    resid.plan_prefetch(sb.id(), 1, cfg.n_layers, &[hot_probs(3, e), hot_probs(2, e)]);

    // A now *needs* (2, 2): it joins B's queued prefetch, which is
    // promoted into the on-demand lane (paper: on-demand jumps ahead of
    // queued prefetches; started transfers are never preempted)
    let need = ExpertKey::new(2, 2);
    let (_ua, waits_a) = resid.acquire(2, vec![(need, Class::Hi, vec![1.0], 0.0)], Some(sa.id()));
    assert_eq!(waits_a.len(), 1);
    resid.wait(&od_waits);
    resid.wait(&waits_a);
    drain(&resid);

    assert!(resid.buffer(need, Pool::Hi).is_some());
    assert_eq!(copier.transfers(), 2, "join must not duplicate the transfer");
    let st = resid.loader_stats();
    assert_eq!(st.dedup_hits, 1, "the join is a dedup hit");
    // the promoted task executed as on-demand (priority lane)
    assert_eq!(st.ondemand_loads.iter().sum::<u64>(), 2);
    assert_eq!(st.prefetch_loads.iter().sum::<u64>(), 0);
    resid.release(need, Pool::Hi);
    resid.release(blocker, Pool::Hi);
    drop(sa);
    drop(sb);
}

#[test]
fn merged_acquire_issues_single_load_per_unique_miss() {
    // deterministic two-row union on a cold cache: rows share (0,1) in Hi,
    // row 1 additionally wants (0,2) in Lo -> exactly 2 transfers
    let cfg = tiny_cfg();
    let (resid, copier) = mk_residency(&cfg, 8, 8, 1e9, "mergebasic");
    let shared = ExpertKey::new(0, 1);
    let solo = ExpertKey::new(0, 2);
    let demands = vec![
        MergedUse {
            key: shared,
            class: Class::Hi,
            gatew: vec![0.6, 0.7],
            rows: vec![0, 1],
            seqs: vec![None, None],
            score: 0.0,
        },
        MergedUse {
            key: solo,
            class: Class::Lo,
            gatew: vec![0.0, 0.3],
            rows: vec![1],
            seqs: vec![None],
            score: 0.0,
        },
    ];
    let (uses, waits) = resid.acquire_merged(0, demands, &[None, None]);
    assert_eq!(uses.len(), 2);
    assert_eq!(waits.len(), 2, "one ticket per unique cache-miss (expert, pool)");
    resid.wait(&waits);
    drain(&resid);
    assert_eq!(copier.transfers(), 2, "in-batch duplicate must not move extra bytes");
    let st = resid.loader_stats();
    assert_eq!(st.merged_acquires, 1);
    assert_eq!(st.merged_unique, 2);
    assert_eq!(st.merged_demands, 3);
    // 3 on-demand demands reached the wait-set; the duplicate is a dedup hit
    assert_eq!(st.dedup_total, 3);
    assert_eq!(st.dedup_hits, 1);
    // pins are per demanding row: shared carries 2, solo carries 1
    resid.release(shared, Pool::Hi);
    resid.release(shared, Pool::Hi);
    resid.release(solo, Pool::Lo);
    let cache = resid.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count() + c.lo.pinned_count(), 0);
}

#[test]
fn prop_merged_acquire_dedup_accounts_for_every_duplicate() {
    // For random routing unions across a batch: exactly one load task per
    // unique cache-miss (expert, pool), and dedup_hits/dedup_total account
    // for every in-batch duplicate.
    check_cfg(
        "merged acquire dedup accounting",
        Config { cases: 16, seed: 0xB47C_4ED },
        |rng| {
            let cfg = tiny_cfg();
            let name = format!("mergeprop{}", rng.below(1 << 30));
            let (resid, copier) = mk_residency(&cfg, 16, 16, 1e9, &name);
            let batch = 2 + rng.below(7); // 2..=8 rows
            let e = cfg.n_experts as usize;
            // rows route top-k-style picks over random layers/experts
            let mut union: BTreeMap<(u32, u32, bool), (Vec<usize>, Vec<f32>)> =
                BTreeMap::new();
            for row in 0..batch {
                let layer = rng.below(cfg.n_layers as usize) as u32;
                for _ in 0..cfg.top_k {
                    let expert = rng.below(e) as u32;
                    // precision class by expert parity: a key never appears
                    // in both pools, so the Lo-request-upgraded-by-Hi-copy
                    // path cannot race the loader thread mid-acquire (the
                    // counts below stay exact)
                    let hi = expert % 2 == 0;
                    let ent = union
                        .entry((layer, expert, hi))
                        .or_insert_with(|| (Vec::new(), vec![0.0; batch]));
                    if !ent.0.contains(&row) {
                        ent.0.push(row);
                        ent.1[row] = 0.5;
                    }
                }
            }
            let demands: Vec<MergedUse> = union
                .into_iter()
                .map(|((layer, expert, hi), (rows, gatew))| MergedUse {
                    key: ExpertKey::new(layer, expert),
                    class: if hi { Class::Hi } else { Class::Lo },
                    gatew,
                    seqs: vec![None; rows.len()],
                    rows,
                    score: 0.0,
                })
                .collect();
            let unique = demands.len() as u64;
            let total: u64 = demands.iter().map(|d| d.rows.len() as u64).sum();
            let seqs: Vec<Option<u64>> = vec![None; batch];
            let releases: Vec<(ExpertKey, Class, usize)> =
                demands.iter().map(|d| (d.key, d.class, d.rows.len())).collect();
            let (uses, waits) = resid.acquire_merged(0, demands, &seqs);
            prop_assert!(uses.len() as u64 == unique);
            // cold cache: every unique (expert, pool) is a miss -> one task
            prop_assert!(
                waits.len() as u64 == unique,
                "{} tickets for {unique} unique misses",
                waits.len()
            );
            resid.wait(&waits);
            drain(&resid);
            prop_assert!(
                copier.transfers() as u64 == unique,
                "{} transfers for {unique} unique misses",
                copier.transfers()
            );
            let st = resid.loader_stats();
            prop_assert!(st.merged_unique == unique);
            prop_assert!(st.merged_demands == total);
            // every demand reached the wait-set; every duplicate is a join
            prop_assert!(
                st.dedup_total == total,
                "dedup_total {} != demands {total}",
                st.dedup_total
            );
            prop_assert!(
                st.dedup_hits == total - unique,
                "dedup_hits {} != duplicates {}",
                st.dedup_hits,
                total - unique
            );
            // release one pin per demanding row: the ledger balances
            for (key, class, m) in releases {
                let pool = if class == Class::Hi { Pool::Hi } else { Pool::Lo };
                for _ in 0..m {
                    resid.release(key, pool);
                }
            }
            let cache = resid.cache_handle();
            let c = cache.lock().unwrap();
            prop_assert!(
                c.hi.pinned_count() + c.lo.pinned_count() == 0,
                "leaked pins after balanced release"
            );
            Ok(())
        },
    );
}

#[test]
fn ticket_wakeups_fire_on_completion_and_refuse_after() {
    let cfg = tiny_cfg();
    let (resid, _copier) = mk_residency(&cfg, 4, 4, 2e4, "wakeup");
    let key = ExpertKey::new(3, 0);
    let (_u, waits) = resid.acquire(3, vec![(key, Class::Hi, vec![1.0], 0.0)], None);
    assert_eq!(waits.len(), 1);
    let ticket = waits.tickets()[0].clone();
    assert!(!ticket.is_ready(), "200ms transfer reported ready instantly");

    let (tx, rx) = std::sync::mpsc::channel();
    assert!(ticket.on_ready(move || {
        let _ = tx.send(());
    }), "in-flight ticket must accept a wakeup");
    rx.recv_timeout(Duration::from_secs(10)).expect("wakeup fired");
    assert!(ticket.is_ready());
    // a completed ticket refuses new wakeups so callers don't park on it
    assert!(!ticket.on_ready(|| {}));
    resid.release(key, Pool::Hi);
}
