//! Serving-path integration: coordinator FCFS semantics and the TCP
//! front-end, on the real engine (skips without artifacts).

use std::path::PathBuf;
use std::time::Duration;

use hobbit::config::{HardwareConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::{Engine, EngineOptions};
use hobbit::server::{client_request, Server};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn fast_hw() -> HardwareConfig {
    HardwareConfig {
        name: "test-fast".into(),
        load_bw: 16e9,
        load_latency: 0.0,
        hi_cache_experts: 24,
        lo_cache_experts: 24,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

fn mk_coord() -> Option<Coordinator> {
    if !artifacts_root().join("mixtral-tiny/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let opts = EngineOptions::new(fast_hw(), PolicyConfig::default());
    let engine = Engine::new(&artifacts_root(), "mixtral-tiny", opts).unwrap();
    Some(Coordinator::new(engine))
}

#[test]
fn coordinator_fcfs_drain() {
    let Some(mut coord) = mk_coord() else { return };
    coord.submit(Request::new(1, "first request", 4));
    coord.submit(Request::new(2, "second request", 4));
    assert_eq!(coord.pending(), 2);
    let results = coord.drain().unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].id, 1);
    assert_eq!(results[1].id, 2);
    for r in &results {
        assert!(r.tokens.len() <= 4);
        assert!(r.metrics.prefill_time > Duration::ZERO);
    }
    assert_eq!(coord.report.requests.len(), 2);
    assert!(coord.report.mean_decode_tps() > 0.0);
}

#[test]
fn generation_respects_budget_and_determinism() {
    let Some(mut coord) = mk_coord() else { return };
    // greedy decoding twice -> identical outputs
    let a = coord.generate(&Request::new(1, "determinism probe", 6)).unwrap();
    let b = coord.generate(&Request::new(2, "determinism probe", 6)).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy decode must be deterministic");
    assert!(a.tokens.len() <= 6);
}

#[test]
fn tcp_server_gen_and_stats() {
    let Some(mut coord) = mk_coord() else { return };
    let mut server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let addr2 = addr.clone();
    let client = std::thread::spawn(move || {
        // no probe connection: the listener is bound before this thread
        // starts, so connects queue in the accept backlog; a probe would
        // consume one of the server's max_conns slots.
        let r = client_request(&addr2, "GEN 4 0 hello world").unwrap();
        assert!(r.get("error").is_none(), "{r:?}");
        assert!(r.get("decode_tps").unwrap().as_f64().unwrap() > 0.0);
        let bad = client_request(&addr2, "NOPE").unwrap();
        assert!(bad.get("error").is_some());
        let stats = client_request(&addr2, "STATS").unwrap();
        assert!(stats.get("mean_decode_tps").is_some());
    });
    // 3 connections: GEN, NOPE, STATS (client_request opens one per call)
    server.serve(&mut coord, Some(3)).unwrap();
    client.join().unwrap();
    assert_eq!(coord.report.requests.len(), 1);
}
