//! Ragged grouped execution + hot-expert replication regression suite:
//! grouping and replication must NEVER change logits.
//!
//! Artifact-free (synthesized model, reference executor), like
//! `batched_decode.rs` — the loader, cache, predictor, residency facade,
//! and both schedulers are the real ones, and every equivalence below is
//! **bit-identical**, not tolerance-based.
//!
//! Coverage:
//! * engine-level: grouped decode of K rows runs *ragged* (no padding)
//!   and matches per-row sequential logits bitwise, K in {2, 4, 5, 8, 16};
//! * hot skew: identical rows collapse each layer step to one launch +
//!   one snapshot per unique expert (`grouped_launches` ==
//!   steps x layers x top_k), with `dequant_reuses` and the snapshot
//!   dedup counters accounting for every shared row;
//! * coordinator-level: `--max-batch K` grouped completions equal the
//!   FCFS batch-1 reference on a per-row engine under rr and sjf,
//!   including K = 16 (past the legacy padded ceiling), and the serving
//!   report carries `exec_mode: "grouped"`;
//! * replication: a hot-skewed run with `max_replicas > 0` creates
//!   replicas, serves reads from them, and stays bit-identical to the
//!   replication-off run; upgrade/quarantine invalidate replicas
//!   atomically; mid-step eviction and batch abort leak no pins with
//!   replication on.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use hobbit::cache::{CacheManager, CommitOutcome, Policy, Pool};
use hobbit::config::{HardwareConfig, IoConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request, SchedPolicy};
use hobbit::engine::{BatchItem, BatchProgress, DecodeProgress, Engine, EngineOptions, KvState};
use hobbit::loader::scorer::Class;
use hobbit::memory::{LinkModel, ThrottledCopier};
use hobbit::model::synth::{
    tiny_model_config, tiny_store_config, write_synth_expert_store, write_synth_model,
};
use hobbit::model::ExpertStore;
use hobbit::predictor::Predictor;
use hobbit::residency::ExpertResidency;
use hobbit::tokenizer::BOS;
use hobbit::util::checksum::fnv1a64;
use hobbit::{ExpertKey, Precision};

const SEED: u64 = 0x6E0;

fn synth_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hobbit_grouped_{name}"));
    let cfg = tiny_model_config(name);
    write_synth_model(&dir, &cfg, SEED).expect("synth model");
    dir
}

fn fast_hw() -> HardwareConfig {
    HardwareConfig {
        name: "grouped-fast".into(),
        load_bw: 1e9,
        load_latency: 0.0,
        hi_cache_experts: 12, // every expert of the tiny model fits
        lo_cache_experts: 12,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Offload-bound: small cache + a link slow enough (~3ms per f32 expert)
/// that merged acquires genuinely wait on the wire.
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "grouped-offload".into(),
        load_bw: 2e6,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Roomy cache: the whole working set fits with Free slots left over, so
/// hot-expert replicas have somewhere to live and nothing ever bypasses.
fn roomy_hw() -> HardwareConfig {
    HardwareConfig { hi_cache_experts: 16, lo_cache_experts: 12, ..fast_hw() }
}

/// Dynamic loading off + fetch precision pinned hi: logits depend only on
/// each row's own token history, so grouping, batching, replication, and
/// scheduling order must not change them.
fn quality_policy(prefetch_depth: usize) -> PolicyConfig {
    PolicyConfig {
        dynamic_loading: false,
        prefetch_depth,
        pin_precision: Some(hobbit::Precision::F32),
        ..PolicyConfig::default()
    }
}

fn mk_engine(
    name: &str,
    dir: &Path,
    hw: HardwareConfig,
    prefetch: usize,
    grouped: bool,
    max_replicas: usize,
) -> Engine {
    let cfg = tiny_model_config(name);
    let mut opts = EngineOptions::new(hw, quality_policy(prefetch));
    opts.grouped = grouped;
    opts.max_replicas = max_replicas;
    Engine::new_reference(dir, cfg, opts).expect("reference engine")
}

/// Deterministic per-row token streams (byte tokens, all < 256).
fn stream(row: usize, step: usize) -> u32 {
    (65 + ((row * 31 + step * 7) % 190)) as u32
}

fn prompt_tokens(row: usize) -> Vec<u32> {
    vec![BOS, (70 + row as u32) % 256]
}

/// Ground truth: each row decoded alone, batch-1, on a per-row engine.
fn sequential_logits(name: &str, dir: &Path, rows: usize, steps: usize) -> Vec<Vec<Vec<f32>>> {
    let mut eng = mk_engine(name, dir, fast_hw(), 2, false, 0);
    (0..rows)
        .map(|r| {
            let mut kv = eng.new_sequence();
            eng.prefill(&mut kv, &prompt_tokens(r)).expect("prefill");
            (0..steps)
                .map(|j| eng.decode_step(&mut kv, stream(r, j)).expect("decode"))
                .collect()
        })
        .collect()
}

fn poll_to_done(eng: &mut Engine, cur: &mut hobbit::engine::BatchCursor) -> Vec<hobbit::engine::BatchDone> {
    loop {
        match eng.decode_poll_batch(cur).expect("poll batch") {
            BatchProgress::Done(d) => break d,
            BatchProgress::Pending => eng.decode_block_batch(cur),
        }
    }
}

// ---------------------------------------------------------------------
// Engine-level grouped bit-equivalence (ragged widths, replication on)
// ---------------------------------------------------------------------

fn grouped_equivalence(rows: usize) {
    let name = format!("eq{rows}");
    let dir = synth_dir(&name);
    let steps = 4usize;
    let reference = sequential_logits(&name, &dir, rows, steps);

    // grouped engine under offload pressure, replication enabled — both
    // must be invisible in the logits
    let mut eng = mk_engine(&name, &dir, offload_hw(), 2, true, 2);
    let mut kvs: Vec<Option<KvState>> = (0..rows)
        .map(|r| {
            let mut kv = eng.new_sequence();
            eng.prefill(&mut kv, &prompt_tokens(r)).expect("prefill");
            Some(kv)
        })
        .collect();
    for j in 0..steps {
        let items: Vec<BatchItem> = (0..rows)
            .map(|r| BatchItem {
                seq: None,
                token: stream(r, j),
                kv: kvs[r].take().expect("kv present"),
            })
            .collect();
        let mut cur = eng.decode_begin_batch(items).expect("begin batch");
        assert_eq!(cur.width(), rows, "grouped decode is ragged: no padding at {rows}");
        let done = poll_to_done(&mut eng, &mut cur);
        assert_eq!(done.len(), rows);
        for (r, d) in done.into_iter().enumerate() {
            assert_eq!(
                d.logits, reference[r][j],
                "row {r} step {j}: grouped logits diverged from sequential"
            );
            kvs[r] = Some(d.kv);
        }
    }
    // still one merged acquire per (batch step, layer), and the grouped
    // pass actually ran
    let st = eng.residency.loader_stats();
    let n_layers = eng.cfg.n_layers as u64;
    assert_eq!(st.merged_acquires, steps as u64 * n_layers);
    assert!(st.grouped_launches > 0, "grouped path never engaged");
    assert!(st.group_rows >= st.grouped_launches);
    assert_eq!(st.dequant_reuses, st.group_rows - st.grouped_launches);
}

#[test]
fn grouped_batch_of_2_matches_sequential_bitwise() {
    grouped_equivalence(2);
}

#[test]
fn grouped_batch_of_4_matches_sequential_bitwise() {
    grouped_equivalence(4);
}

#[test]
fn grouped_batch_of_5_is_ragged_and_matches_sequential_bitwise() {
    grouped_equivalence(5); // not a padded width: only grouped mode serves it natively
}

#[test]
fn grouped_batch_of_8_matches_sequential_bitwise() {
    grouped_equivalence(8);
}

#[test]
fn grouped_batch_of_16_matches_sequential_bitwise() {
    grouped_equivalence(16); // past the legacy padded ceiling of 8
}

#[test]
fn per_row_engine_rejects_width_over_ceiling_grouped_accepts() {
    let name = "ceiling";
    let dir = synth_dir(name);
    let mut per_row = mk_engine(name, &dir, fast_hw(), 0, false, 0);
    assert_eq!(per_row.batch_ceiling(), 8);
    assert_ne!(per_row.exec_mode(), "grouped");
    let items: Vec<BatchItem> = (0..9)
        .map(|r| BatchItem { seq: None, token: stream(r, 0), kv: KvState::new(&per_row.cfg) })
        .collect();
    assert!(per_row.decode_begin_batch(items).is_err(), "padded path must cap at 8");

    let mut grouped = mk_engine(name, &dir, fast_hw(), 0, true, 0);
    assert_eq!(grouped.batch_ceiling(), 64);
    assert_eq!(grouped.exec_mode(), "grouped");
    let items: Vec<BatchItem> = (0..9)
        .map(|r| BatchItem { seq: None, token: stream(r, 0), kv: KvState::new(&grouped.cfg) })
        .collect();
    let mut cur = grouped.decode_begin_batch(items).expect("grouped serves width 9");
    assert_eq!(cur.width(), 9);
    let done = poll_to_done(&mut grouped, &mut cur);
    assert_eq!(done.len(), 9);
}

// ---------------------------------------------------------------------
// Hot skew: launches and snapshots collapse to unique experts
// ---------------------------------------------------------------------

/// Eight bit-identical rows (same prompt, same token stream) route to the
/// same top-k experts every step, so each layer step must execute exactly
/// top_k grouped launches with exactly one snapshot copy each — the
/// per-unique-(key, step) dedup contract — while every other routed row
/// is a dequant reuse.
#[test]
fn hot_skew_collapses_launches_and_snapshot_copies() {
    let name = "hotskew";
    let dir = synth_dir(name);
    let (rows, steps) = (8usize, 4usize);
    let mut eng = mk_engine(name, &dir, roomy_hw(), 2, true, 0);
    let mut kvs: Vec<Option<KvState>> = (0..rows)
        .map(|_| {
            let mut kv = eng.new_sequence();
            eng.prefill(&mut kv, &[BOS, 70]).expect("prefill");
            Some(kv)
        })
        .collect();
    let st0 = eng.residency.loader_stats();
    for j in 0..steps {
        let items: Vec<BatchItem> = (0..rows)
            .map(|r| BatchItem {
                seq: None,
                token: stream(0, j), // every row decodes the same token
                kv: kvs[r].take().expect("kv present"),
            })
            .collect();
        let mut cur = eng.decode_begin_batch(items).expect("begin batch");
        let done = poll_to_done(&mut eng, &mut cur);
        for (r, d) in done.into_iter().enumerate() {
            kvs[r] = Some(d.kv);
        }
    }
    let st = eng.residency.loader_stats();
    let expect_launches = (steps * eng.cfg.n_layers as usize * eng.cfg.top_k) as u64;
    let launches = st.grouped_launches - st0.grouped_launches;
    let group_rows = st.group_rows - st0.group_rows;
    assert_eq!(launches, expect_launches, "one launch per unique expert per layer step");
    assert_eq!(group_rows, expect_launches * rows as u64, "every routed row grouped");
    assert_eq!(
        st.dequant_reuses - st0.dequant_reuses,
        group_rows - launches,
        "all but the first row of each group reuse the dequant"
    );
    assert_eq!(
        st.snapshot_copies - st0.snapshot_copies,
        launches,
        "exactly one resident-record snapshot per unique (expert, step)"
    );
}

// ---------------------------------------------------------------------
// Coordinator-level equivalence (rr + sjf), grouped vs per-row engines
// ---------------------------------------------------------------------

const PROMPTS: [&str; 16] = [
    "alpha request one",
    "bravo request two",
    "charlie request three",
    "delta request four",
    "echo request five",
    "foxtrot request six",
    "golf request seven",
    "hotel request eight",
    "india request nine",
    "juliet request ten",
    "kilo request eleven",
    "lima request twelve",
    "mike request thirteen",
    "november request fourteen",
    "oscar request fifteen",
    "papa request sixteen",
];

/// FCFS batch-1 ground truth on a fresh per-row reference engine.
fn reference_results(name: &str, dir: &Path, k: usize, max_new: usize) -> Vec<Vec<u32>> {
    let eng = mk_engine(name, dir, fast_hw(), 2, false, 0);
    let mut coord = Coordinator::new(eng);
    (0..k)
        .map(|i| {
            coord
                .generate(&Request::new(i as u64 + 1, PROMPTS[i], max_new))
                .expect("generate")
                .tokens
        })
        .collect()
}

fn coordinator_grouped_equivalence(k: usize, policy: SchedPolicy) {
    let name = format!("coord{k}{:?}", policy == SchedPolicy::Sjf);
    let dir = synth_dir(&name);
    let max_new = 5usize;
    let reference = reference_results(&name, &dir, k, max_new);

    let eng = mk_engine(&name, &dir, offload_hw(), 2, true, 2);
    let mut coord = Coordinator::interleaved(eng);
    coord.sched_policy = policy;
    coord.max_active = k;
    coord.max_batch = k;
    for (i, p) in PROMPTS.iter().take(k).enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, max_new));
    }
    let mut results = coord.drain().expect("drain");
    assert_eq!(results.len(), k);
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert_eq!(
            &r.tokens, want,
            "request {}: grouped batched decode diverged from the batch-1 reference",
            r.id
        );
    }

    // batching engaged past the legacy ceiling, grouped counters flowed,
    // and the serving report names the mode
    let sch = coord.scheduler_stats().clone();
    assert!(sch.batch_steps > 0, "no batched steps with max_batch {k}");
    coord.sync_report();
    assert!(coord.report.loader.grouped_launches > 0);
    assert!(coord.report.loader.group_rows >= coord.report.loader.grouped_launches);
    let serving = coord
        .report
        .to_json()
        .get("serving")
        .expect("serving section")
        .to_string();
    assert!(
        serving.contains("\"exec_mode\":\"grouped\""),
        "serving report must surface the execution mode: {serving}"
    );
    assert!(serving.contains("\"grouped_launches\""));
    if k > 8 {
        assert!(
            sch.batch_occupancy() > 8.0,
            "occupancy {} never exceeded the legacy padded ceiling with {k} sequences",
            sch.batch_occupancy()
        );
    }
}

#[test]
fn coordinator_rr_grouped_matches_reference_k4() {
    coordinator_grouped_equivalence(4, SchedPolicy::RoundRobin);
}

#[test]
fn coordinator_rr_grouped_matches_reference_k16() {
    coordinator_grouped_equivalence(16, SchedPolicy::RoundRobin);
}

#[test]
fn coordinator_sjf_grouped_matches_reference_k16() {
    coordinator_grouped_equivalence(16, SchedPolicy::Sjf);
}

// ---------------------------------------------------------------------
// Hot-expert replication: visible in counters, invisible in logits
// ---------------------------------------------------------------------

/// One hot-skewed run: `rows` identical sequences, `steps` grouped steps.
/// Returns every step's row-0 logits plus the final cache stats.
fn hot_run(name: &str, dir: &Path, max_replicas: usize) -> (Vec<Vec<f32>>, hobbit::metrics::CacheStats) {
    let (rows, steps) = (8usize, 24usize);
    let mut eng = mk_engine(name, dir, roomy_hw(), 2, true, max_replicas);
    let mut kvs: Vec<Option<KvState>> = (0..rows)
        .map(|_| {
            let mut kv = eng.new_sequence();
            eng.prefill(&mut kv, &[BOS, 70]).expect("prefill");
            Some(kv)
        })
        .collect();
    let mut out = Vec::with_capacity(steps);
    for j in 0..steps {
        let items: Vec<BatchItem> = (0..rows)
            .map(|r| BatchItem {
                seq: None,
                token: stream(0, j),
                kv: kvs[r].take().expect("kv present"),
            })
            .collect();
        let mut cur = eng.decode_begin_batch(items).expect("begin batch");
        let done = poll_to_done(&mut eng, &mut cur);
        out.push(done[0].logits.clone());
        for (r, d) in done.into_iter().enumerate() {
            kvs[r] = Some(d.kv);
        }
    }
    // replicas hold no pins: the ledger balances once the run is done
    let cache = eng.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "leaked lo-pool pins");
    drop(c);
    (out, eng.residency.cache_stats())
}

#[test]
fn replication_serves_reads_without_changing_logits() {
    let name = "replica";
    let dir = synth_dir(name);
    let (base_logits, base_stats) = hot_run(name, &dir, 0);
    let (repl_logits, repl_stats) = hot_run(name, &dir, 2);
    assert_eq!(base_stats.replicas_created, 0, "budget 0 must disable replication");
    assert!(
        repl_stats.replicas_created > 0,
        "a 24-step hot-skewed run with free slots never created a replica"
    );
    assert!(
        repl_stats.replica_hits > 0,
        "rotated snapshot reads never landed on a replica"
    );
    assert_eq!(
        repl_logits, base_logits,
        "replica-served reads changed logits vs the replication-off run"
    );
}

// ---------------------------------------------------------------------
// Replica coherence at the residency seam: rotation, dedup, upgrade,
// quarantine
// ---------------------------------------------------------------------

#[test]
fn replica_rotation_snapshot_dedup_and_upgrade_coherence() {
    let cfg = tiny_store_config("grouped-replica");
    let dir = std::env::temp_dir().join("hobbit_grouped_replica_store");
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    let store = Arc::new(ExpertStore::load(&dir, &cfg).expect("store"));
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        4,
        cfg.bytes_for(Precision::F32),
        2,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    cache.lock().unwrap().set_max_replicas(2);
    let copier =
        Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: 1e9, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    let resid = ExpertResidency::with_io(
        store.clone(),
        cache.clone(),
        copier,
        predictor,
        Precision::F32,
        Precision::Q8,
        IoConfig::default(),
    );
    let key = ExpertKey::new(0, 0);
    let (_uses, w) = resid.acquire(0, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
    resid.wait(&w);
    assert!(resid.add_replica(key, Pool::Hi), "Ready primary + free slot + budget");

    // snapshot dedup: repeats of one (key, pool) within a step cost one
    // copy, the rest are reuses
    let st0 = resid.loader_stats();
    let snap = resid.snapshot_records(&[(key, Pool::Hi), (key, Pool::Hi), (key, Pool::Hi)]);
    assert_eq!(snap.len(), 1);
    let st1 = resid.loader_stats();
    assert_eq!(st1.snapshot_copies - st0.snapshot_copies, 1);
    assert_eq!(st1.snapshot_reuses - st0.snapshot_reuses, 2);
    assert_eq!(
        snap[&(key, Pool::Hi)].1.as_slice(),
        store.record(key, Precision::F32),
        "snapshot bytes match the store record wherever the rotation lands"
    );
    // a second snapshot rotates onto the replica — same bytes
    let snap2 = resid.snapshot_records(&[(key, Pool::Hi)]);
    assert_eq!(
        snap2[&(key, Pool::Hi)].1.as_slice(),
        store.record(key, Precision::F32)
    );
    assert!(resid.cache_stats().replica_hits > 0, "rotation never used the replica");

    // in-place upgrade of the primary invalidates its replicas atomically
    {
        let mut c = cache.lock().unwrap();
        let rec = store.record(key, Precision::F32).to_vec();
        assert!(c.commit_upgrade(key, Pool::Hi, None, &rec));
        assert_eq!(c.hi.replica_count(key), 0, "upgrade left a stale replica");
    }
    assert!(resid.cache_stats().replica_evictions >= 1);
    // reads still resolve from the (upgraded) primary
    let snap3 = resid.snapshot_records(&[(key, Pool::Hi)]);
    assert_eq!(
        snap3[&(key, Pool::Hi)].1.as_slice(),
        store.record(key, Precision::F32)
    );
    resid.release(key, Pool::Hi);

    // quarantine: a corrupt landing scrubs the slot AND drops replicas —
    // a rotated read can never serve bytes whose primary was quarantined
    {
        let mut c = cache.lock().unwrap();
        let k2 = ExpertKey::new(0, 1);
        let good = store.record(k2, Precision::F32);
        let sum = fnv1a64(good);
        let r = c.reserve(k2, Pool::Hi, 0).expect("reserve");
        assert!(!c.add_replica(k2, Pool::Hi), "a Loading key can't be replicated");
        let mut bad = good.to_vec();
        bad[0] ^= 0x01;
        r.buffer.lock().unwrap()[..bad.len()].copy_from_slice(&bad);
        let out = c.commit_tier_verified(k2, Pool::Hi, None, Some((sum, good.len())));
        assert_eq!(out, CommitOutcome::Corrupt);
        assert_eq!(c.hi.replica_count(k2), 0);
        assert!(c.read_buffer_tier(k2, Pool::Hi).is_none(), "quarantined key unreadable");
    }
}

// ---------------------------------------------------------------------
// Eviction + abort under grouped execution with replication on
// ---------------------------------------------------------------------

/// A row whose loads block mid-group leaves the grouped batch without
/// stalling the others, finishes solo bit-identically, and every cache
/// pin is released — with replication enabled.
#[test]
fn grouped_blocked_row_evicts_without_stalling_or_leaking_pins() {
    let name = "gevict";
    let dir = synth_dir(name);
    let reference: Vec<Vec<f32>> = {
        let mut eng = mk_engine(name, &dir, fast_hw(), 0, false, 0);
        (0..2)
            .map(|r| {
                let mut kv = eng.new_sequence();
                eng.decode_step(&mut kv, stream(r, 0)).expect("decode")
            })
            .collect()
    };

    // ~120ms per f32 expert: layer-0 misses are guaranteed mid-flight
    let slow = HardwareConfig { load_bw: 5e4, ..offload_hw() };
    let mut eng = mk_engine(name, &dir, slow, 0, true, 2);
    let items: Vec<BatchItem> = (0..2)
        .map(|r| BatchItem { seq: None, token: stream(r, 0), kv: KvState::new(&eng.cfg) })
        .collect();
    let mut cur = eng.decode_begin_batch(items).expect("begin");
    let progress = eng.decode_poll_batch(&mut cur).expect("poll");
    assert!(matches!(progress, BatchProgress::Pending));
    assert!(cur.row_blocked(1), "row 1's loads are on the link");

    let (seq, mut kv1, mut solo) =
        eng.decode_evict_row(&mut cur, 1).expect("blocked row is evictable");
    assert_eq!(seq, None);
    assert_eq!(cur.rows_alive(), 1, "evicted row left the group");

    let done = poll_to_done(&mut eng, &mut cur);
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].logits, reference[0], "survivor diverged after eviction");

    let logits1 = loop {
        match eng.decode_poll(&mut kv1, &mut solo).expect("solo poll") {
            DecodeProgress::Done(l) => break l,
            DecodeProgress::Pending => eng.decode_block(&mut solo),
        }
    };
    assert_eq!(logits1, reference[1], "evicted row diverged from sequential");

    let cache = eng.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "leaked lo-pool pins");
}

/// Aborting a suspended grouped batch releases every remaining row's pins
/// (replication on — replica slots hold no pins either).
#[test]
fn grouped_batch_abort_releases_all_pins() {
    let name = "gabort";
    let dir = synth_dir(name);
    let slow = HardwareConfig { load_bw: 5e4, ..offload_hw() };
    let mut eng = mk_engine(name, &dir, slow, 0, true, 2);
    let items: Vec<BatchItem> = (0..4)
        .map(|r| BatchItem { seq: None, token: stream(r, 0), kv: KvState::new(&eng.cfg) })
        .collect();
    let mut cur = eng.decode_begin_batch(items).expect("begin");
    let progress = eng.decode_poll_batch(&mut cur).expect("poll");
    assert!(matches!(progress, BatchProgress::Pending));
    eng.decode_abort_batch(cur);
    let cache = eng.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "abort leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "abort leaked lo-pool pins");
}
