//! Property-based tests (proptest_mini) on coordinator-side invariants:
//! routing/scoring, cache bookkeeping under random access streams, and
//! predictor pin/unpin balance.

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::loader::scorer::{self, Class};
use hobbit::predictor::Predictor;
use hobbit::prop_assert;
use hobbit::tensor::softmax;
use hobbit::util::proptest_mini::check;
use hobbit::util::rng::Rng;
use hobbit::ExpertKey;

fn random_probs(rng: &mut Rng, e: usize) -> Vec<f32> {
    let logits: Vec<f32> = (0..e).map(|_| rng.normal() as f32 * 2.0).collect();
    softmax(&logits)
}

#[test]
fn prop_scorer_invariants() {
    check("scorer invariants", |rng| {
        let e = 2 + rng.below(62);
        let k = 1 + rng.below(e.min(8));
        let t1 = rng.f64();
        let t2 = t1 + (1.0 - t1) * rng.f64();
        let probs = random_probs(rng, e);
        let d = scorer::decide(&probs, k, t1, t2, true);
        prop_assert!(d.len() == k, "got {} decisions for top-{k}", d.len());
        // first expert always high precision
        prop_assert!(d[0].class == Class::Hi, "rank-0 must be Hi");
        prop_assert!(d[0].score == 0.0);
        // scores monotone, in [0, 1]
        for w in d.windows(2) {
            prop_assert!(w[0].score <= w[1].score + 1e-9);
        }
        for x in &d {
            prop_assert!((0.0..=1.0 + 1e-6).contains(&x.score), "score {}", x.score);
            // class consistent with thresholds
            let want = if x.score == 0.0 || x.score <= t1 {
                Class::Hi
            } else if x.score <= t2 {
                Class::Lo
            } else {
                Class::Skip
            };
            prop_assert!(x.class == want, "class mismatch at score {}", x.score);
        }
        // gate weights renormalized over top-k
        let s: f32 = d.iter().map(|x| x.gate_weight).sum();
        prop_assert!((s - 1.0).abs() < 1e-4, "gate weights sum {s}");
        // distinct experts
        let mut seen: Vec<u32> = d.iter().map(|x| x.expert).collect();
        seen.sort_unstable();
        seen.dedup();
        prop_assert!(seen.len() == k, "duplicate experts selected");
        Ok(())
    });
}

#[test]
fn prop_cache_capacity_and_consistency() {
    check("cache capacity + bookkeeping", |rng| {
        let layers = 1 + rng.below(8) as u32;
        let experts = 1 + rng.below(16) as u32;
        let hi_cap = 1 + rng.below(12);
        let lo_cap = 1 + rng.below(12);
        let policy = match rng.below(5) {
            0 => Policy::Random { seed: rng.next_u64() },
            1 => Policy::Lru,
            2 => Policy::LfuSeq,
            3 => Policy::Lhu,
            _ => Policy::Multidim { w: [0.65, 0.05, 0.10, 0.20] },
        };
        let mut cache =
            CacheManager::new(layers, experts, hi_cap, 0, lo_cap, 0, policy, 0.25);
        let mut resident_hi = std::collections::HashSet::new();
        let mut resident_lo = std::collections::HashSet::new();
        for step in 0..200 {
            if step % 7 == 0 {
                cache.records.note_token();
            }
            let key = ExpertKey::new(
                rng.below(layers as usize) as u32,
                rng.below(experts as usize) as u32,
            );
            let pool = if rng.below(2) == 0 { Pool::Hi } else { Pool::Lo };
            let hit = cache.access(key, pool);
            let resident = match pool {
                Pool::Hi => &mut resident_hi,
                Pool::Lo => &mut resident_lo,
            };
            prop_assert!(
                hit == resident.contains(&key),
                "hit state diverged for {key:?} {pool:?} at step {step}"
            );
            if !hit {
                if let Some(r) = cache.reserve(key, pool, key.layer) {
                    if let Some(victim) = r.evicted {
                        prop_assert!(resident.remove(&victim), "evicted non-resident {victim:?}");
                    }
                    cache.commit(key, pool);
                    resident.insert(key);
                }
            }
            cache.note_use(key, pool);
            prop_assert!(cache.hi.len() <= hi_cap, "hi pool overflow");
            prop_assert!(cache.lo.len() <= lo_cap, "lo pool overflow");
        }
        // stats identity
        let st = &cache.stats;
        prop_assert!(
            st.hits_hi + st.hits_lo + st.misses_hi + st.misses_lo == 200,
            "access count mismatch"
        );
        let expected = st.misses_hi as f64 + st.misses_lo as f64 * 0.25;
        prop_assert!(
            (st.miss_penalty - expected).abs() < 1e-9,
            "penalty {} != {expected}",
            st.miss_penalty
        );
        Ok(())
    });
}

#[test]
fn prop_predictor_pins_balanced() {
    check("predictor pin/unpin balance", |rng| {
        let layers = 4 + rng.below(6) as u32;
        let e = 4 + rng.below(12);
        let mut cache = CacheManager::new(layers, e as u32, 16, 0, 16, 0, Policy::Lru, 0.25);
        // pre-populate some experts
        for _ in 0..10 {
            let key = ExpertKey::new(
                rng.below(layers as usize) as u32,
                rng.below(e) as u32,
            );
            if cache.reserve(key, Pool::Hi, 0).is_some() {
                cache.commit(key, Pool::Hi);
            }
        }
        let depth = 1 + rng.below(3);
        let mut pred = Predictor::new(depth, 2, 0.6, 0.9, true, layers);
        // simulate several decode layer sweeps
        for l in 0..layers.saturating_sub(1) {
            let stacked: Vec<Vec<f32>> =
                (0..=depth).map(|_| random_probs(rng, e)).collect();
            let _ = pred.plan(&mut cache, l, layers, &stacked);
            pred.observe(&mut cache, l, &stacked[0]);
        }
        // after observing every layer, no pins may survive the sweep for
        // layers we observed
        for l in 0..layers {
            let probs = random_probs(rng, e);
            pred.observe(&mut cache, l, &probs);
        }
        for l in 0..layers {
            for ei in 0..e {
                let key = ExpertKey::new(l, ei as u32);
                prop_assert!(
                    !cache.hi.pinned_contains(key) && !cache.lo.pinned_contains(key),
                    "leaked pin on {key:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_selection_stable() {
    check("topk deterministic + ordered", |rng| {
        let e = 2 + rng.below(30);
        let probs = random_probs(rng, e);
        let k = 1 + rng.below(e);
        let a = hobbit::tensor::topk(&probs, k);
        let b = hobbit::tensor::topk(&probs, k);
        prop_assert!(a == b, "topk not deterministic");
        for w in a.windows(2) {
            prop_assert!(w[0].1 >= w[1].1, "topk not descending");
        }
        Ok(())
    });
}
