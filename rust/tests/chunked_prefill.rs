//! Chunked-prefill regression suite: slicing prefill must NEVER change
//! the model's output.
//!
//! Everything here runs artifact-free on a synthesized model
//! (`model::synth`) through the pure-Rust reference executor
//! (`Engine::new_reference`), like `batched_decode.rs` — the loader,
//! cache, predictor, residency facade, and both schedulers are the real
//! ones, so this suite gates CI without the AOT compile step.
//!
//! Coverage:
//! * engine-level: driving a `PrefillCursor` to completion (poll → park →
//!   resume, the interleaved scheduler's shape) produces **bit-identical**
//!   final logits AND identical KV state to the blocking `Engine::prefill`,
//!   for prompt lengths {1, 16, 129, 300} spanning every `PREFILL_CHUNKS`
//!   width mix, and stays identical through subsequent decode steps;
//! * coordinator-level: interleaved serving with chunked admission (the
//!   default), under rr, sjf and the new token-budget policy, completes
//!   every request bit-identically to the FCFS batch-1 reference while
//!   admitting a long prompt mid-flight — with prefill-slice stats in the
//!   `"serving"` report section;
//! * lifecycle: aborting a sequence mid-prefill-chunk (engine abort and
//!   coordinator `abort_all` alike) releases every cache pin, and a
//!   prefill error fails only its own request instead of tearing down the
//!   scheduler loop.

use std::path::{Path, PathBuf};

use hobbit::config::{HardwareConfig, ModelConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request, SchedPolicy};
use hobbit::engine::{prefill_chunk_schedule, Engine, EngineOptions, PrefillProgress};
use hobbit::model::synth::{tiny_model_config, write_synth_model};
use hobbit::util::json::Json;

const SEED: u64 = 0xCF1115;

/// The tiny synth shape with a KV budget large enough for 300-token
/// prompts (weights do not depend on `max_seq`).
fn big_cfg(name: &str) -> ModelConfig {
    let mut cfg = tiny_model_config(name);
    cfg.max_seq = 512;
    cfg
}

fn synth_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hobbit_chunked_{name}"));
    let cfg = big_cfg(name);
    write_synth_model(&dir, &cfg, SEED).expect("synth model");
    dir
}

fn fast_hw() -> HardwareConfig {
    HardwareConfig {
        name: "chunked-fast".into(),
        load_bw: 1e9,
        load_latency: 0.0,
        hi_cache_experts: 12, // every expert of the tiny model fits
        lo_cache_experts: 12,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Offload-bound: small cache + a link slow enough (~3ms per f32 expert)
/// that chunk barriers genuinely wait on the wire.
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "chunked-offload".into(),
        load_bw: 2e6,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Dynamic loading off: every routed expert executes in high precision,
/// so logits depend only on the token history — chunking, interleaving
/// order, link speed, and cache pressure must not change them. The fetch
/// precision is pinned to the hi format so the per-acquire precision
/// choice can never perturb this bit-equivalence suite.
fn quality_policy(prefetch_depth: usize) -> PolicyConfig {
    PolicyConfig {
        dynamic_loading: false,
        prefetch_depth,
        pin_precision: Some(hobbit::Precision::F32),
        ..PolicyConfig::default()
    }
}

fn mk_engine(name: &str, dir: &Path, hw: HardwareConfig, prefetch: usize) -> Engine {
    Engine::new_reference(dir, big_cfg(name), EngineOptions::new(hw, quality_policy(prefetch)))
        .expect("reference engine")
}

fn prompt_tokens(len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| 65 + (i * 13) % 190).collect()
}

fn decode_stream(step: usize) -> u32 {
    (65 + (step * 7) % 190) as u32
}

/// The greedy 128/16/1 split both prefill paths must take — the engine's
/// own schedule helper (its literal values are pinned by
/// `sim::des::tests::chunk_split_follows_prefill_chunks`).
fn expected_chunks(len: usize) -> Vec<usize> {
    prefill_chunk_schedule(len)
}

// ---------------------------------------------------------------------
// Engine-level bit-equivalence
// ---------------------------------------------------------------------

#[test]
fn chunked_prefill_matches_blocking_bitwise() {
    for &plen in &[1usize, 16, 129, 300] {
        let name = format!("eq{plen}");
        let dir = synth_dir(&name);
        let toks = prompt_tokens(plen);
        let decode_steps = 3usize;

        // blocking reference on a fast link
        let mut eng_a = mk_engine(&name, &dir, fast_hw(), 2);
        let mut kv_a = eng_a.new_sequence();
        let logits_a = eng_a.prefill(&mut kv_a, &toks).expect("blocking prefill");
        let decode_a: Vec<Vec<f32>> = (0..decode_steps)
            .map(|j| eng_a.decode_step(&mut kv_a, decode_stream(j)).expect("decode"))
            .collect();

        // chunked under offload pressure, driven like the scheduler:
        // poll; park at barriers; block only when nothing else is runnable
        let mut eng_b = mk_engine(&name, &dir, offload_hw(), 2);
        let mut kv_b = eng_b.new_sequence();
        let mut cur = eng_b.prefill_begin(&kv_b, &toks).expect("prefill begin");
        let mut slices = 0usize;
        let logits_b = loop {
            match eng_b.prefill_poll(&mut kv_b, &mut cur).expect("prefill poll") {
                PrefillProgress::Done(l) => {
                    slices += 1;
                    break l;
                }
                PrefillProgress::Chunk { done, total } => {
                    slices += 1;
                    assert!(done < total, "Chunk after the last chunk");
                    assert_eq!(total, plen);
                    assert_eq!(done, cur.prefilled());
                }
                PrefillProgress::Pending => {
                    assert!(cur.is_pending());
                    eng_b.prefill_block(&mut cur);
                }
            }
        };

        assert_eq!(
            logits_b, logits_a,
            "prompt {plen}: chunked prefill logits diverged from blocking"
        );
        assert_eq!(kv_b.pos, kv_a.pos, "prompt {plen}: KV position diverged");
        assert_eq!(kv_b.k, kv_a.k, "prompt {plen}: K cache diverged");
        assert_eq!(kv_b.v, kv_a.v, "prompt {plen}: V cache diverged");

        // one slice per chunk, widths following the greedy 128/16/1 split
        let want = expected_chunks(plen);
        assert_eq!(slices, want.len(), "prompt {plen}: one slice per chunk");
        assert_eq!(cur.chunk_widths(), &want[..], "prompt {plen}: chunk widths");

        // the prefill-class merged acquires happened: one per (chunk, layer)
        let st = eng_b.residency.loader_stats();
        let n_layers = big_cfg(&name).n_layers as u64;
        assert_eq!(st.prefill_merged_acquires, want.len() as u64 * n_layers);
        assert!(st.prefill_merged_demands >= st.prefill_merged_unique);
        // the blocking path never bumps the prefill-merged ledger
        assert_eq!(eng_a.residency.loader_stats().prefill_merged_acquires, 0);

        // the KV state keeps decoding identically after a chunked prefill
        for (j, want_logits) in decode_a.iter().enumerate() {
            let got = eng_b.decode_step(&mut kv_b, decode_stream(j)).expect("decode");
            assert_eq!(
                &got, want_logits,
                "prompt {plen}: decode step {j} diverged after chunked prefill"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator-level equivalence with a long admission mid-flight
// ---------------------------------------------------------------------

const SHORT_PROMPTS: [&str; 3] = [
    "alpha request one",
    "bravo request two",
    "charlie request three",
];

fn long_prompt_text() -> String {
    // 299 bytes + BOS = 300 tokens: chunks 128/128/16/16/1x12
    "x".repeat(299)
}

/// FCFS batch-1 ground truth on a fresh reference engine.
fn reference_results(name: &str, dir: &Path, max_new: usize) -> Vec<Vec<u32>> {
    let eng = mk_engine(name, dir, fast_hw(), 2);
    let mut coord = Coordinator::new(eng);
    let mut out = Vec::new();
    for (i, p) in SHORT_PROMPTS.iter().enumerate() {
        out.push(
            coord
                .generate(&Request::new(i as u64 + 1, *p, max_new))
                .expect("generate")
                .tokens,
        );
    }
    out.push(
        coord
            .generate(&Request::new(99, long_prompt_text(), max_new))
            .expect("generate long")
            .tokens,
    );
    out
}

fn coordinator_equivalence(policy: SchedPolicy, token_budget: usize) {
    let name = format!("coord{policy:?}{token_budget}").to_lowercase();
    let dir = synth_dir(&name);
    let max_new = 5usize;
    let reference = reference_results(&name, &dir, max_new);

    let eng = mk_engine(&name, &dir, offload_hw(), 2);
    let mut coord = Coordinator::interleaved(eng);
    coord.sched_policy = policy;
    coord.token_budget = token_budget;
    coord.max_active = 4;
    assert!(coord.chunked_prefill, "chunked prefill is the interleaved default");
    for (i, p) in SHORT_PROMPTS.iter().enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, max_new));
    }
    // the late long-prompt admission rides alongside the live short ones
    coord.submit(Request::new(99, long_prompt_text(), max_new));
    let mut results = coord.drain().expect("drain");
    assert!(coord.take_failures().is_empty(), "no request may fail");
    assert_eq!(results.len(), SHORT_PROMPTS.len() + 1);
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert_eq!(
            &r.tokens, want,
            "request {}: chunked interleaved serving diverged from the FCFS reference",
            r.id
        );
    }

    // prefill really was sliced: at least one slice per chunk of the long
    // prompt, and the 128/16/1 histogram saw every width
    let sch = coord.scheduler_stats().clone();
    assert!(
        sch.prefill_slices >= 16,
        "only {} prefill slices for a 300-token admission",
        sch.prefill_slices
    );
    assert!(sch.prefill_chunks[0] >= 2, "no 128-wide chunks recorded");
    assert!(sch.prefill_chunks[1] >= 2, "no 16-wide chunks recorded");
    assert!(sch.prefill_chunks[2] >= 12, "no 1-wide chunks recorded");
    assert_eq!(sch.prefill_failures, 0);

    // ... and surfaced under the serving report key
    coord.sync_report();
    let j = Json::parse(&coord.report.to_json().to_string()).unwrap();
    let serving = j.get("serving").expect("serving section");
    assert!(serving.get("prefill_slices").unwrap().as_f64().unwrap() >= 16.0);
    assert!(serving.get("prefill_stall_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(serving.get("prefill_chunks_128").unwrap().as_f64().unwrap() >= 2.0);
    assert!(serving.get("prefill_merged_acquires").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn coordinator_rr_chunked_matches_reference() {
    coordinator_equivalence(SchedPolicy::RoundRobin, 1);
}

#[test]
fn coordinator_sjf_chunked_matches_reference() {
    coordinator_equivalence(SchedPolicy::Sjf, 1);
}

#[test]
fn coordinator_token_budget_chunked_matches_reference() {
    coordinator_equivalence(SchedPolicy::TokenBudget, 2);
}

#[test]
fn prefill_first_knob_matches_reference() {
    let name = "prio";
    let dir = synth_dir(name);
    let max_new = 4usize;
    let reference = reference_results(name, &dir, max_new);
    let eng = mk_engine(name, &dir, offload_hw(), 2);
    let mut coord = Coordinator::interleaved(eng);
    coord.prefill_first = true;
    for (i, p) in SHORT_PROMPTS.iter().enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, max_new));
    }
    coord.submit(Request::new(99, long_prompt_text(), max_new));
    let mut results = coord.drain().expect("drain");
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert_eq!(&r.tokens, want, "request {}: prefill-first diverged", r.id);
    }
}

// ---------------------------------------------------------------------
// Lifecycle: pin leaks and failure isolation
// ---------------------------------------------------------------------

/// Aborting mid-prefill-chunk (loads still on the link) releases every
/// cache pin the chunk barrier held. Prefetch off so the pin ledger
/// isolates the chunk-acquire accounting.
#[test]
fn aborting_mid_prefill_chunk_releases_pins() {
    let name = "abort";
    let dir = synth_dir(name);
    // ~120ms per f32 expert: the first chunk's misses are mid-flight
    let slow = HardwareConfig { load_bw: 5e4, ..offload_hw() };
    let mut eng = mk_engine(name, &dir, slow, 0);
    let mut kv = eng.new_sequence();
    let mut cur = eng.prefill_begin(&kv, &prompt_tokens(16)).expect("begin");
    let progress = eng.prefill_poll(&mut kv, &mut cur).expect("poll");
    assert!(
        matches!(progress, PrefillProgress::Pending),
        "cold cache over a 120ms/expert link must suspend the chunk"
    );
    assert!(cur.is_pending() && cur.is_blocked());
    assert!(!cur.pending_tickets().is_empty());
    eng.prefill_abort(cur);
    let cache = eng.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "abort leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "abort leaked lo-pool pins");
}

/// `Coordinator::abort_all` drains a sequence suspended mid-prefill-chunk
/// exactly like batch eviction drains a row: no pin survives.
#[test]
fn coordinator_abort_all_drains_prefill_pins() {
    let name = "abortall";
    let dir = synth_dir(name);
    let slow = HardwareConfig { load_bw: 5e4, ..offload_hw() };
    let eng = mk_engine(name, &dir, slow, 0);
    let mut coord = Coordinator::interleaved(eng);
    coord.submit(Request::new(1, long_prompt_text(), 4));
    // a few non-blocking rounds: admission + the first chunk's barrier
    for _ in 0..3 {
        let _ = coord.step_nonblocking().expect("step");
    }
    assert!(
        !coord.pending_tickets().is_empty(),
        "the prefill chunk should be parked on in-flight loads"
    );
    let ids = coord.abort_all();
    assert_eq!(ids, vec![1]);
    let cache = coord.engine.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "abort_all leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "abort_all leaked lo-pool pins");
}

/// A prefill error fails only its own request: the scheduler loop keeps
/// running (drain returns Ok) and the failure is reported per-request for
/// the serving front-end — on the chunked AND the blocking admission
/// path. (A zero-capacity KV budget makes every prefill fail
/// deterministically.)
#[test]
fn prefill_error_fails_only_that_request() {
    for chunked in [true, false] {
        let name = format!("fail{chunked}");
        let dir = std::env::temp_dir().join(format!("hobbit_chunked_{name}"));
        let mut cfg = tiny_model_config(&name);
        cfg.max_seq = 0; // no KV budget: prefill must error, not panic
        write_synth_model(&dir, &cfg, SEED).expect("synth model");
        let eng =
            Engine::new_reference(&dir, cfg, EngineOptions::new(fast_hw(), quality_policy(0)))
                .expect("reference engine");
        let mut coord = Coordinator::interleaved(eng);
        coord.chunked_prefill = chunked;
        coord.submit(Request::new(7, "doomed request", 2));
        // the loop must survive the error instead of propagating it
        let results = coord.drain().expect("drain survives a prefill error");
        assert!(results.is_empty());
        let failures = coord.take_failures();
        assert_eq!(failures.len(), 1, "exactly one failed request (chunked={chunked})");
        assert_eq!(failures[0].0, 7);
        assert!(
            failures[0].1.contains("KV capacity"),
            "failure carries the prefill error: {}",
            failures[0].1
        );
        assert_eq!(coord.scheduler_stats().prefill_failures, 1);
        // failures drain exactly once
        assert!(coord.take_failures().is_empty());
    }
}
