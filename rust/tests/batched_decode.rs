//! Batched-decode regression suite: batching must NEVER change logits.
//!
//! Everything here runs artifact-free on a synthesized model
//! (`model::synth`) through the pure-Rust reference executor
//! (`Engine::new_reference`), so it gates CI without the AOT compile
//! step. The loader, cache, predictor, residency facade, and both
//! schedulers are the real ones; the reference kernels compute every op
//! row-independently in a fixed order, so the batch-vs-sequential
//! comparisons below are **bit-identical**, not tolerance-based.
//!
//! Coverage:
//! * engine-level: decoding K sequences as one `BatchCursor` step stream
//!   produces bit-identical per-sequence logits to `decode_step`-ing them
//!   one at a time, for K in {2, 3 (padded to 4), 8};
//! * coordinator-level: `--max-batch K` completions equal the FCFS
//!   reference under both rr and sjf, with batch occupancy > 1 and one
//!   merged acquire per (batch, layer) in the serving stats;
//! * eviction: a row whose loads block mid-group leaves the batch without
//!   stalling the others, finishes solo with identical logits, and every
//!   cache pin is released (no leaks).

use std::path::{Path, PathBuf};

use hobbit::config::{HardwareConfig, PolicyConfig};
use hobbit::coordinator::{Coordinator, Request, SchedPolicy};
use hobbit::engine::{BatchItem, BatchProgress, DecodeProgress, Engine, EngineOptions, KvState};
use hobbit::model::synth::{tiny_model_config, write_synth_model};
use hobbit::tokenizer::BOS;
use hobbit::util::json::Json;

const SEED: u64 = 0xBA7C4;

fn synth_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hobbit_batched_{name}"));
    let cfg = tiny_model_config(name);
    write_synth_model(&dir, &cfg, SEED).expect("synth model");
    dir
}

fn fast_hw() -> HardwareConfig {
    HardwareConfig {
        name: "batched-fast".into(),
        load_bw: 1e9,
        load_latency: 0.0,
        hi_cache_experts: 12, // every expert of the tiny model fits
        lo_cache_experts: 12,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Offload-bound: small cache + a link slow enough (~3ms per f32 expert)
/// that merged acquires genuinely wait on the wire.
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "batched-offload".into(),
        load_bw: 2e6,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Dynamic loading off: every routed expert executes in high precision,
/// so logits depend only on each row's own token history — cache state,
/// link speed, batching, and scheduling order must not change them. The
/// fetch precision is pinned to the hi format so the per-acquire
/// precision choice can never perturb this bit-equivalence suite.
fn quality_policy(prefetch_depth: usize) -> PolicyConfig {
    PolicyConfig {
        dynamic_loading: false,
        prefetch_depth,
        pin_precision: Some(hobbit::Precision::F32),
        ..PolicyConfig::default()
    }
}

fn mk_engine(name: &str, dir: &Path, hw: HardwareConfig, prefetch: usize) -> Engine {
    let cfg = tiny_model_config(name);
    Engine::new_reference(dir, cfg, EngineOptions::new(hw, quality_policy(prefetch)))
        .expect("reference engine")
}

/// Deterministic per-row token streams (byte tokens, all < 256).
fn stream(row: usize, step: usize) -> u32 {
    (65 + ((row * 31 + step * 7) % 190)) as u32
}

fn prompt_tokens(row: usize) -> Vec<u32> {
    vec![BOS, (70 + row as u32) % 256]
}

/// Ground truth: each row decoded alone with the blocking batch-1 step.
fn sequential_logits(
    name: &str,
    dir: &Path,
    rows: usize,
    steps: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut eng = mk_engine(name, dir, fast_hw(), 2);
    (0..rows)
        .map(|r| {
            let mut kv = eng.new_sequence();
            eng.prefill(&mut kv, &prompt_tokens(r)).expect("prefill");
            (0..steps)
                .map(|j| eng.decode_step(&mut kv, stream(r, j)).expect("decode"))
                .collect()
        })
        .collect()
}

fn batch_equivalence(rows: usize, expect_width: usize) {
    let name = format!("eq{rows}");
    let dir = synth_dir(&name);
    let steps = 5usize;
    let reference = sequential_logits(&name, &dir, rows, steps);

    // batched engine under offload pressure: merged acquires really wait
    let mut eng = mk_engine(&name, &dir, offload_hw(), 2);
    let mut kvs: Vec<Option<KvState>> = (0..rows)
        .map(|r| {
            let mut kv = eng.new_sequence();
            eng.prefill(&mut kv, &prompt_tokens(r)).expect("prefill");
            Some(kv)
        })
        .collect();
    for j in 0..steps {
        let items: Vec<BatchItem> = (0..rows)
            .map(|r| BatchItem {
                seq: None,
                token: stream(r, j),
                kv: kvs[r].take().expect("kv present"),
            })
            .collect();
        let mut cur = eng.decode_begin_batch(items).expect("begin batch");
        assert_eq!(cur.width(), expect_width, "batch of {rows} pads to {expect_width}");
        let done = loop {
            match eng.decode_poll_batch(&mut cur).expect("poll batch") {
                BatchProgress::Done(d) => break d,
                BatchProgress::Pending => eng.decode_block_batch(&mut cur),
            }
        };
        assert_eq!(done.len(), rows);
        for (r, d) in done.into_iter().enumerate() {
            assert_eq!(
                d.logits, reference[r][j],
                "row {r} step {j}: batched logits diverged from sequential"
            );
            kvs[r] = Some(d.kv);
        }
    }
    // one merged acquire per (batch step, layer)
    let st = eng.residency.loader_stats();
    let n_layers = eng.cfg.n_layers as u64;
    assert_eq!(st.merged_acquires, steps as u64 * n_layers);
    assert!(st.merged_demands >= st.merged_unique);
}

#[test]
fn batch_of_2_matches_sequential_bitwise() {
    batch_equivalence(2, 2);
}

#[test]
fn batch_of_3_pads_to_4_and_matches_sequential_bitwise() {
    batch_equivalence(3, 4);
}

#[test]
fn batch_of_8_matches_sequential_bitwise() {
    batch_equivalence(8, 8);
}

// ---------------------------------------------------------------------
// Coordinator-level equivalence (rr + sjf) and serving stats
// ---------------------------------------------------------------------

const PROMPTS: [&str; 8] = [
    "alpha request one",
    "bravo request two",
    "charlie request three",
    "delta request four",
    "echo request five",
    "foxtrot request six",
    "golf request seven",
    "hotel request eight",
];

/// FCFS batch-1 ground truth on a fresh reference engine.
fn reference_results(name: &str, dir: &Path, k: usize, max_new: usize) -> Vec<Vec<u32>> {
    let eng = mk_engine(name, dir, fast_hw(), 2);
    let mut coord = Coordinator::new(eng);
    (0..k)
        .map(|i| {
            coord
                .generate(&Request::new(i as u64 + 1, PROMPTS[i], max_new))
                .expect("generate")
                .tokens
        })
        .collect()
}

fn coordinator_equivalence(k: usize, policy: SchedPolicy) {
    let name = format!("coord{k}{:?}", policy == SchedPolicy::Sjf);
    let dir = synth_dir(&name);
    let max_new = 6usize;
    let reference = reference_results(&name, &dir, k, max_new);

    let eng = mk_engine(&name, &dir, offload_hw(), 2);
    let mut coord = Coordinator::interleaved(eng);
    coord.sched_policy = policy;
    coord.max_active = k;
    coord.max_batch = k;
    for (i, p) in PROMPTS.iter().take(k).enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, max_new));
    }
    let mut results = coord.drain().expect("drain");
    assert_eq!(results.len(), k);
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert_eq!(
            &r.tokens, want,
            "request {}: batched decode diverged from the batch-1 reference",
            r.id
        );
    }

    // batching actually engaged, and each batch issued one merged acquire
    // per layer
    let sch = coord.scheduler_stats().clone();
    assert!(sch.batch_steps > 0, "no batched steps with max_batch {k}");
    assert!(
        sch.batch_occupancy() > 1.0,
        "occupancy {} with {k} concurrent sequences",
        sch.batch_occupancy()
    );
    coord.sync_report();
    let n_layers = coord.engine.cfg.n_layers as u64;
    assert_eq!(coord.report.loader.merged_acquires, sch.batch_steps * n_layers);

    // stats surface under the serving key
    let j = Json::parse(&coord.report.to_json().to_string()).unwrap();
    let serving = j.get("serving").expect("serving section");
    assert!(serving.get("batch_occupancy").unwrap().as_f64().unwrap() > 1.0);
    assert!(serving.get("merged_acquires").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn coordinator_rr_batched_matches_reference_k2() {
    coordinator_equivalence(2, SchedPolicy::RoundRobin);
}

#[test]
fn coordinator_rr_batched_matches_reference_k3_padded() {
    coordinator_equivalence(3, SchedPolicy::RoundRobin);
}

#[test]
fn coordinator_rr_batched_matches_reference_k8() {
    coordinator_equivalence(8, SchedPolicy::RoundRobin);
}

#[test]
fn coordinator_sjf_batched_matches_reference_k3_padded() {
    coordinator_equivalence(3, SchedPolicy::Sjf);
}

#[test]
fn coordinator_sjf_batched_matches_reference_k8() {
    coordinator_equivalence(8, SchedPolicy::Sjf);
}

// ---------------------------------------------------------------------
// Eviction under blocking: the satellite fix
// ---------------------------------------------------------------------

/// A row whose expert loads are still on the link is evicted from the
/// batch; the survivor finishes WITHOUT waiting on the evicted row's
/// tickets, the evicted row finishes solo, both bit-identical to their
/// sequential references, and no cache pin leaks. Prefetch is off so the
/// pin ledger isolates the batch/merged-acquire accounting.
#[test]
fn blocked_row_evicts_without_stalling_or_leaking_pins() {
    let name = "evict";
    let dir = synth_dir(name);
    // sequential references (fresh engine, fast link)
    let reference: Vec<Vec<f32>> = {
        let mut eng = mk_engine(name, &dir, fast_hw(), 0);
        (0..2)
            .map(|r| {
                let mut kv = eng.new_sequence();
                eng.decode_step(&mut kv, stream(r, 0)).expect("decode")
            })
            .collect()
    };

    // ~120ms per f32 expert: layer-0 misses are guaranteed mid-flight
    let slow = HardwareConfig { load_bw: 5e4, ..offload_hw() };
    let mut eng = mk_engine(name, &dir, slow, 0);
    let items: Vec<BatchItem> = (0..2)
        .map(|r| BatchItem { seq: None, token: stream(r, 0), kv: KvState::new(&eng.cfg) })
        .collect();
    let mut cur = eng.decode_begin_batch(items).expect("begin");
    let progress = eng.decode_poll_batch(&mut cur).expect("poll");
    assert!(
        matches!(progress, BatchProgress::Pending),
        "cold cache over a 120ms/expert link must suspend the batch"
    );
    assert!(cur.row_blocked(1), "row 1's loads are on the link");
    let tickets_before = cur.pending_tickets().len();

    let (seq, mut kv1, mut solo) =
        eng.decode_evict_row(&mut cur, 1).expect("blocked row is evictable");
    assert_eq!(seq, None);
    assert_eq!(cur.rows_alive(), 1, "evicted row left the group");
    assert!(
        cur.pending_tickets().len() <= tickets_before,
        "the batch must not keep waiting on the evicted row's own tickets"
    );
    // a second eviction of the same row is refused
    assert!(eng.decode_evict_row(&mut cur, 1).is_none());

    // the survivor finishes on the batch path
    let done = loop {
        match eng.decode_poll_batch(&mut cur).expect("poll") {
            BatchProgress::Done(d) => break d,
            BatchProgress::Pending => eng.decode_block_batch(&mut cur),
        }
    };
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].logits, reference[0], "survivor diverged after eviction");
    assert_eq!(done[0].kv.pos, 1);

    // the evicted row finishes solo on its carved-out barrier
    let logits1 = loop {
        match eng.decode_poll(&mut kv1, &mut solo).expect("solo poll") {
            DecodeProgress::Done(l) => break l,
            DecodeProgress::Pending => eng.decode_block(&mut solo),
        }
    };
    assert_eq!(logits1, reference[1], "evicted row diverged from sequential");
    assert_eq!(kv1.pos, 1);

    // no leaked pins anywhere (the pin ledger is balanced per row)
    let cache = eng.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "leaked lo-pool pins");
}

/// Aborting a suspended batch releases every remaining row's pins.
#[test]
fn batch_abort_releases_all_pins() {
    let name = "abort";
    let dir = synth_dir(name);
    let slow = HardwareConfig { load_bw: 5e4, ..offload_hw() };
    let mut eng = mk_engine(name, &dir, slow, 0);
    let items: Vec<BatchItem> = (0..4)
        .map(|r| BatchItem { seq: None, token: stream(r, 0), kv: KvState::new(&eng.cfg) })
        .collect();
    let mut cur = eng.decode_begin_batch(items).expect("begin");
    let progress = eng.decode_poll_batch(&mut cur).expect("poll");
    assert!(matches!(progress, BatchProgress::Pending));
    eng.decode_abort_batch(cur);
    let cache = eng.residency.cache_handle();
    let c = cache.lock().unwrap();
    assert_eq!(c.hi.pinned_count(), 0, "abort leaked hi-pool pins");
    assert_eq!(c.lo.pinned_count(), 0, "abort leaked lo-pool pins");
}
