//! End-to-end expert-record integrity: checksums at every tier boundary,
//! deterministic fault injection, and self-healing re-fetch.
//!
//! Everything here is artifact-free (synthetic stores / synthetic model),
//! in the style of `remote_tier.rs`. The per-tier detection unit tests
//! live next to the code (`remote::tiered`, `remote::shard`, `cache`,
//! `faults`); this suite covers the composed system:
//!
//! * **chaos-under-bit-identity** (the headline acceptance run): a full
//!   generation under a hostile seeded fault plan — a disk bit-flip, a
//!   truncated peer stream, a flipped peer reply, a stalled I/O lane, a
//!   corrupted in-flight transfer — produces logits byte-identical to the
//!   fault-free run, with the damage visible only in the integrity
//!   counters (and never in the FCFS report);
//! * **retry-exhaustion bypass**: when every re-acquire lands corrupt, the
//!   ticket resolves unfulfilled and the cache-bypass path still serves
//!   clean verified bytes — corruption degrades latency, never
//!   correctness or availability;
//! * **torn upgrade**: a corrupted in-place upgrade commit never touches
//!   the slot (the floor record keeps serving), heals within the bounded
//!   reheal budget, and aborts cleanly when the budget exhausts;
//! * **`hobbit verify-weights`**: the CLI scan passes on a clean store and
//!   fails (exit 1, FAIL line) on a deliberately flipped byte;
//! * **multi-process corrupt peer**: a real `shard-serve` child serving
//!   deliberately flipped records is quarantined at the frame checksum and
//!   healed from the disk tier, bit-identically.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hobbit::cache::{CacheManager, Policy, Pool};
use hobbit::config::{HardwareConfig, IoConfig, ModelConfig, PeerSpec, PolicyConfig, RemoteConfig};
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::{Engine, EngineOptions};
use hobbit::faults::FaultPlan;
use hobbit::loader::scorer::Class;
use hobbit::memory::{LinkModel, ThrottledCopier, ONDEMAND_WEIGHT};
use hobbit::model::synth::{
    tiny_model_config, tiny_store_config, write_store_manifest, write_synth_expert_store,
    write_synth_model,
};
use hobbit::model::ExpertStore;
use hobbit::predictor::Predictor;
use hobbit::remote::{RetryPolicy, ShardSpec, TieredStore};
use hobbit::residency::ExpertResidency;
use hobbit::tokenizer::BOS;
use hobbit::{ExpertKey, Precision};

// ---------------------------------------------------------------------
// Shared rigs
// ---------------------------------------------------------------------

/// Synthetic store on disk (4 layers x 4 experts) plus its manifest, so
/// `ExpertStore::load` verifies against real checksums.
fn synth_store(name: &str) -> (ModelConfig, PathBuf, Arc<ExpertStore>) {
    let cfg = tiny_store_config(name);
    let dir = std::env::temp_dir().join(format!("hobbit_integrity_{name}"));
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    write_store_manifest(&dir, &cfg).expect("manifest");
    let store = Arc::new(ExpertStore::load(&dir, &cfg).unwrap());
    (cfg, dir, store)
}

/// Residency facade over a (possibly fault-injected) tiered store.
fn mk_residency(
    tiered: Arc<TieredStore>,
    progressive: bool,
) -> (ExpertResidency, Arc<ThrottledCopier>) {
    let cfg = tiered.config().clone();
    let cache = Arc::new(Mutex::new(CacheManager::new(
        cfg.n_layers,
        cfg.n_experts,
        8,
        cfg.bytes_for(Precision::F32),
        4,
        cfg.bytes_for(Precision::Q8),
        Policy::Lru,
        0.25,
    )));
    let copier = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: 1e9, latency_s: 0.0 }));
    let predictor = Predictor::new(2, cfg.top_k, 0.6, 0.9, true, cfg.n_layers);
    let resid = ExpertResidency::with_tiered(
        tiered,
        cache,
        copier.clone(),
        predictor,
        Precision::F32,
        Precision::Q8,
        IoConfig { lanes: 2, chunk_bytes: 1024, ..IoConfig::default() },
    )
    .with_precision_mode(None, progressive, 0.6);
    (resid, copier)
}

fn drain(resid: &ExpertResidency) {
    let t0 = Instant::now();
    while !resid.is_idle() {
        assert!(t0.elapsed() < Duration::from_secs(30), "loader never drained");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------
// (a) retry-exhaustion bypass: corruption degrades latency, never
//     correctness or availability
// ---------------------------------------------------------------------

#[test]
fn exhausted_heals_resolve_unfulfilled_and_bypass_serves_clean_bytes() {
    let (_cfg, _dir, store) = synth_store("bypass");
    // EVERY fresh transfer flips a bit: the initial attempt and all three
    // re-acquires land corrupt, so the heal budget must exhaust
    let plan = Arc::new(FaultPlan::parse("3:flip@xfer#*").unwrap());
    let tiered =
        Arc::new(TieredStore::local_only(store.clone()).with_faults(Some(plan.clone())));
    let (resid, _copier) = mk_residency(tiered.clone(), false);

    let key = ExpertKey::new(1, 2);
    let (_u, waits) = resid.acquire(1, vec![(key, Class::Hi, vec![1.0], 0.0)], None);
    assert_eq!(waits.len(), 1, "the miss must submit a load");
    resid.wait(&waits);
    let t = &waits.tickets()[0];
    assert!(t.is_ready(), "an exhausted ticket still resolves — waiters never wedge");
    assert!(!t.is_fulfilled(), "every attempt was corrupt; the ticket must be unfulfilled");
    assert!(
        resid.resident_record(key, Pool::Hi).is_none(),
        "a quarantined expert must never be served from the cache"
    );

    // 1 initial attempt + 3 re-acquires, all corrupt-at-commit
    let st = resid.loader_stats();
    assert_eq!(st.integrity_failures, 4, "failures: {st:?}");
    assert_eq!(st.quarantined_slots, 4);
    assert_eq!(st.integrity_refetches, 3, "one heal per re-acquire");

    // availability: the bypass path reads the tier hierarchy directly —
    // transfer faults live on the loader's lanes, so the bytes are clean
    // and verified
    let rec = tiered.fetch(key, Precision::F32, ONDEMAND_WEIGHT);
    assert_eq!(rec.as_slice(), store.record(key, Precision::F32), "bypass bytes diverged");
    assert!(plan.injected() >= 4);
    resid.release(key, Pool::Hi);
}

// ---------------------------------------------------------------------
// (b) a corrupt commit heals transparently: one flip, one re-acquire,
//     byte-identical residency
// ---------------------------------------------------------------------

#[test]
fn single_corrupt_commit_heals_and_serves_identical_bytes() {
    let (_cfg, _dir, store) = synth_store("heal");
    let plan = Arc::new(FaultPlan::parse("11:flip@xfer#1").unwrap());
    let tiered = Arc::new(TieredStore::local_only(store.clone()).with_faults(Some(plan)));
    let (resid, _copier) = mk_residency(tiered, false);

    let key = ExpertKey::new(2, 3);
    let (_u, waits) = resid.acquire(2, vec![(key, Class::Hi, vec![1.0], 0.0)], None);
    resid.wait(&waits);
    assert!(waits.tickets()[0].is_fulfilled(), "one corrupt commit must heal, not exhaust");
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("resident after heal");
    assert_eq!(tier, Precision::F32);
    assert_eq!(&bytes[..], store.record(key, Precision::F32), "healed bytes diverged");
    let st = resid.loader_stats();
    assert_eq!(st.integrity_failures, 1);
    assert_eq!(st.quarantined_slots, 1);
    assert_eq!(st.integrity_refetches, 1);
    resid.release(key, Pool::Hi);
}

// ---------------------------------------------------------------------
// (c) torn upgrades: the slot never regresses, heals are bounded
// ---------------------------------------------------------------------

#[test]
fn torn_upgrade_heals_within_budget_and_lands_exact_hi_bytes() {
    let (_cfg, _dir, store) = synth_store("tear_heal");
    let plan = Arc::new(FaultPlan::parse("5:tear@upgrade#1").unwrap());
    let tiered = Arc::new(TieredStore::local_only(store.clone()).with_faults(Some(plan)));
    let (resid, _copier) = mk_residency(tiered, true);

    // tolerant progressive miss: Q8 floor now, F32 upgrade behind it —
    // the first upgrade commit is torn, the reheal lands clean
    let key = ExpertKey::new(0, 1);
    let (_u, waits) = resid.acquire(0, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
    resid.wait(&waits);
    drain(&resid);
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("resident");
    assert_eq!(tier, Precision::F32, "the healed upgrade must land");
    assert_eq!(&bytes[..], store.record(key, Precision::F32), "upgraded bytes diverged");
    let st = resid.loader_stats();
    assert_eq!(st.integrity_failures, 1);
    assert_eq!(st.integrity_refetches, 1);
    assert_eq!(st.upgrades_committed, 1);
    assert_eq!(st.upgrades_aborted, 0);
    assert_eq!(st.quarantined_slots, 0, "a torn upgrade never touches the slot");
    resid.release(key, Pool::Hi);
}

#[test]
fn torn_upgrade_exhausts_reheal_budget_and_keeps_serving_the_floor() {
    let (_cfg, _dir, store) = synth_store("tear_abort");
    // EVERY upgrade commit is torn: initial + MAX_INTEGRITY_HEALS reheals
    let plan = Arc::new(FaultPlan::parse("5:tear@upgrade#*").unwrap());
    let tiered = Arc::new(TieredStore::local_only(store.clone()).with_faults(Some(plan)));
    let (resid, _copier) = mk_residency(tiered, true);

    let key = ExpertKey::new(0, 2);
    let (_u, waits) = resid.acquire(0, vec![(key, Class::Hi, vec![1.0], 1.0)], None);
    resid.wait(&waits);
    drain(&resid);
    // the upgrade never lands, but the floor record keeps serving —
    // valid, verified lo-tier bytes
    let (tier, bytes) = resid.resident_record(key, Pool::Hi).expect("floor still resident");
    assert_eq!(tier, Precision::Q8, "an aborted upgrade must leave the floor tier");
    assert_eq!(&bytes[..], store.record(key, Precision::Q8), "floor bytes diverged");
    let st = resid.loader_stats();
    assert_eq!(st.integrity_failures, 3, "initial + 2 bounded reheals");
    assert_eq!(st.integrity_refetches, 2);
    assert_eq!(st.upgrades_committed, 0);
    assert_eq!(st.upgrades_aborted, 1, "budget exhaustion must abort, not loop");
    resid.release(key, Pool::Hi);
}

// ---------------------------------------------------------------------
// (d) `hobbit verify-weights`: clean pass, flipped-byte fail
// ---------------------------------------------------------------------

#[test]
fn verify_weights_cli_catches_a_flipped_byte() {
    let cfg = tiny_store_config("verify_cli");
    let dir = std::env::temp_dir().join("hobbit_integrity_verify_cli");
    write_synth_expert_store(&dir, &cfg).expect("synth store");
    write_store_manifest(&dir, &cfg).expect("manifest");

    let run = || {
        Command::new(env!("CARGO_BIN_EXE_hobbit"))
            .args(["verify-weights", "--weights", dir.to_str().unwrap()])
            .output()
            .expect("run verify-weights")
    };
    let clean = run();
    assert!(clean.status.success(), "clean store must pass: {clean:?}");
    let stdout = String::from_utf8_lossy(&clean.stdout).to_string();
    assert!(stdout.contains("0 failed"), "unexpected clean output: {stdout}");

    // flip one bit of one q4 record on disk
    let path = dir.join("experts_q4.bin");
    let mut bytes = std::fs::read(&path).unwrap();
    let rb = cfg.bytes_for(Precision::Q4);
    bytes[rb * 5 + 17] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();

    let bad = run();
    assert!(!bad.status.success(), "corrupt store must exit nonzero");
    let stdout = String::from_utf8_lossy(&bad.stdout).to_string();
    assert!(stdout.contains("FAIL"), "no FAIL line in: {stdout}");
    assert!(stdout.contains("1 failed"), "exactly one record was flipped: {stdout}");
}

// ---------------------------------------------------------------------
// (e) the chaos acceptance run + multi-process corrupt peer
// ---------------------------------------------------------------------

const MP_STEPS: usize = 16;

struct KillOnDrop(Vec<Child>);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Spawn `hobbit shard-serve` (optionally with a fault plan) and parse
/// the bound address from its banner line.
fn spawn_shard_server(dir: &Path, shard: &str, fault_plan: Option<&str>) -> (Child, String) {
    let mut args = vec![
        "shard-serve".to_string(),
        "--weights".into(),
        dir.to_str().unwrap().into(),
        "--shard".into(),
        shard.into(),
        "--addr".into(),
        "127.0.0.1:0".into(),
    ];
    if let Some(fp) = fault_plan {
        args.push("--fault-plan".into());
        args.push(fp.into());
    }
    let mut child = Command::new(env!("CARGO_BIN_EXE_hobbit"))
        .args(&args)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn shard-serve");
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().expect("child stdout"))
        .read_line(&mut line)
        .expect("read shard-serve banner");
    let addr = line
        .trim()
        .strip_prefix("shard-serve listening on ")
        .unwrap_or_else(|| panic!("unexpected shard-serve banner: {line:?}"))
        .to_string();
    (child, addr)
}

/// Reference engine over the synthesized model, pinned F32 (progressive
/// logits are timing-dependent; pinned generation is bit-deterministic,
/// so healing must reproduce it exactly).
fn reference_engine(
    dir: &Path,
    remote: Option<RemoteConfig>,
    faults: Option<Arc<FaultPlan>>,
    watchdog_ms: u64,
) -> Engine {
    let cfg = tiny_model_config("integrity-mp");
    let hw = HardwareConfig {
        name: "integrity-mp".into(),
        load_bw: 64e9,
        load_latency: 0.0,
        hi_cache_experts: 4,
        lo_cache_experts: 4,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    let policy = PolicyConfig {
        dynamic_loading: false,
        pin_precision: Some(Precision::F32),
        prefetch_depth: 0,
        ..PolicyConfig::default()
    };
    let mut opts = EngineOptions::new(hw, policy);
    opts.remote = remote;
    opts.faults = faults;
    opts.io.watchdog_ms = watchdog_ms;
    Engine::new_reference(dir, cfg, opts).expect("reference engine")
}

fn remote_cfg(peers: Vec<PeerSpec>) -> RemoteConfig {
    RemoteConfig {
        local_shard: ShardSpec::parse("none").unwrap(),
        peers,
        net_bw: 1e9,
        net_latency: 0.0,
        retry: RetryPolicy::fast(),
        cooldown: Duration::from_millis(300),
        ..RemoteConfig::default()
    }
}

fn mp_token(i: usize) -> u32 {
    (65 + (i * 7) % 50) as u32
}

fn generate_logits(eng: &mut Engine) -> Vec<Vec<f32>> {
    let mut kv = eng.new_sequence();
    let mut out = Vec::with_capacity(MP_STEPS + 1);
    out.push(eng.prefill(&mut kv, &[BOS, 72, 101]).expect("prefill"));
    for i in 0..MP_STEPS {
        out.push(eng.decode_step(&mut kv, mp_token(i)).expect("decode"));
    }
    out
}

/// The headline acceptance run: a hostile seeded fault plan on both sides
/// of the wire — the peer truncates one stream and flips one reply, the
/// client flips its first disk read, stalls an I/O lane past the watchdog
/// period, and corrupts one in-flight transfer — and the generated logits
/// are byte-identical to the fault-free run.
#[test]
fn chaos_generation_is_bit_identical_to_the_fault_free_run() {
    let dir = std::env::temp_dir().join("hobbit_integrity_chaos");
    let cfg = tiny_model_config("integrity-mp");
    write_synth_model(&dir, &cfg, 0xC0FFEE).expect("synth model");
    write_store_manifest(&dir, &cfg).expect("manifest");

    // fault-free single-node baseline, and the all-counters-zero check
    let mut clean = reference_engine(&dir, None, None, 0);
    let want = generate_logits(&mut clean);
    let st = clean.residency.loader_stats();
    assert_eq!(st.integrity_failures, 0, "no faults => no failures");
    assert_eq!(st.integrity_refetches, 0);
    assert_eq!(st.quarantined_slots, 0);
    assert_eq!(st.watchdog_recoveries, 0);

    // one real shard-server child owning every expert, seeded to truncate
    // its first reply and flip its second
    let (child, addr) = spawn_shard_server(&dir, "all", Some("7:trunc@peer#1,flip@peer#2"));
    let _guard = KillOnDrop(vec![child]);
    let rc = remote_cfg(vec![PeerSpec { addr, shard: ShardSpec::parse("all").unwrap() }]);

    // client-side plan: first disk read flipped, first transfer stalled
    // past the 250 ms watchdog, third transfer corrupted in flight
    let plan =
        Arc::new(FaultPlan::parse("42:flip@disk#1,stall@xfer#1:600ms,flip@xfer#3").unwrap());
    let mut chaos = reference_engine(&dir, Some(rc), Some(plan.clone()), 250);
    let got = generate_logits(&mut chaos);
    assert_eq!(want, got, "corruption must never reach the logits");

    let st = chaos.residency.loader_stats();
    assert!(st.integrity_failures > 0, "the plan must have fired: {st:?}");
    assert!(st.integrity_refetches > 0, "every failure must heal: {st:?}");
    assert!(st.watchdog_recoveries >= 1, "the 600 ms stall must trip the 250 ms watchdog");
    assert!(plan.injected() >= 3, "client-side faults fired {}", plan.injected());
}

/// A peer that corrupts EVERY reply is quarantined at the frame checksum
/// and healed from the disk tier — a whole generation stays bit-identical
/// to the fault-free local run.
#[test]
fn corrupt_peer_process_is_quarantined_and_healed_from_disk() {
    let dir = std::env::temp_dir().join("hobbit_integrity_badpeer");
    let cfg = tiny_model_config("integrity-mp");
    write_synth_model(&dir, &cfg, 0xC0FFEE).expect("synth model");
    write_store_manifest(&dir, &cfg).expect("manifest");

    let mut local = reference_engine(&dir, None, None, 0);
    let want = generate_logits(&mut local);

    let (child, addr) = spawn_shard_server(&dir, "all", Some("9:flip@peer#*"));
    let _guard = KillOnDrop(vec![child]);
    let rc = remote_cfg(vec![PeerSpec { addr, shard: ShardSpec::parse("all").unwrap() }]);
    let mut eng = reference_engine(&dir, Some(rc), None, 0);
    let got = generate_logits(&mut eng);
    assert_eq!(want, got, "a corrupt peer must never change the logits");

    let st = eng.residency.loader_stats();
    assert!(st.integrity_failures > 0, "corrupt frames must be counted: {st:?}");
    assert!(st.integrity_refetches > 0, "every quarantine must heal: {st:?}");
    assert!(st.disk_fetches > 0, "heals must come from the disk tier: {st:?}");
    assert_eq!(st.remote_fetches, 0, "no corrupt frame may ever count as a good fetch");
}

// ---------------------------------------------------------------------
// (f) the FCFS report stays frozen: integrity lives under "serving" only
// ---------------------------------------------------------------------

#[test]
fn fcfs_report_json_is_unchanged_by_the_integrity_layer() {
    let dir = std::env::temp_dir().join("hobbit_integrity_fcfs");
    let cfg = tiny_model_config("integrity-mp");
    write_synth_model(&dir, &cfg, 0xC0FFEE).expect("synth model");
    write_store_manifest(&dir, &cfg).expect("manifest");
    let engine = reference_engine(&dir, None, None, 0);
    let mut coord = Coordinator::new(engine);
    coord.generate(&Request::new(1, "integrity probe", 4)).expect("generate");
    coord.sync_report();
    let json = coord.report.to_json().to_string();
    assert!(
        !json.contains("integrity") && !json.contains("quarantined") && !json.contains("watchdog"),
        "FCFS report grew integrity keys: {json}"
    );
}
