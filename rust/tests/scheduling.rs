//! Interleaved-scheduler integration: determinism of expert execution
//! order, interleaved-vs-FCFS output equivalence, and concurrent serving
//! over the threaded TCP front-end, on the real engine (skips without
//! artifacts).
//!
//! The equivalence/serving tests run with dynamic loading off: every
//! selected expert then executes in high precision regardless of cache
//! state, so the logits depend only on each sequence's own token history —
//! interleaving order, link speed, and cache pressure must not change any
//! client's completion.

use std::path::PathBuf;

use hobbit::baselines;
use hobbit::config::HardwareConfig;
use hobbit::coordinator::{Coordinator, Request};
use hobbit::engine::Engine;
use hobbit::server::{client_request, Server};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_root().join("mixtral-tiny/manifest.json").exists()
}

fn fast_hw() -> HardwareConfig {
    HardwareConfig {
        name: "test-fast".into(),
        load_bw: 16e9,
        load_latency: 0.0,
        hi_cache_experts: 24,
        lo_cache_experts: 24,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

/// Offload-bound profile: slow link + small caches, so decode stalls on
/// on-demand expert transfers (the regime interleaving is built for).
fn offload_hw() -> HardwareConfig {
    HardwareConfig {
        name: "test-offload".into(),
        load_bw: 2.5e8,
        load_latency: 0.0,
        hi_cache_experts: 6,
        lo_cache_experts: 6,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    }
}

const PROMPTS: [&str; 4] = [
    "alpha request one",
    "bravo request two",
    "charlie request three",
    "delta request four",
];

/// Ground truth: a fresh engine serving each prompt alone, batch-1 FCFS,
/// greedy.
fn reference_texts(max_new: usize) -> Vec<String> {
    let engine = Engine::new(
        &artifacts_root(),
        "mixtral-tiny",
        baselines::real_no_dynamic(fast_hw()),
    )
    .unwrap();
    let mut coord = Coordinator::new(engine);
    PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| coord.generate(&Request::new(i as u64 + 1, *p, max_new)).unwrap().text)
        .collect()
}

#[test]
fn expert_execution_order_is_deterministic() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // prefetch off: with blocking on-demand loads the cache evolves
    // identically across runs, so the only cross-run variation left
    // (before the BTreeMap fix) was HashMap iteration order of the
    // per-layer expert set — i.e. FFN accumulation order
    let run = || -> Vec<Vec<f32>> {
        let mut engine = Engine::new(
            &artifacts_root(),
            "mixtral-tiny",
            baselines::real_no_prefetch(fast_hw()),
        )
        .unwrap();
        let mut kv = engine.new_sequence();
        let tokens = hobbit::tokenizer::Tokenizer::new().encode("determinism probe text");
        let mut out = vec![engine.prefill(&mut kv, &tokens).unwrap()];
        for t in [65u32, 66, 67, 68] {
            out.push(engine.decode_step(&mut kv, t).unwrap());
        }
        out
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), b.len());
    for (i, (la, lb)) in a.iter().zip(&b).enumerate() {
        assert_eq!(la, lb, "logits diverged at step {i}");
    }
}

#[test]
fn interleaved_drain_matches_fcfs_reference() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let max_new = 6;
    let reference = reference_texts(max_new);
    let engine = Engine::new(
        &artifacts_root(),
        "mixtral-tiny",
        baselines::real_no_dynamic(offload_hw()),
    )
    .unwrap();
    let mut coord = Coordinator::interleaved(engine);
    for (i, p) in PROMPTS.iter().enumerate() {
        coord.submit(Request::new(i as u64 + 1, *p, max_new));
    }
    assert_eq!(coord.pending(), PROMPTS.len());
    let mut results = coord.drain().unwrap();
    assert_eq!(results.len(), PROMPTS.len());
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert_eq!(&r.text, want, "interleaved decode diverged for request {}", r.id);
    }
    // scheduler aggregates are present and consistent
    let sch = coord.report.scheduler.as_ref().expect("serving stats in report");
    assert_eq!(sch.completed, PROMPTS.len() as u64);
    let decoded: u64 = results.iter().map(|r| r.tokens.len() as u64).sum();
    assert_eq!(sch.decoded_tokens, decoded);
    assert!(sch.busy_wall.as_secs_f64() > 0.0);
    assert_eq!(coord.report.requests.len(), PROMPTS.len());
}

#[test]
fn concurrent_clients_get_correct_deterministic_completions() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let max_new = 6usize;
    let reference = reference_texts(max_new);
    let engine = Engine::new(
        &artifacts_root(),
        "mixtral-tiny",
        baselines::real_no_dynamic(offload_hw()),
    )
    .unwrap();
    let mut coord = Coordinator::interleaved(engine);
    let mut server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();

    // 4 concurrent GEN clients + 1 STATS client. The listener is bound
    // before the threads start, so connects queue in the accept backlog.
    let clients: Vec<_> = PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let addr = addr.clone();
            let prompt = p.to_string();
            std::thread::spawn(move || {
                let r = client_request(&addr, &format!("GEN {max_new} 0 {prompt}")).unwrap();
                (i, r)
            })
        })
        .collect();
    let stats_addr = addr.clone();
    let stats = std::thread::spawn(move || client_request(&stats_addr, "STATS").unwrap());

    server.serve_concurrent(&mut coord, Some(PROMPTS.len() + 1)).unwrap();

    for c in clients {
        let (i, r) = c.join().unwrap();
        assert!(r.get("error").is_none(), "client {i}: {r:?}");
        assert_eq!(
            r.get("text").unwrap().as_str().unwrap(),
            reference[i],
            "client {i} got a different completion than the FCFS reference"
        );
        assert!(r.get("decode_tps").unwrap().as_f64().unwrap() >= 0.0);
    }
    let st = stats.join().unwrap();
    assert!(st.get("mean_decode_tps").is_some(), "{st:?}");

    assert_eq!(coord.report.requests.len(), PROMPTS.len());
    let sch = coord.report.scheduler.as_ref().expect("serving stats");
    assert_eq!(sch.completed, PROMPTS.len() as u64);
}
