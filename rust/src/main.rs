//! HOBBIT leader entrypoint: serve / generate / figures / sim / selfcheck.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use hobbit::baselines::{self, EQ3_WEIGHTS};
use hobbit::cache::Policy;
use hobbit::cli::{Args, USAGE};
use hobbit::config::{
    validate_max_batch, HardwareConfig, ModelConfig, PolicyConfig, RemoteConfig,
};
use hobbit::coordinator::{Coordinator, Request, SchedPolicy, SchedulerMode};
use hobbit::engine::Engine;
use hobbit::faults::FaultPlan;
use hobbit::figures;
use hobbit::model::ExpertStore;
use hobbit::remote::{ShardServer, ShardSpec};
use hobbit::server::Server;
use hobbit::sim::des::{simulate_decode, SimSystem};
use hobbit::sim::params::{SimHardware, SimModel};
use hobbit::trace::{generate as gen_traces, TraceGenConfig};
use hobbit::util::json::Json;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return;
    }
    let cmd = argv.remove(0);
    let args = Args::parse(
        argv,
        &[
            "all",
            "no-dynamic",
            "no-prefetch",
            "report",
            "interleaved",
            "no-chunked-prefill",
            "prefill-first",
            "progressive",
            "no-ladder",
            "no-grouped",
            "verbose",
        ],
    );
    let r = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "shard-serve" => cmd_shard_serve(&args),
        "generate" => cmd_generate(&args),
        "figures" => cmd_figures(&args),
        "sim" => cmd_sim(&args),
        "selfcheck" => cmd_selfcheck(&args),
        "verify-weights" => cmd_verify_weights(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `allow_sched_policy`: whether `--policy rr|sjf` is meaningful for the
/// calling command (`serve --interleaved`); everywhere else those names
/// are rejected instead of silently doing nothing.
fn build_engine(args: &Args, allow_sched_policy: bool) -> Result<Engine> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let model = args.get_or("model", "mixtral-tiny");
    let hw = HardwareConfig::preset(args.get_or("hardware", "rtx4090"))
        .ok_or_else(|| anyhow!("unknown hardware preset"))?;
    let mut opts = if args.has("no-dynamic") {
        baselines::real_no_dynamic(hw)
    } else if args.has("no-prefetch") {
        baselines::real_no_prefetch(hw)
    } else {
        baselines::real_hobbit(hw)
    };
    if let Some(p) = args.get("policy") {
        // scheduler fairness names (rr/sjf) are handled by `serve`, not
        // the cache-policy table
        if SchedPolicy::from_name(p).is_some() {
            if !allow_sched_policy {
                return Err(anyhow!(
                    "--policy {p} is a scheduler policy and applies to \
                     `serve --interleaved` only (cache policies: \
                     lru|lfu|lfu-model|lhu|fld|random|multidim)"
                ));
            }
        } else {
            opts.cache_policy = Some(
                Policy::from_name(p, EQ3_WEIGHTS).ok_or_else(|| anyhow!("unknown policy"))?,
            );
        }
    }
    if let Some(group) = args.get("precision-group") {
        if group == "int8" {
            opts.policy = PolicyConfig {
                dynamic_loading: opts.policy.dynamic_loading,
                prefetch_depth: opts.policy.prefetch_depth,
                ..PolicyConfig::int8_group()
            };
        }
    }
    // transfer-pipeline knobs: lanes sharing the link + preemption
    // granularity (defaults: 2 lanes, 256 KiB chunks)
    opts.io.lanes = args.get_usize("io-lanes", opts.io.lanes);
    opts.io.chunk_bytes = args.get_usize("io-chunk-bytes", opts.io.chunk_bytes);
    opts.io.validate().map_err(|e| anyhow!("{e}"))?;
    // precision scheduling: freeze the per-acquire fetch precision, or
    // stream low-bits-first with background upgrades (mutually exclusive;
    // PolicyConfig::validate rejects the combination)
    if let Some(name) = args.get("pin-precision") {
        let p = hobbit::Precision::from_name(name)
            .ok_or_else(|| anyhow!("unknown precision '{name}' (f32|q8|q4|q2)"))?;
        opts.policy.pin_precision = Some(p);
    }
    if args.has("progressive") {
        opts.policy.progressive = true;
    }
    // ragged grouped expert execution (default on): batched decode runs
    // at its exact row count, one grouped FFN pass per layer
    opts.grouped = !args.has("no-grouped");
    // hot-expert read-replica budget (0 = replication off)
    opts.max_replicas = args.get_usize("max-replicas", 0);
    // remote expert tier: this node's DRAM shard + peer shard servers +
    // the network link budget (validated as a disjoint, complete
    // partition at engine construction)
    let net_gbps = match args.get("net-gbps") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| anyhow!("bad --net-gbps '{v}'"))?),
        None => None,
    };
    opts.remote = RemoteConfig::from_flags(args.get("peers"), args.get("shard"), net_gbps)
        .map_err(|e| anyhow!("{e}"))?;
    // deterministic fault injection: seeded corruption/stall/tear events
    // at the tier boundaries, exercising the integrity layer's
    // quarantine-and-heal path (see DESIGN.md)
    opts.faults = parse_fault_plan(args)?;
    Engine::new(&artifacts, model, opts)
}

/// `--fault-plan seed:spec` (e.g. `42:flip@disk#1,stall@xfer#2:50ms`).
fn parse_fault_plan(args: &Args) -> Result<Option<std::sync::Arc<FaultPlan>>> {
    match args.get("fault-plan") {
        Some(s) => Ok(Some(std::sync::Arc::new(
            FaultPlan::parse(s).map_err(|e| anyhow!("{e}"))?,
        ))),
        None => Ok(None),
    }
}

/// `verify-weights`: scan a weight directory's records against the
/// manifest checksums; nonzero exit when any record fails.
fn cmd_verify_weights(args: &Args) -> Result<()> {
    let dir = PathBuf::from(
        args.get("weights").ok_or_else(|| anyhow!("verify-weights needs --weights DIR"))?,
    );
    let report = hobbit::model::verify_weights_dir(&dir)?;
    for r in &report.records {
        if !r.ok || args.has("verbose") {
            println!(
                "{} L{}E{} {}: {}",
                if r.ok { "PASS" } else { "FAIL" },
                r.key.layer,
                r.key.expert,
                r.precision.name(),
                if r.ok { "checksum ok" } else { "checksum mismatch" },
            );
        }
    }
    println!(
        "verify-weights: {} records, {} passed, {} failed",
        report.records.len(),
        report.passed,
        report.failed
    );
    if !report.all_ok() {
        return Err(anyhow!("{} corrupt record(s) in {}", report.failed, dir.display()));
    }
    Ok(())
}

/// `shard-serve`: run one expert shard server over a weight directory —
/// the peer side of the remote expert tier. The model shape comes from
/// `manifest.json` next to the weight files
/// (`model::synth::write_store_manifest` / the AOT export both write it).
fn cmd_shard_serve(args: &Args) -> Result<()> {
    let weights = PathBuf::from(
        args.get("weights").ok_or_else(|| anyhow!("shard-serve needs --weights DIR"))?,
    );
    let manifest_path = weights.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| anyhow!("reading {}: {e}", manifest_path.display()))?;
    let manifest = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
    let cfg = ModelConfig::from_manifest(&manifest).map_err(|e| anyhow!("manifest: {e}"))?;
    let shard = ShardSpec::parse(args.get_or("shard", "all")).map_err(|e| anyhow!("{e}"))?;
    let store = std::sync::Arc::new(ExpertStore::load(&weights, &cfg)?);
    let chunk = args.get_usize("net-chunk-bytes", hobbit::remote::shard::DEFAULT_CHUNK_BYTES);
    let server = ShardServer::bind(args.get_or("addr", "127.0.0.1:0"), store, shard, chunk)?
        .with_faults(parse_fault_plan(args)?);
    // exact line the multi-process suite (and any orchestrator) parses
    println!("shard-serve listening on {}", server.local_addr());
    server.serve()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let interleaved = args.has("interleaved");
    let sched = args.get("policy").and_then(SchedPolicy::from_name);
    if sched.is_some() && !interleaved {
        return Err(anyhow!(
            "--policy {} schedules interleaved serving; add --interleaved",
            args.get("policy").unwrap_or_default()
        ));
    }
    let max_batch = args.get_usize("max-batch", 1);
    if max_batch > 1 && !interleaved {
        return Err(anyhow!(
            "--max-batch batches the interleaved scheduler; add --interleaved"
        ));
    }
    validate_max_batch(max_batch, !args.has("no-grouped")).map_err(|e| anyhow!("{e}"))?;
    let engine = build_engine(args, true)?;
    let mut coord = Coordinator::new(engine);
    if interleaved {
        coord.mode = SchedulerMode::Interleaved;
        coord.max_active = args.get_usize("max-active", coord.max_active);
        coord.max_batch = max_batch;
        // a batch wider than the live-set cap can never fill
        coord.max_active = coord.max_active.max(coord.max_batch);
        if let Some(p) = sched {
            coord.sched_policy = p;
        }
        coord.chunked_prefill = !args.has("no-chunked-prefill");
        coord.prefill_first = args.has("prefill-first");
        coord.token_budget = args.get_usize("token-budget", coord.token_budget).max(1);
        coord.ttft_deadline = std::time::Duration::from_millis(
            args.get_usize("ttft-deadline-ms", coord.ttft_deadline.as_millis() as usize)
                .max(1) as u64,
        );
        // overload control: bounded admission + the degradation ladder
        // (shed precision, then prefetch, then admissions)
        if let Some(limit) = args.get("admission-limit") {
            let n: usize =
                limit.parse().map_err(|_| anyhow!("bad --admission-limit '{limit}'"))?;
            coord.overload.queue_limit = Some(n);
        }
        if let Some(ms) = args.get("slo-ttft-ms") {
            let n: u64 = ms.parse().map_err(|_| anyhow!("bad --slo-ttft-ms '{ms}'"))?;
            coord.overload.slo_ttft = Some(std::time::Duration::from_millis(n));
        }
        coord.overload.ladder = !args.has("no-ladder");
        coord.overload.validate().map_err(|e| anyhow!("{e}"))?;
    }
    let addr = args.get_or("addr", "127.0.0.1:7077");
    let mut server = Server::bind(addr)?;
    server.set_client_timeout(std::time::Duration::from_millis(
        args.get_usize("client-timeout-ms", 30_000).max(1) as u64,
    ));
    server.set_max_conn_threads(args.get_usize("max-conn-threads", 256));
    println!(
        "hobbit serving on {} (platform: {}, scheduler: {}{})",
        server.local_addr()?,
        coord.engine.platform(),
        match (interleaved, coord.sched_policy) {
            (false, _) => "fcfs",
            (true, SchedPolicy::RoundRobin) => "interleaved/rr",
            (true, SchedPolicy::Sjf) => "interleaved/sjf",
            (true, SchedPolicy::TokenBudget) => "interleaved/token-budget",
            (true, SchedPolicy::Deadline) => "interleaved/deadline",
        },
        if coord.max_batch > 1 {
            format!(
                ", max-batch {} (exec {}, native widths {:?})",
                coord.max_batch,
                coord.engine.exec_mode(),
                coord.engine.native_batch_widths()
            )
        } else {
            String::new()
        },
    );
    let max_conns = args.get("max-conns").and_then(|v| v.parse().ok());
    if interleaved {
        server.serve_concurrent(&mut coord, max_conns)?;
    } else {
        server.serve(&mut coord, max_conns)?;
    }
    coord.sync_report();
    println!("{}", coord.report.to_json().to_string());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let engine = build_engine(args, false)?;
    let mut coord = Coordinator::new(engine);
    let req = Request {
        id: 1,
        prompt: args.get_or("prompt", "The mixture of experts").to_string(),
        max_new_tokens: args.get_usize("max-new", 32),
        temperature: args.get_f64("temp", 0.8) as f32,
    };
    let r = coord.generate(&req)?;
    println!("generated {} tokens: {:?}", r.tokens.len(), r.text);
    println!(
        "prefill {:.3}s | decode {:.2} tok/s | compute {:.3}s | load-wait {:.3}s",
        r.metrics.prefill_time.as_secs_f64(),
        r.metrics.decode_tps(),
        r.metrics.compute_time.as_secs_f64(),
        r.metrics.load_wait_time.as_secs_f64(),
    );
    if args.has("report") {
        coord.sync_report();
        println!("{}", coord.report.to_json().to_string());
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let model = args.get_or("model", "mixtral-tiny");
    let which = args.get_or("fig", if args.has("all") { "all" } else { "" });
    if which.is_empty() {
        return Err(anyhow!("pass --fig <id> or --all"));
    }
    let all = which == "all" || args.has("all");
    let want = |id: &str| all || which == id;

    // trace/sim figures (no artifacts needed)
    if want("3a") {
        figures::endtoend::fig3a();
    }
    if want("9") {
        figures::endtoend::fig9();
    }
    if want("10") {
        figures::analysis::fig10();
    }
    if want("11") {
        figures::analysis::fig11();
    }
    if want("14") {
        figures::endtoend::fig14();
    }
    if want("15") {
        figures::endtoend::fig15();
    }
    if want("16") {
        figures::endtoend::fig16();
    }
    if want("17b") {
        figures::endtoend::fig17b();
    }
    if want("18a") {
        figures::analysis::fig18a(EQ3_WEIGHTS);
    }
    if want("18b") {
        figures::analysis::fig18b();
    }
    // live-engine figures
    let have_artifacts = artifacts.join(model).join("manifest.json").exists();
    if !have_artifacts && (all || ["3b", "5", "7", "17a", "table3"].contains(&which)) {
        eprintln!("(skipping live-engine figures: no artifacts at {})", artifacts.display());
        return Ok(());
    }
    if want("3b") {
        figures::real::fig3b(&artifacts, model)?;
    }
    if want("5") {
        figures::real::fig5(&artifacts, model)?;
    }
    if want("7") {
        figures::real::fig7(&artifacts, model)?;
    }
    if want("17a") {
        figures::real::fig17a(&artifacts, model)?;
    }
    if want("table3") {
        figures::real::table3(&artifacts, model)?;
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let hw = match args.get_or("hardware", "rtx4090") {
        "orin" => SimHardware::orin(),
        _ => SimHardware::rtx4090(),
    };
    let model = match args.get_or("model", "mixtral") {
        "phi" => SimModel::phi_moe(),
        _ => SimModel::mixtral_8x7b(),
    };
    let bits = if hw.name == "JetsonOrin" { 8.0 } else { 16.0 };
    let sys = match args.get_or("system", "hobbit") {
        "hobbit" | "hb" => {
            if bits == 8.0 {
                SimSystem::hobbit_int8(EQ3_WEIGHTS)
            } else {
                SimSystem::hobbit(EQ3_WEIGHTS)
            }
        }
        "mo" => SimSystem::moe_offloading(bits),
        "mi" => SimSystem::moe_infinity(bits),
        "tf" => SimSystem::dense("Transformers", bits),
        "ds" => SimSystem::dense("DeepSpeed", bits),
        "ll" => SimSystem::llama_cpp(bits),
        "fd" => SimSystem::fiddler(bits),
        other => return Err(anyhow!("unknown system '{other}'")),
    };
    let gen_cfg = if model.n_experts == 16 {
        TraceGenConfig::phi_like()
    } else {
        TraceGenConfig::mixtral_like()
    };
    let traces = gen_traces(&gen_cfg, args.get_usize("seqs", 3), args.get_usize("tokens", 64) as u32);
    let prompt = args.get_usize("prompt-len", 16);
    let (p, d) = simulate_decode(&sys, &hw, &model, &traces, prompt, 1);
    println!(
        "{} / {} / {}: prefill {:.3}s, decode {:.2} tok/s (load {:.1}%, {:.1} GB moved, {} skips)",
        sys.name,
        hw.name,
        model.name,
        p.latency,
        d.tps(),
        100.0 * d.load_fraction(),
        d.bytes_loaded / 1e9,
        d.skipped,
    );
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let artifacts = Path::new(args.get_or("artifacts", "artifacts")).to_path_buf();
    let model = args.get_or("model", "mixtral-tiny");
    println!("selfcheck: opening artifacts at {}/{model}", artifacts.display());
    let engine = build_engine(args, false)?;
    println!("  platform = {}", engine.platform());
    println!("  model    = {} ({} layers, {} experts/layer, top-{})",
        engine.cfg.name, engine.cfg.n_layers, engine.cfg.n_experts, engine.cfg.top_k);
    let mut coord = Coordinator::new(engine);
    let r = coord.generate(&Request::new(0, "selfcheck", 4))?;
    println!(
        "  generated {} tokens, prefill {:.3}s, decode {:.2} tok/s",
        r.tokens.len(),
        r.metrics.prefill_time.as_secs_f64(),
        r.metrics.decode_tps()
    );
    println!("selfcheck OK");
    Ok(())
}
