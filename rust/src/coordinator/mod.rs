//! The serving coordinator: request queue, sequence lifecycle, generation
//! loop, metrics. Follows the paper's evaluation protocol — batch size 1,
//! FCFS, prefill latency + decode tokens/s as the headline metrics (§5.1
//! "edge-side continuous serving scenarios often focus on single-batch
//! inference").

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{Engine, KvState};
use crate::metrics::{RequestMetrics, RunReport};
use crate::tensor::sample_logits;
use crate::tokenizer::{Tokenizer, EOS};
use crate::util::rng::Rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy
    pub temperature: f32,
}

impl Request {
    pub fn new(id: u64, prompt: impl Into<String>, max_new_tokens: usize) -> Self {
        Self { id, prompt: prompt.into(), max_new_tokens, temperature: 0.0 }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<u32>,
    pub metrics: RequestMetrics,
}

/// FCFS coordinator over one engine.
pub struct Coordinator {
    pub engine: Engine,
    pub tokenizer: Tokenizer,
    pub report: RunReport,
    queue: VecDeque<Request>,
    rng: Rng,
}

impl Coordinator {
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            tokenizer: Tokenizer::new(),
            report: RunReport::default(),
            queue: VecDeque::new(),
            rng: Rng::new(0xC0FFEE),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve every queued request FCFS; returns the results in order.
    pub fn drain(&mut self) -> Result<Vec<GenerationResult>> {
        let mut out = Vec::with_capacity(self.queue.len());
        while let Some(req) = self.queue.pop_front() {
            out.push(self.generate(&req)?);
        }
        Ok(out)
    }

    /// Run one request through prefill + decode.
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let mut prompt_tokens = self.tokenizer.encode(&req.prompt);
        let budget = self.engine.cfg.max_seq.saturating_sub(req.max_new_tokens + 1);
        if prompt_tokens.len() > budget {
            prompt_tokens.truncate(budget.max(1));
        }

        let mut kv: KvState = self.engine.new_sequence();
        let compute0 = self.engine.compute_time();
        let wait0 = self.engine.load_wait;

        let t0 = Instant::now();
        let mut logits = self.engine.prefill(&mut kv, &prompt_tokens)?;
        let prefill_time = t0.elapsed();

        let mut generated: Vec<u32> = Vec::with_capacity(req.max_new_tokens);
        let t1 = Instant::now();
        for _ in 0..req.max_new_tokens {
            if kv.remaining() == 0 {
                break;
            }
            let next = sample_logits(&logits, req.temperature, &mut self.rng) as u32;
            if next == EOS {
                break;
            }
            generated.push(next);
            logits = self.engine.decode_step(&mut kv, next)?;
        }
        let decode_time = t1.elapsed();

        let metrics = RequestMetrics {
            prompt_tokens: prompt_tokens.len(),
            generated_tokens: generated.len(),
            prefill_time,
            decode_time,
            compute_time: self.engine.compute_time().saturating_sub(compute0),
            load_wait_time: self.engine.load_wait.saturating_sub(wait0),
        };
        self.report.requests.push(metrics.clone());
        self.sync_report();

        Ok(GenerationResult {
            id: req.id,
            text: self.tokenizer.decode(&generated),
            tokens: generated,
            metrics,
        })
    }

    /// Pull loader/cache stats into the report.
    pub fn sync_report(&mut self) {
        self.report.loader = self.engine.loader.stats.lock().unwrap().clone();
        self.report.cache = self.engine.cache.lock().unwrap().stats.clone();
        let (h, t) = self.engine.predictor.tracker.per_offset[0];
        self.report.loader.prefetch_hits = h;
        self.report.loader.prefetch_total = self.report.loader.prefetch_total.max(t);
    }
}
