//! The serving coordinator: request queue, sequence lifecycle, generation
//! loop, metrics.
//!
//! Two scheduler modes:
//!
//! * [`SchedulerMode::Fcfs`] — the paper's evaluation protocol: batch
//!   size 1, FCFS, prefill latency + decode tokens/s as the headline
//!   metrics (§5.1 "edge-side continuous serving scenarios often focus on
//!   single-batch inference"). Every expert wait blocks on its residency
//!   tickets; the report JSON is byte-identical to the pre-scheduler
//!   format, so `figures/` and `baselines/` are unaffected.
//! * [`SchedulerMode::Interleaved`] — continuous serving: a set of live
//!   sequences (each with its own `KvState` and per-sequence residency
//!   session) is decoded round-robin (or shortest-job-first with
//!   [`SchedPolicy::Sjf`]), and expert waits are *non-blocking*:
//!   when sequence A's on-demand load is in flight, the scheduler advances
//!   sequence B's decode instead of sleeping — the same latency-hiding the
//!   paper's prefetcher performs within one sequence (§3.3), applied
//!   across sequences. The scheduler blocks only when every live sequence
//!   is stalled on the link at once; that residue is the *unhidden* stall
//!   reported by the overlap-ratio metric.
//!
//! With `--max-batch N` (N > 1), the interleaved scheduler additionally
//! performs **true batched decode**: each round it gangs up to N runnable,
//! non-blocked sequences into one [`BatchCursor`] step (ragged, at the
//! exact batch width, under grouped execution — the default — or padded
//! to the nearest compiled launch width in {2, 4, 8} on the legacy
//! per-row path) so concurrency becomes
//! FLOP *and* load sharing — per layer the group issues a single merged
//! `ExpertResidency::acquire` for the union of its routed experts and
//! parks on one ticket set. Group membership follows the fairness policy
//! (rr: submission order; sjf: shortest-remaining first); sequences beyond
//! N, and rows *evicted* from a group because their expert loads blocked
//! while the rest was runnable, continue on the solo interleaved path.
//!
//! **Chunked-prefill interleaving** (default in interleaved mode): an
//! admitted request enters the live set as a *Prefilling* sequence — a
//! suspendable [`PrefillCursor`] whose `PREFILL_CHUNKS`-sized chunks are
//! first-class schedulable slices alongside decode, under the same
//! rr/sjf/token-budget policies. A prefill chunk parks at its
//! ensure-resident barrier instead of blocking, so live solo cursors and
//! batched-decode groups keep stepping while the chunk's experts stream
//! in, and the next chunk's layer-0 loads are kicked across each chunk
//! boundary — a long prompt's admission no longer inflates other users'
//! inter-token latency beyond ~one chunk's work. The
//! [`Coordinator::prefill_first`] knob flips prefill/decode priority
//! (default: decode first); [`Coordinator::chunked_prefill`] = false
//! restores the pre-chunking blocking admission for A/B runs. Prefill
//! errors — on either admission path — fail only their own request
//! ([`Coordinator::take_failures`]); the scheduler loop keeps serving
//! everyone else.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::OverloadConfig;
use crate::engine::{
    BatchCursor, BatchItem, BatchProgress, DecodeCursor, DecodeProgress, Engine, KvState,
    PrefillCursor, PrefillProgress, PREFILL_CHUNKS,
};
use crate::metrics::{RequestMetrics, RunReport, SchedulerStats};
use crate::residency::{SequenceSession, Ticket};
use crate::tensor::sample_logits;
use crate::tokenizer::{Tokenizer, EOS};
use crate::util::rng::Rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy
    pub temperature: f32,
}

impl Request {
    pub fn new(id: u64, prompt: impl Into<String>, max_new_tokens: usize) -> Self {
        Self { id, prompt: prompt.into(), max_new_tokens, temperature: 0.0 }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<u32>,
    pub metrics: RequestMetrics,
}

/// How queued requests are scheduled onto the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// paper-faithful batch-1 blocking FCFS (the default)
    Fcfs,
    /// interleaved continuous serving: decode interleaved across live
    /// sequences, suspending at expert-load barriers instead of blocking
    Interleaved,
}

/// Which live sequence the interleaved scheduler advances next (the
/// fairness knob; `hobbit serve --interleaved --policy {rr,sjf}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// advance every live sequence one unit per round (the default)
    RoundRobin,
    /// shortest-job-first: each round advances only the runnable sequence
    /// with the fewest remaining tokens; stalled sequences overlap their
    /// loads underneath it
    Sjf,
    /// round-robin at token granularity: each round a sequence may
    /// complete up to [`Coordinator::token_budget`] decode tokens before
    /// the turn passes on (a configurable fairness quantum; budget 1 is
    /// strict per-token round-robin)
    TokenBudget,
    /// TTFT-deadline-aware (SLO) fairness: decode-first round-robin —
    /// until a Prefilling sequence burns most of its
    /// [`Coordinator::ttft_deadline`] budget, at which point prefill
    /// slices preempt decode (earliest admission first, i.e. EDF under a
    /// uniform deadline) until the at-risk admission produces its first
    /// token. Live decode pays at most the prefill-chunk bound PR 4
    /// established, and only when an SLO is actually at risk.
    Deadline,
}

impl SchedPolicy {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" => Some(SchedPolicy::RoundRobin),
            "sjf" | "shortest-job-first" => Some(SchedPolicy::Sjf),
            "token-budget" | "tb" => Some(SchedPolicy::TokenBudget),
            "deadline" | "edf" => Some(SchedPolicy::Deadline),
            _ => None,
        }
    }
}

/// Deadline-policy urgency over (time-since-submit, is-prefilling)
/// snapshots: true when any Prefilling sequence has burned 75% or more of
/// the uniform TTFT budget — prefill slices then preempt decode. The 25%
/// lead leaves room for the slices themselves (a reactive check at 100%
/// would only fire after the SLO was already missed).
pub(crate) fn ttft_deadline_urgent(seqs: &[(Duration, bool)], deadline: Duration) -> bool {
    seqs.iter().any(|&(waited, prefilling)| {
        prefilling && waited.as_secs_f64() * 4.0 >= deadline.as_secs_f64() * 3.0
    })
}

/// SJF selection over (remaining_tokens, stalled) snapshots: the runnable
/// sequence with the fewest remaining tokens (first on ties, for
/// determinism). None when every sequence is stalled (or none exist).
pub(crate) fn sjf_pick(seqs: &[(usize, bool)]) -> Option<usize> {
    seqs.iter()
        .enumerate()
        .filter(|(_, (_, stalled))| !stalled)
        .min_by_key(|(_, (remaining, _))| *remaining)
        .map(|(i, _)| i)
}

/// Typed admission rejection ([`Coordinator::try_submit`]) — the overload
/// ladder's last stage. The caller answers the client's channel with it;
/// the request never entered the queue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// the bounded admission queue is full
    QueueFull { depth: usize, limit: usize },
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { depth, limit } => {
                write!(f, "admission queue full ({depth}/{limit}); retry later")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Degradation ladder stage, ordered by severity. Stages are cumulative:
/// `ShedPrefetch` implies the precision shed stays on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum OverloadStage {
    Normal,
    /// force the progressive precision floor to the low tier
    ShedPrecision,
    /// additionally drop speculative prefetch planning
    ShedPrefetch,
}

/// Pure ladder-stage decision (unit-testable): queue fill fraction against
/// the configured thresholds, plus the SLO-risk signal — the *oldest*
/// queued request having burned half its TTFT budget while still
/// unadmitted means everything behind it is already late, so precision
/// shedding starts even at shallow depth.
pub(crate) fn overload_stage(
    depth: usize,
    limit: usize,
    oldest_wait: Option<Duration>,
    slo_ttft: Option<Duration>,
    precision_frac: f64,
    prefetch_frac: f64,
) -> OverloadStage {
    let limit = limit.max(1);
    let fill = depth as f64 / limit as f64;
    let slo_risk = match (oldest_wait, slo_ttft) {
        (Some(w), Some(slo)) => w * 2 >= slo,
        _ => false,
    };
    if fill >= prefetch_frac {
        OverloadStage::ShedPrefetch
    } else if fill >= precision_frac || slo_risk {
        OverloadStage::ShedPrecision
    } else {
        OverloadStage::Normal
    }
}

struct QueuedRequest {
    req: Request,
    enqueued: Instant,
}

/// One live sequence in the interleaved scheduler.
struct ActiveSeq {
    req: Request,
    /// RAII residency session: per-sequence cache records + prefetch
    /// generation scope, retired when this sequence drops (finish, error,
    /// or abort alike)
    session: SequenceSession,
    kv: KvState,
    /// logits of the last completed step (next sample input); empty while
    /// the sequence is still prefilling
    logits: Vec<f32>,
    generated: Vec<u32>,
    /// in-flight chunked prefill (the *Prefilling* state): the sequence is
    /// not decodable until this completes
    prefill: Option<PrefillCursor>,
    /// in-flight decode token, if suspended or mid-poll
    cursor: Option<DecodeCursor>,
    /// true while this sequence rides the live batched group (its KV state
    /// is inside the group's `BatchCursor`; the solo loops must skip it)
    in_batch: bool,
    /// per-sequence sampling stream: interleaving order must not change
    /// any sequence's samples
    rng: Rng,
    // ---- metrics ----
    enqueued: Instant,
    queue_wait: Duration,
    prompt_tokens: usize,
    /// admission (prefill start) instant — chunked prefill's wall latency
    /// runs from here to the cursor's completion
    prefill_started: Instant,
    prefill_time: Duration,
    prefill_load_wait: Duration,
    /// decode stall (barrier reach → clear), hidden or not
    load_wait: Duration,
    /// PJRT time attributed to this sequence
    compute: Duration,
    decode_started: Instant,
    ttft: Option<Duration>,
    /// instant of the last completed decode token (inter-token-latency
    /// histogram samples are the gaps between these)
    last_token: Option<Instant>,
    /// cached scheduler-visibility flags, kept current by
    /// [`Coordinator::refresh_stall`] at every cursor/prefill/in_batch
    /// mutation site — the incrementally-updated counts behind the O(1)
    /// [`Coordinator::all_stalled`]
    counted_live: bool,
    counted_stalled: bool,
}

enum Advance {
    Progressed,
    Stalled,
    Finished(GenerationResult),
}

/// Outcome of one prefill slice ([`Coordinator::step_prefill_one`]).
enum PrefillOutcome {
    /// a chunk boundary was crossed, or the prefill completed (the
    /// sequence is decodable next round)
    Progressed,
    /// parked at the chunk's ensure-resident barrier
    Stalled,
    /// the prefill errored: the sequence was removed and its request
    /// failed individually (see [`Coordinator::take_failures`])
    Failed,
}

/// Outcome of the between-token lifecycle step ([`Coordinator::next_token`]).
enum TokenStep {
    /// budget/KV exhausted or EOS sampled: the sequence was finished
    Finished(GenerationResult),
    /// the sampled token, already committed to `generated`
    Token(u32),
}

/// Coordinator over one engine; see [`SchedulerMode`] for the two
/// scheduling disciplines.
pub struct Coordinator {
    pub engine: Engine,
    pub tokenizer: Tokenizer,
    pub report: RunReport,
    pub mode: SchedulerMode,
    /// fairness policy of the interleaved scheduler
    pub sched_policy: SchedPolicy,
    /// max sequences decoded concurrently in interleaved mode
    pub max_active: usize,
    /// max sequences ganged into one batched decode step (1 = solo
    /// time-multiplexing only; capped at the engine's
    /// [`Engine::batch_ceiling`] — `runtime::MAX_GROUPED_BATCH` under
    /// grouped execution, `runtime::MAX_DECODE_BATCH` on the legacy
    /// padded path)
    pub max_batch: usize,
    /// chunked-prefill interleaving (interleaved mode only, default on):
    /// admission is non-blocking and prefill chunks are schedulable slices
    /// alongside decode. false = run the whole prefill at admission,
    /// blocking the scheduler (the pre-chunking behavior, kept for A/B
    /// comparison — `serve --no-chunked-prefill`)
    pub chunked_prefill: bool,
    /// prefill/decode priority knob: true gives prefill slices the engine
    /// before decode work each round (drain admissions fast, at the cost
    /// of live sequences' inter-token latency); false (default) steps
    /// decode first so admission never delays a runnable token
    pub prefill_first: bool,
    /// decode tokens one sequence may complete per round under
    /// [`SchedPolicy::TokenBudget`] (>= 1)
    pub token_budget: usize,
    /// uniform TTFT budget under [`SchedPolicy::Deadline`]: once a
    /// Prefilling sequence has waited 75% of this since submission, its
    /// prefill slices preempt decode (`--ttft-deadline-ms`)
    pub ttft_deadline: Duration,
    /// overload-control plane: bounded admission + the degradation ladder
    /// (precision → prefetch → rejection); default = unbounded, ladder
    /// armed but keyed off a queue that never fills
    pub overload: OverloadConfig,
    /// per-request failures (admission/prefill errors) awaiting
    /// [`Self::take_failures`]
    failed: Vec<(u64, String)>,
    queue: VecDeque<QueuedRequest>,
    active: Vec<ActiveSeq>,
    /// live solo (non-group) sequence count — `counted_live` sum
    solo_live: usize,
    /// of those, how many are suspended on unconsumed loads —
    /// `counted_stalled` sum
    solo_stalled: usize,
    /// sequences examined by `all_stalled`/`first_stalled` since startup
    /// (observability for the O(1)-per-slice guarantee; Cell so the
    /// `&self` accessors can count themselves)
    scan_ops: std::cell::Cell<u64>,
    /// the in-flight batched decode step, if one is ganged up
    group: Option<BatchCursor>,
    sched: SchedulerStats,
    busy_since: Option<Instant>,
    rng: Rng,
}

impl Coordinator {
    pub fn new(engine: Engine) -> Self {
        Self {
            engine,
            tokenizer: Tokenizer::new(),
            report: RunReport::default(),
            mode: SchedulerMode::Fcfs,
            sched_policy: SchedPolicy::RoundRobin,
            max_active: 4,
            max_batch: 1,
            chunked_prefill: true,
            prefill_first: false,
            token_budget: 1,
            ttft_deadline: Duration::from_millis(500),
            overload: OverloadConfig::default(),
            failed: Vec::new(),
            queue: VecDeque::new(),
            active: Vec::new(),
            solo_live: 0,
            solo_stalled: 0,
            scan_ops: std::cell::Cell::new(0),
            group: None,
            sched: SchedulerStats::default(),
            busy_since: None,
            rng: Rng::new(0xC0FFEE),
        }
    }

    /// Convenience constructor for interleaved continuous serving.
    pub fn interleaved(engine: Engine) -> Self {
        let mut c = Self::new(engine);
        c.mode = SchedulerMode::Interleaved;
        c
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(QueuedRequest { req, enqueued: Instant::now() });
    }

    /// Submit under admission control: with a bounded queue configured
    /// ([`OverloadConfig::queue_limit`]), a full queue rejects with a
    /// typed error instead of growing without bound — the ladder's last
    /// stage, reached only after precision and prefetch already shed.
    /// Unbounded (the default) never rejects, matching [`Self::submit`].
    pub fn try_submit(&mut self, req: Request) -> Result<(), AdmissionError> {
        if let Some(limit) = self.overload.queue_limit {
            let depth = self.queue.len();
            if depth >= limit {
                self.sched.admission_rejects += 1;
                return Err(AdmissionError::QueueFull { depth, limit });
            }
        }
        self.submit(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Queued or live work remains.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Serve every queued request; returns the results. FCFS mode returns
    /// them in submission order; interleaved mode in completion order.
    pub fn drain(&mut self) -> Result<Vec<GenerationResult>> {
        match self.mode {
            SchedulerMode::Fcfs => {
                let mut out = Vec::with_capacity(self.queue.len());
                while let Some(q) = self.queue.pop_front() {
                    out.push(self.generate(&q.req)?);
                }
                Ok(out)
            }
            SchedulerMode::Interleaved => {
                let mut out = Vec::new();
                while self.has_work() {
                    out.extend(self.step()?);
                }
                self.sync_report();
                // per-request prefill failures are isolated, not fatal:
                // they are absent from `out` (each was logged when it
                // happened); callers collect them via `take_failures`
                Ok(out)
            }
        }
    }

    /// Run one request through prefill + decode (blocking batch-1 path).
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let mut prompt_tokens = self.tokenizer.encode(&req.prompt);
        let budget = self.engine.cfg.max_seq.saturating_sub(req.max_new_tokens + 1);
        if prompt_tokens.len() > budget {
            prompt_tokens.truncate(budget.max(1));
        }

        let mut kv: KvState = self.engine.new_sequence();
        let compute0 = self.engine.compute_time();
        let wait0 = self.engine.load_wait;

        let t0 = Instant::now();
        let mut logits = self.engine.prefill(&mut kv, &prompt_tokens)?;
        let prefill_time = t0.elapsed();

        let mut generated: Vec<u32> = Vec::with_capacity(req.max_new_tokens);
        let t1 = Instant::now();
        for _ in 0..req.max_new_tokens {
            if kv.remaining() == 0 {
                break;
            }
            let next = sample_logits(&logits, req.temperature, &mut self.rng) as u32;
            if next == EOS {
                break;
            }
            generated.push(next);
            logits = self.engine.decode_step(&mut kv, next)?;
        }
        let decode_time = t1.elapsed();

        let metrics = RequestMetrics {
            prompt_tokens: prompt_tokens.len(),
            generated_tokens: generated.len(),
            prefill_time,
            decode_time,
            compute_time: self.engine.compute_time().saturating_sub(compute0),
            load_wait_time: self.engine.load_wait.saturating_sub(wait0),
        };
        self.report.requests.push(metrics.clone());
        self.sync_report();

        Ok(GenerationResult {
            id: req.id,
            text: self.tokenizer.decode(&generated),
            tokens: generated,
            metrics,
        })
    }

    // ------------------------------------------------------------------
    // Interleaved scheduler
    // ------------------------------------------------------------------

    /// One scheduler round: admit waiting requests, advance every live
    /// sequence one unit (a decode-poll or a new-token start), and return
    /// any completions. Blocks only when every live sequence is stalled on
    /// the link at once (the unhidden stall).
    pub fn step(&mut self) -> Result<Vec<GenerationResult>> {
        self.step_inner(true)
    }

    /// Like [`Self::step`] but never blocks — the serving front-end uses
    /// this and parks on its own event channel instead (woken by loader
    /// completion callbacks).
    pub fn step_nonblocking(&mut self) -> Result<Vec<GenerationResult>> {
        self.step_inner(false)
    }

    fn step_inner(&mut self, may_block: bool) -> Result<Vec<GenerationResult>> {
        if self.busy_since.is_none() && self.has_work() {
            self.busy_since = Some(Instant::now());
        }
        self.admit_waiting();
        // overload ladder: judge standing pressure from what is STILL
        // queued after admission filled the live set, and publish the
        // shed signals to the residency facade for this round
        self.apply_overload_ladder();
        let mut out = Vec::new();
        let mut progressed = false;
        // prefill-priority: admissions' chunks take the engine before any
        // decode work this round (rr/token-budget sweep; under sjf the
        // selection below handles it). The deadline policy flips to
        // prefill-first dynamically, exactly while an admission's TTFT
        // budget is at risk.
        let deadline_urgent =
            self.sched_policy == SchedPolicy::Deadline && self.deadline_urgent();
        // publish TTFT urgency to the residency facade: while an
        // admission's budget is at risk, progressive hi-pool misses floor
        // at the lo precision (time-to-first-usable over fidelity)
        if self.sched_policy == SchedPolicy::Deadline {
            self.engine.residency.set_deadline_urgent(deadline_urgent);
        }
        let prefill_priority = self.prefill_first || deadline_urgent;
        if prefill_priority && self.sched_policy != SchedPolicy::Sjf {
            progressed |= self.step_prefills()?;
        }
        // batched decode: advance the in-flight group, then gang the next
        // one from the between-token sequences BEFORE the solo loops see
        // them (or the solo loops would consume every candidate)
        if self.mode == SchedulerMode::Interleaved && self.max_batch > 1 {
            progressed |= self.step_group()?;
            progressed |= self.form_group(&mut out)?;
        }
        match self.sched_policy {
            SchedPolicy::RoundRobin | SchedPolicy::TokenBudget | SchedPolicy::Deadline => {
                // token-budget is rr with a configurable per-round token
                // quantum: a sequence keeps the engine until it completes
                // `budget` tokens or stalls. Plain rr IS budget 1 — one
                // advance_one per turn with identical outcome handling
                let budget = match self.sched_policy {
                    SchedPolicy::TokenBudget => self.token_budget.max(1),
                    _ => 1,
                };
                let mut i = 0;
                while i < self.active.len() {
                    if self.active[i].in_batch || self.active[i].prefill.is_some() {
                        // its token rides the batched group this round, or
                        // the sequence is still prefilling (sliced in
                        // step_prefills, not decodable yet)
                        i += 1;
                        continue;
                    }
                    let mut tokens_done = 0usize;
                    loop {
                        match self.advance_one(i)? {
                            // finish() removed the sequence at i: the
                            // outer loop re-examines i, no increment
                            Advance::Finished(r) => {
                                out.push(r);
                                progressed = true;
                                break;
                            }
                            Advance::Progressed => {
                                progressed = true;
                                tokens_done += 1;
                                if tokens_done >= budget {
                                    i += 1;
                                    break;
                                }
                            }
                            Advance::Stalled => {
                                i += 1;
                                break;
                            }
                        }
                    }
                }
            }
            SchedPolicy::Sjf => {
                // advance only the runnable sequence closest to completion;
                // stalled sequences keep their loads in flight underneath.
                // One unit per round keeps the serving event loop live.
                // Prefilling sequences are first-class candidates: their
                // remaining work counts the unprefilled prompt tokens, and
                // winning the pick buys them one chunk slice.
                let snapshot: Vec<(usize, bool)> = self
                    .active
                    .iter()
                    .map(|s| {
                        // is_blocked, not is_pending: a cursor whose loads
                        // all completed is runnable (its next poll clears
                        // the barrier) and must be selectable, or SJF
                        // livelocks with every sequence "stalled".
                        // Group members are not solo-selectable at all.
                        let stalled = s.in_batch
                            || s.prefill.as_ref().map(|c| c.is_blocked()).unwrap_or(false)
                            || s.cursor.as_ref().map(|c| c.is_blocked()).unwrap_or(false);
                        let remaining = s.req.max_new_tokens.saturating_sub(s.generated.len())
                            + s.prefill.as_ref().map(|c| c.remaining()).unwrap_or(0);
                        (remaining, stalled)
                    })
                    .collect();
                // prefill-priority under sjf: a runnable prefill preempts
                // the decode pick
                let pick = if self.prefill_first {
                    self.active
                        .iter()
                        .position(|s| {
                            s.prefill.as_ref().map(|c| !c.is_blocked()).unwrap_or(false)
                        })
                        .or_else(|| sjf_pick(&snapshot))
                } else {
                    sjf_pick(&snapshot)
                };
                if let Some(i) = pick {
                    if self.active[i].prefill.is_some() {
                        match self.step_prefill_one(i)? {
                            PrefillOutcome::Progressed | PrefillOutcome::Failed => {
                                progressed = true;
                            }
                            PrefillOutcome::Stalled => {}
                        }
                    } else {
                        match self.advance_one(i)? {
                            Advance::Finished(r) => {
                                out.push(r);
                                progressed = true;
                            }
                            Advance::Progressed => progressed = true,
                            Advance::Stalled => {}
                        }
                    }
                }
            }
        }
        // decode-priority (the default): prefill slices run on whatever
        // rounds remain after decode work — but they always run, so
        // admission progresses whenever decode is stalled or idle
        if !prefill_priority && self.sched_policy != SchedPolicy::Sjf {
            progressed |= self.step_prefills()?;
        }
        if !progressed && may_block {
            let t0 = Instant::now();
            if self.group.as_ref().map(|g| g.is_pending()).unwrap_or(false) {
                // the whole group (and every solo sequence) waits on the
                // link: block on the merged barrier
                let mut cur = self.group.take().unwrap();
                self.engine.set_active_sequence(None);
                self.engine.decode_block_batch(&mut cur);
                self.group = Some(cur);
                self.sched.unhidden_stall += t0.elapsed();
            } else if let Some(idx) = self.first_stalled() {
                // every live sequence waits on the link: nothing left to
                // overlap, so block — the unhidden share of the load wait
                let seq = &mut self.active[idx];
                self.engine.set_active_sequence(Some(seq.session.id()));
                if let Some(pf) = seq.prefill.as_mut() {
                    self.engine.prefill_block(pf);
                } else {
                    self.engine.decode_block(seq.cursor.as_mut().unwrap());
                }
                self.sched.unhidden_stall += t0.elapsed();
                // the block satisfied the cursor's pending loads: it is
                // runnable again, so the cached stall flag must clear
                self.refresh_stall(idx);
            }
        }
        if !self.has_work() {
            if let Some(t) = self.busy_since.take() {
                self.sched.busy_wall += t.elapsed();
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Batched decode (group formation + stepping)
    // ------------------------------------------------------------------

    fn index_of(&self, id: u64) -> Option<usize> {
        self.active.iter().position(|s| s.session.id() == id)
    }

    /// Gang up to `max_batch` between-token sequences into one batched
    /// decode step. Membership order follows the fairness policy: rr takes
    /// submission order, sjf the shortest remaining first. Sequences that
    /// turn out finished (budget/EOS) are completed here instead; a lone
    /// survivor starts a solo cursor (its token is already sampled).
    fn form_group(&mut self, out: &mut Vec<GenerationResult>) -> Result<bool> {
        if self.group.is_some() {
            return Ok(false);
        }
        let limit = self.max_batch.min(self.engine.batch_ceiling());
        let mut ids: Vec<(u64, usize)> = self
            .active
            .iter()
            .filter(|s| !s.in_batch && s.cursor.is_none() && s.prefill.is_none())
            .map(|s| {
                (s.session.id(), s.req.max_new_tokens.saturating_sub(s.generated.len()))
            })
            .collect();
        if self.sched_policy == SchedPolicy::Sjf {
            ids.sort_by_key(|&(_, rem)| rem);
        }
        let mut progressed = false;
        let mut picked: Vec<(u64, u32)> = Vec::new();
        for (id, _) in ids {
            if picked.len() == limit {
                break;
            }
            let Some(i) = self.index_of(id) else { continue };
            match self.next_token(i) {
                TokenStep::Finished(r) => {
                    out.push(r);
                    progressed = true;
                }
                TokenStep::Token(next) => picked.push((id, next)),
            }
        }
        match picked.len() {
            0 => Ok(progressed),
            1 => {
                // a group of one is just the solo path — but its token is
                // already sampled, so start the cursor here (the solo
                // loops would re-sample)
                let (id, tok) = picked[0];
                let i = self.index_of(id).expect("picked sequence is live");
                self.engine.set_active_sequence(Some(id));
                let cursor = self.engine.decode_begin(&self.active[i].kv, tok)?;
                self.active[i].cursor = Some(cursor);
                self.refresh_stall(i);
                Ok(true)
            }
            n => {
                let mut items = Vec::with_capacity(n);
                for &(id, tok) in &picked {
                    let i = self.index_of(id).expect("picked sequence is live");
                    let seq = &mut self.active[i];
                    seq.in_batch = true;
                    let kv = std::mem::replace(&mut seq.kv, KvState::empty());
                    items.push(BatchItem { seq: Some(id), token: tok, kv });
                    self.refresh_stall(i);
                }
                self.engine.set_active_sequence(None);
                let cur = self.engine.decode_begin_batch(items)?;
                self.sched.batch_steps += 1;
                self.sched.batch_rows += n as u64;
                self.sched.padded_slots += (cur.width() - n) as u64;
                self.group = Some(cur);
                Ok(true)
            }
        }
    }

    /// Advance the in-flight batched group one poll. On `Pending`, rows
    /// whose own loads block while some row is runnable are evicted onto
    /// the solo path (they park on exactly their ticket subset); the rest
    /// of the group keeps going. On `Done`, every row's logits/KV return
    /// to its sequence (completions happen at the next formation, via
    /// [`Self::next_token`]).
    fn step_group(&mut self) -> Result<bool> {
        let Some(mut cur) = self.group.take() else { return Ok(false) };
        self.engine.set_active_sequence(None);
        let compute0 = self.engine.compute_time();
        let progress = match self.engine.decode_poll_batch(&mut cur) {
            Ok(p) => p,
            Err(e) => {
                // release the merged barrier's per-row pins before
                // surfacing the error — the server survives scheduler
                // errors, and leaked pins would make those slots
                // eviction-proof for the life of the process
                self.engine.decode_abort_batch(cur);
                return Err(e);
            }
        };
        let dt = self.engine.compute_time().saturating_sub(compute0);
        // attribute the shared launch evenly across the riding sequences
        let alive = cur.rows_alive().max(1) as u32;
        let share = dt / alive;
        for r in 0..cur.rows() {
            if let Some(id) = cur.row_seq_alive(r) {
                if let Some(i) = self.index_of(id) {
                    self.active[i].compute += share;
                }
            }
        }
        match progress {
            BatchProgress::Pending => {
                let mut evicted = false;
                if cur.any_row_runnable() {
                    for r in 0..cur.rows() {
                        if !cur.row_blocked(r) {
                            continue;
                        }
                        let carved = self.engine.decode_evict_row(&mut cur, r);
                        if let Some((seq_id, kv, solo)) = carved {
                            self.sched.batch_evictions += 1;
                            evicted = true;
                            let id = seq_id.expect("group rows carry session ids");
                            if let Some(i) = self.index_of(id) {
                                let seq = &mut self.active[i];
                                seq.kv = kv;
                                seq.cursor = Some(solo);
                                seq.in_batch = false;
                                self.refresh_stall(i);
                            }
                        }
                    }
                }
                if cur.rows_alive() == 0 {
                    self.engine.decode_abort_batch(cur);
                } else {
                    self.group = Some(cur);
                }
                Ok(evicted)
            }
            BatchProgress::Done(rows) => {
                let shared_wait = cur.load_wait;
                let now = Instant::now();
                for done in rows {
                    let id = done.seq.expect("group rows carry session ids");
                    if let Some(i) = self.index_of(id) {
                        let seq = &mut self.active[i];
                        seq.kv = done.kv;
                        seq.logits = done.logits;
                        seq.in_batch = false;
                        seq.load_wait += shared_wait;
                        if seq.ttft.is_none() {
                            seq.ttft = Some(seq.enqueued.elapsed());
                        }
                        if let Some(prev) = seq.last_token {
                            self.sched.itl_hist.record(now.saturating_duration_since(prev));
                        }
                        seq.last_token = Some(now);
                        self.refresh_stall(i);
                    }
                }
                Ok(true)
            }
        }
    }

    /// Compute the current ladder stage and publish the shed signals to
    /// the residency facade. Stage 3 (rejection) lives in
    /// [`Self::try_submit`]; this drives stages 1–2 each round. With
    /// `ladder` off the signals stay cleared — the A/B baseline where
    /// only admission bounding protects the server.
    fn apply_overload_ladder(&mut self) {
        let stage = match self.overload.queue_limit {
            Some(limit) if self.overload.ladder => overload_stage(
                self.queue.len(),
                limit,
                self.queue.front().map(|q| q.enqueued.elapsed()),
                self.overload.slo_ttft,
                self.overload.precision_frac,
                self.overload.prefetch_frac,
            ),
            _ => OverloadStage::Normal,
        };
        let shed_precision = stage >= OverloadStage::ShedPrecision;
        let shed_prefetch = stage >= OverloadStage::ShedPrefetch;
        self.engine.residency.set_queue_pressure(shed_precision);
        self.engine.residency.set_prefetch_shed(shed_prefetch);
        if shed_precision {
            self.sched.shed_precision_rounds += 1;
        }
        if shed_prefetch {
            self.sched.shed_prefetch_rounds += 1;
        }
    }

    /// Is sequence `i` invisible to the solo scheduler until a load lands?
    /// (Suspended on unconsumed loads; group members never match — their
    /// cursors live inside the group.)
    fn seq_stalled(s: &ActiveSeq) -> bool {
        s.prefill.as_ref().map(|c| c.is_pending()).unwrap_or(false)
            || s.cursor.as_ref().map(|c| c.is_pending()).unwrap_or(false)
    }

    /// Re-derive sequence `i`'s cached live/stalled contribution and fix
    /// the running counts. Called at every site that mutates a sequence's
    /// `cursor`/`prefill`/`in_batch` — pending-ness only changes through
    /// coordinator-driven polls and blocks, so between calls the counts
    /// stay exact and [`Self::all_stalled`] never rescans the live set.
    fn refresh_stall(&mut self, i: usize) {
        let live_now = !self.active[i].in_batch;
        let stalled_now = Self::seq_stalled(&self.active[i]);
        let s = &mut self.active[i];
        if s.counted_live != live_now {
            self.solo_live = if live_now {
                self.solo_live + 1
            } else {
                self.solo_live - 1
            };
            s.counted_live = live_now;
        }
        if s.counted_stalled != stalled_now {
            self.solo_stalled = if stalled_now {
                self.solo_stalled + 1
            } else {
                self.solo_stalled - 1
            };
            s.counted_stalled = stalled_now;
        }
    }

    /// Drop sequence `i`'s contribution from the running counts (it is
    /// about to be removed from the live set).
    fn forget_stall(&mut self, i: usize) {
        if self.active[i].counted_live {
            self.solo_live -= 1;
        }
        if self.active[i].counted_stalled {
            self.solo_stalled -= 1;
        }
    }

    /// True when every live sequence is suspended on in-flight loads (and
    /// there is at least one). Group members count as stalled only while
    /// the whole group is blocked — a group with a runnable row makes
    /// progress next step (directly or by evicting the blocked rows).
    ///
    /// O(1): reads the incrementally-maintained counts instead of
    /// rescanning the live set — at 1k live sequences the per-slice
    /// scheduler overhead stays flat ([`Self::stall_scan_ops`] is the
    /// test-visible proof).
    pub fn all_stalled(&self) -> bool {
        self.scan_ops.set(self.scan_ops.get() + 1);
        let solos_stalled = self.solo_stalled == self.solo_live;
        let group_stalled = match &self.group {
            Some(g) => g.is_pending() && !g.any_row_runnable(),
            None => true,
        };
        let stalled = !self.active.is_empty() && solos_stalled && group_stalled;
        #[cfg(debug_assertions)]
        {
            let rescan = self.active.iter().filter(|s| !s.in_batch).all(|s| {
                s.prefill.as_ref().map(|c| c.is_pending()).unwrap_or(false)
                    || s.cursor.as_ref().map(|c| c.is_pending()).unwrap_or(false)
            });
            debug_assert_eq!(
                solos_stalled, rescan,
                "incremental stall counts drifted from the live set \
                 (live={} stalled={})",
                self.solo_live, self.solo_stalled
            );
        }
        stalled
    }

    /// Sequences examined by the stall queries since startup. The O(1)
    /// guarantee, observable: each [`Self::all_stalled`] call adds exactly
    /// 1 regardless of how many sequences are live.
    pub fn stall_scan_ops(&self) -> u64 {
        self.scan_ops.get()
    }

    /// Residency tickets every live sequence is suspended on (for the
    /// serving front-end's completion wakeups), the batched group's merged
    /// barrier included.
    pub fn pending_tickets(&self) -> Vec<Ticket> {
        let mut tickets: Vec<Ticket> = self
            .active
            .iter()
            .filter_map(|s| s.cursor.as_ref())
            .flat_map(|c| c.pending_tickets().iter().cloned())
            .collect();
        tickets.extend(
            self.active
                .iter()
                .filter_map(|s| s.prefill.as_ref())
                .flat_map(|c| c.pending_tickets().iter().cloned()),
        );
        if let Some(g) = &self.group {
            tickets.extend(g.pending_tickets().iter().cloned());
        }
        tickets
    }

    /// Attribute externally-measured blocked time (the serving front-end
    /// parking while all sequences stall) to the unhidden-stall metric.
    pub fn note_unhidden_wait(&mut self, d: Duration) {
        self.sched.unhidden_stall += d;
    }

    pub fn scheduler_stats(&self) -> &SchedulerStats {
        &self.sched
    }

    /// Abort every live and queued request (after an engine error leaves
    /// the scheduler state suspect): releases each live sequence's barrier
    /// pins and — via its dropped session — its cache records, and returns
    /// the request ids so the serving front-end can fail them individually
    /// instead of tearing the server down.
    pub fn abort_all(&mut self) -> Vec<u64> {
        if let Some(cur) = self.group.take() {
            // release the group's per-row cache pins; its rows' sessions
            // retire when their ActiveSeqs drain below
            self.engine.decode_abort_batch(cur);
        }
        let mut ids = Vec::with_capacity(self.active.len() + self.queue.len());
        for mut seq in std::mem::take(&mut self.active) {
            if let Some(pf) = seq.prefill.take() {
                // the aborted prefill's partial work still counts in the
                // serving stats (same as the per-request error path), then
                // the chunk barrier's pins drain exactly like batch
                // eviction drains a row's
                self.sched.prefill_stall += pf.load_wait;
                self.fold_chunk_widths(pf.chunk_widths());
                self.engine.prefill_abort(pf);
            }
            if let Some(cur) = seq.cursor.take() {
                self.engine.decode_abort(cur);
            }
            ids.push(seq.req.id);
            // seq drops here: its SequenceSession retires the records
        }
        for q in self.queue.drain(..) {
            ids.push(q.req.id);
        }
        self.solo_live = 0;
        self.solo_stalled = 0;
        self.engine.set_active_sequence(None);
        if let Some(t) = self.busy_since.take() {
            self.sched.busy_wall += t.elapsed();
        }
        ids
    }

    /// First suspended sequence, for the blocking fallback. Reads the
    /// cached per-sequence flags (no cursor re-polling); only runs on the
    /// about-to-block path, never per slice.
    fn first_stalled(&self) -> Option<usize> {
        self.scan_ops.set(self.scan_ops.get() + 1);
        self.active.iter().position(|s| s.counted_stalled)
    }

    /// Move queued requests into the live set (up to `max_active`). With
    /// [`Self::chunked_prefill`] (the default) admission is *non-blocking*:
    /// the sequence enters the Prefilling state and its chunks become
    /// schedulable slices ([`Self::step_prefills`]) — decode of live
    /// sequences never stalls behind a long prompt. Without it, the whole
    /// prefill runs here, blocking the round (the pre-chunking behavior).
    /// Either way a prefill error fails only its own request (recorded for
    /// [`Self::take_failures`]); the scheduler keeps running.
    fn admit_waiting(&mut self) {
        while self.active.len() < self.max_active.max(1) && !self.queue.is_empty() {
            let q = self.queue.pop_front().unwrap();
            let queue_wait = q.enqueued.elapsed();
            let mut prompt_tokens = self.tokenizer.encode(&q.req.prompt);
            let budget =
                self.engine.cfg.max_seq.saturating_sub(q.req.max_new_tokens + 1);
            if prompt_tokens.len() > budget {
                prompt_tokens.truncate(budget.max(1));
            }
            let (session, mut kv) = self.engine.begin_session();
            self.engine.set_active_sequence(Some(session.id()));
            let compute0 = self.engine.compute_time();
            let wait0 = self.engine.load_wait;
            let t0 = Instant::now();
            let (prefill, logits, prefill_time) = if self.chunked_prefill {
                let cursor = match self.engine.prefill_begin(&kv, &prompt_tokens) {
                    Ok(c) => c,
                    Err(e) => {
                        // fail only this request; the session drops here,
                        // retiring its records
                        self.engine.set_active_sequence(None);
                        self.fail_request(q.req.id, format!("{e:#}"));
                        continue;
                    }
                };
                (Some(cursor), Vec::new(), Duration::ZERO)
            } else {
                match self.engine.prefill(&mut kv, &prompt_tokens) {
                    Ok(l) => (None, l, t0.elapsed()),
                    Err(e) => {
                        self.engine.set_active_sequence(None);
                        self.fail_request(q.req.id, format!("{e:#}"));
                        continue;
                    }
                }
            };
            self.active.push(ActiveSeq {
                session,
                kv,
                logits,
                generated: Vec::with_capacity(q.req.max_new_tokens),
                prefill,
                cursor: None,
                in_batch: false,
                // per-sequence stream: deterministic for a given request id
                rng: Rng::new(0xC0FFEE ^ q.req.id),
                enqueued: q.enqueued,
                queue_wait,
                prompt_tokens: prompt_tokens.len(),
                prefill_started: t0,
                prefill_time,
                prefill_load_wait: self.engine.load_wait.saturating_sub(wait0),
                load_wait: Duration::ZERO,
                compute: self.engine.compute_time().saturating_sub(compute0),
                decode_started: Instant::now(),
                ttft: None,
                last_token: None,
                counted_live: false,
                counted_stalled: false,
                req: q.req,
            });
            let idx = self.active.len() - 1;
            self.refresh_stall(idx);
        }
    }

    /// True when any Prefilling sequence has burned most of its TTFT
    /// budget (the deadline policy's preemption trigger).
    fn deadline_urgent(&self) -> bool {
        let snapshot: Vec<(Duration, bool)> = self
            .active
            .iter()
            .map(|s| (s.enqueued.elapsed(), s.prefill.is_some()))
            .collect();
        ttft_deadline_urgent(&snapshot, self.ttft_deadline)
    }

    /// One prefill slice for every Prefilling sequence (the rr/token-budget
    /// sweep; sjf picks a single one instead). Sweeps in live-set order,
    /// which is admission order — under the uniform TTFT deadline that IS
    /// earliest-deadline-first, so the deadline policy needs no re-sort.
    /// Returns whether any slice progressed.
    fn step_prefills(&mut self) -> Result<bool> {
        let mut progressed = false;
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].prefill.is_none() {
                i += 1;
                continue;
            }
            match self.step_prefill_one(i)? {
                PrefillOutcome::Progressed => {
                    progressed = true;
                    i += 1;
                }
                PrefillOutcome::Stalled => {
                    i += 1;
                }
                PrefillOutcome::Failed => {
                    // removed at i: do not advance i
                    progressed = true;
                }
            }
        }
        Ok(progressed)
    }

    /// Advance sequence `i`'s prefill one slice: poll its cursor, which
    /// runs at most one chunk (parking at the ensure-resident barrier, and
    /// kicking the next chunk's layer-0 loads across the boundary). On
    /// completion the sequence becomes decodable and the TTFT clock keeps
    /// running from submission, as before. On error the sequence is
    /// removed, its chunk pins drained, and the request failed
    /// individually.
    fn step_prefill_one(&mut self, i: usize) -> Result<PrefillOutcome> {
        let seq_id = self.active[i].session.id();
        let mut cursor = self.active[i].prefill.take().expect("sequence is prefilling");
        self.engine.set_active_sequence(Some(seq_id));
        let compute0 = self.engine.compute_time();
        let progress = {
            let seq = &mut self.active[i];
            self.engine.prefill_poll(&mut seq.kv, &mut cursor)
        };
        let dt = self.engine.compute_time().saturating_sub(compute0);
        self.active[i].compute += dt;
        let progress = match progress {
            Ok(p) => p,
            Err(e) => {
                // same contract as decode: drain the barrier's pins, then
                // fail only this request — serving survives. Its partial
                // work still counts in the serving stats (like abort_all)
                self.sched.prefill_stall += cursor.load_wait;
                self.fold_chunk_widths(cursor.chunk_widths());
                self.engine.prefill_abort(cursor);
                self.forget_stall(i);
                let seq = self.active.remove(i);
                self.engine.set_active_sequence(None);
                self.fail_request(seq.req.id, format!("{e:#}"));
                return Ok(PrefillOutcome::Failed);
            }
        };
        match progress {
            PrefillProgress::Pending => {
                self.active[i].prefill = Some(cursor);
                self.refresh_stall(i);
                Ok(PrefillOutcome::Stalled)
            }
            PrefillProgress::Chunk { .. } => {
                self.sched.prefill_slices += 1;
                self.active[i].prefill = Some(cursor);
                self.refresh_stall(i);
                Ok(PrefillOutcome::Progressed)
            }
            PrefillProgress::Done(logits) => {
                self.sched.prefill_slices += 1;
                self.sched.prefill_stall += cursor.load_wait;
                self.fold_chunk_widths(cursor.chunk_widths());
                let seq = &mut self.active[i];
                seq.prefill_load_wait += cursor.load_wait;
                seq.prefill_time = seq.prefill_started.elapsed();
                seq.logits = logits;
                seq.decode_started = Instant::now();
                // cursor dropped: the sequence is decodable next round
                self.refresh_stall(i);
                Ok(PrefillOutcome::Progressed)
            }
        }
    }

    /// Fold a finished (or aborted) prefill's chunk widths into the
    /// serving histogram, indexed parallel to `PREFILL_CHUNKS`.
    fn fold_chunk_widths(&mut self, widths: &[usize]) {
        for w in widths {
            if let Some(slot) = PREFILL_CHUNKS.iter().position(|c| c == w) {
                self.sched.prefill_chunks[slot] += 1;
            }
        }
    }

    /// Record a per-request prefill failure: logged once here, counted,
    /// and queued for [`Self::take_failures`].
    fn fail_request(&mut self, id: u64, msg: String) {
        eprintln!("[coordinator] request {id} failed in prefill: {msg}");
        self.sched.prefill_failures += 1;
        self.failed.push((id, msg));
    }

    /// Per-request failures (admission/prefill errors) since the last
    /// call. The serving front-end responds to each on its own channel;
    /// one bad request no longer tears down serving for everyone.
    pub fn take_failures(&mut self) -> Vec<(u64, String)> {
        std::mem::take(&mut self.failed)
    }

    /// The between-token lifecycle, shared by the solo path and batch
    /// formation so the two can never drift: finish the sequence when its
    /// budget/KV is exhausted or it samples EOS; otherwise commit the
    /// sampled token to `generated` and hand it back for decoding.
    fn next_token(&mut self, i: usize) -> TokenStep {
        let done = {
            let seq = &self.active[i];
            seq.generated.len() >= seq.req.max_new_tokens || seq.kv.remaining() == 0
        };
        if done {
            return TokenStep::Finished(self.finish(i));
        }
        let next = {
            let seq = &mut self.active[i];
            sample_logits(&seq.logits, seq.req.temperature, &mut seq.rng) as u32
        };
        if next == EOS {
            return TokenStep::Finished(self.finish(i));
        }
        self.active[i].generated.push(next);
        TokenStep::Token(next)
    }

    /// Advance sequence `i` one unit: start its next token if it is
    /// between tokens, then poll its cursor once. Removal on completion
    /// happens inside (via `finish`).
    fn advance_one(&mut self, i: usize) -> Result<Advance> {
        if self.active[i].cursor.is_none() {
            let next = match self.next_token(i) {
                TokenStep::Finished(r) => return Ok(Advance::Finished(r)),
                TokenStep::Token(t) => t,
            };
            self.engine.set_active_sequence(Some(self.active[i].session.id()));
            let cursor = self.engine.decode_begin(&self.active[i].kv, next)?;
            self.active[i].cursor = Some(cursor);
        }

        let seq_id = self.active[i].session.id();
        let mut cursor = self.active[i].cursor.take().unwrap();
        self.engine.set_active_sequence(Some(seq_id));
        let compute0 = self.engine.compute_time();
        let progress = {
            let seq = &mut self.active[i];
            self.engine.decode_poll(&mut seq.kv, &mut cursor)
        };
        let dt = self.engine.compute_time().saturating_sub(compute0);
        self.active[i].compute += dt;
        let progress = match progress {
            Ok(p) => p,
            Err(e) => {
                // same contract as the batched path: release the barrier's
                // pins before surfacing the error the server will survive
                self.engine.decode_abort(cursor);
                return Err(e);
            }
        };
        match progress {
            DecodeProgress::Pending => {
                self.active[i].cursor = Some(cursor);
                self.refresh_stall(i);
                Ok(Advance::Stalled)
            }
            DecodeProgress::Done(logits) => {
                let now = Instant::now();
                let seq = &mut self.active[i];
                seq.load_wait += cursor.load_wait;
                seq.logits = logits;
                if seq.ttft.is_none() {
                    seq.ttft = Some(seq.enqueued.elapsed());
                }
                if let Some(prev) = seq.last_token {
                    self.sched.itl_hist.record(now.saturating_duration_since(prev));
                }
                seq.last_token = Some(now);
                self.refresh_stall(i);
                Ok(Advance::Progressed)
            }
        }
    }

    /// Retire sequence `i`: build its result and fold its metrics into the
    /// report and scheduler aggregates. The sequence's cache records and
    /// prefetch scope are released by its session dropping at the end of
    /// this function.
    fn finish(&mut self, i: usize) -> GenerationResult {
        self.forget_stall(i);
        let seq = self.active.remove(i);
        self.engine.set_active_sequence(None);
        let metrics = RequestMetrics {
            prompt_tokens: seq.prompt_tokens,
            generated_tokens: seq.generated.len(),
            prefill_time: seq.prefill_time,
            // wall latency of the decode phase, interleaving included
            decode_time: seq.decode_started.elapsed(),
            compute_time: seq.compute,
            load_wait_time: seq.prefill_load_wait + seq.load_wait,
        };
        self.report.requests.push(metrics.clone());
        self.sched.completed += 1;
        self.sched.decoded_tokens += seq.generated.len() as u64;
        self.sched.queue_wait += seq.queue_wait;
        let ttft = seq.ttft.unwrap_or_else(|| seq.enqueued.elapsed());
        self.sched.ttft += ttft;
        self.sched.ttft_hist.record(ttft);
        // goodput accounting: a request counts only if its TTFT met the
        // SLO (no SLO configured = every completion counts)
        if self.overload.slo_ttft.map(|slo| ttft <= slo).unwrap_or(true) {
            self.sched.slo_met += 1;
            self.sched.slo_met_tokens += seq.generated.len() as u64;
        }
        self.sched.total_stall += seq.load_wait;
        GenerationResult {
            id: seq.req.id,
            text: self.tokenizer.decode(&seq.generated),
            tokens: seq.generated,
            metrics,
        }
    }

    /// Pull loader/cache stats into the report. The loader stats are the
    /// single source of truth for prefetch accounting — the engine pushes
    /// realized tracker hits into them as it observes each layer, so
    /// nothing is recomputed (or clobbered) here.
    pub fn sync_report(&mut self) {
        self.report.loader = self.engine.residency.loader_stats();
        self.report.cache = self.engine.residency.cache_stats();
        if self.mode == SchedulerMode::Interleaved {
            self.sched.exec_mode = self.engine.exec_mode().to_string();
            self.report.scheduler = Some(self.sched.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sjf_picks_shortest_runnable() {
        // (remaining tokens, stalled)
        assert_eq!(sjf_pick(&[(8, false), (3, false), (5, false)]), Some(1));
        // stalled sequences are skipped even when shortest
        assert_eq!(sjf_pick(&[(8, false), (3, true), (5, false)]), Some(2));
        // ties resolve to the first (submission order) for determinism
        assert_eq!(sjf_pick(&[(4, false), (4, false)]), Some(0));
        // nothing runnable
        assert_eq!(sjf_pick(&[(1, true), (2, true)]), None);
        assert_eq!(sjf_pick(&[]), None);
    }

    #[test]
    fn sched_policy_names() {
        assert_eq!(SchedPolicy::from_name("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::from_name("sjf"), Some(SchedPolicy::Sjf));
        assert_eq!(
            SchedPolicy::from_name("token-budget"),
            Some(SchedPolicy::TokenBudget)
        );
        assert_eq!(SchedPolicy::from_name("tb"), Some(SchedPolicy::TokenBudget));
        assert_eq!(SchedPolicy::from_name("deadline"), Some(SchedPolicy::Deadline));
        assert_eq!(SchedPolicy::from_name("edf"), Some(SchedPolicy::Deadline));
        assert_eq!(SchedPolicy::from_name("lru"), None);
    }

    #[test]
    fn ladder_stages_escalate_with_queue_fill() {
        let stage = |depth| overload_stage(depth, 8, None, None, 0.25, 0.75);
        assert_eq!(stage(0), OverloadStage::Normal);
        assert_eq!(stage(1), OverloadStage::Normal);
        // 2/8 = 0.25: precision sheds first
        assert_eq!(stage(2), OverloadStage::ShedPrecision);
        assert_eq!(stage(5), OverloadStage::ShedPrecision);
        // 6/8 = 0.75: prefetch sheds next
        assert_eq!(stage(6), OverloadStage::ShedPrefetch);
        assert_eq!(stage(8), OverloadStage::ShedPrefetch);
        // severity order backs the cumulative application
        assert!(OverloadStage::ShedPrefetch > OverloadStage::ShedPrecision);
        assert!(OverloadStage::ShedPrecision > OverloadStage::Normal);
    }

    #[test]
    fn ladder_slo_risk_sheds_precision_at_shallow_depth() {
        let slo = Some(Duration::from_millis(400));
        // shallow queue, but the oldest waiter burned half its SLO budget
        let w = Some(Duration::from_millis(200));
        assert_eq!(
            overload_stage(1, 64, w, slo, 0.25, 0.75),
            OverloadStage::ShedPrecision
        );
        // fresh waiter: depth rules alone
        let w = Some(Duration::from_millis(10));
        assert_eq!(overload_stage(1, 64, w, slo, 0.25, 0.75), OverloadStage::Normal);
        // no SLO configured: the risk signal never fires
        assert_eq!(
            overload_stage(1, 64, Some(Duration::from_secs(9)), None, 0.25, 0.75),
            OverloadStage::Normal
        );
    }

    #[test]
    fn admission_error_displays_depth() {
        let e = AdmissionError::QueueFull { depth: 8, limit: 8 };
        let msg = e.to_string();
        assert!(msg.contains("8/8"), "got {msg}");
    }

    #[test]
    fn deadline_urgency_trips_at_three_quarters_of_budget() {
        let d = Duration::from_millis(400);
        // no prefilling sequences: never urgent, however long they waited
        assert!(!ttft_deadline_urgent(&[(Duration::from_secs(9), false)], d));
        // a fresh admission is not urgent
        assert!(!ttft_deadline_urgent(&[(Duration::from_millis(100), true)], d));
        // 75% of the budget burned: preempt decode now
        assert!(ttft_deadline_urgent(&[(Duration::from_millis(300), true)], d));
        assert!(ttft_deadline_urgent(&[(Duration::from_millis(900), true)], d));
        // any single at-risk admission flips the round
        assert!(ttft_deadline_urgent(
            &[(Duration::from_millis(10), true), (Duration::from_millis(350), true)],
            d
        ));
        assert!(!ttft_deadline_urgent(&[], d));
    }
}
