//! Deterministic fault injection: the chaos harness behind the integrity
//! layer's acceptance tests.
//!
//! A [`FaultPlan`] is parsed from a `seed:spec` string (the `--fault-plan`
//! CLI knob) and threaded to every tier boundary the integrity layer
//! guards. Each fault names a *site* and an *occurrence*; the sites count
//! their events (1-based) and a fault fires when its occurrence matches —
//! `#3` fires on the third event, `#*` on every event. Which bit flips,
//! which byte a truncation keeps, is drawn from a [`Rng`] seeded by
//! `seed ^ occurrence`, so the same plan string always corrupts the same
//! bits: a chaos run is exactly reproducible from its CLI line.
//!
//! Grammar (comma-separated, no spaces):
//!
//! ```text
//!   flip@disk#N      flip one bit of the Nth disk-tier record read
//!   flip@peer#N      shard server: flip one bit of the Nth EXPERT reply
//!                    body (after the frame checksum is computed, so the
//!                    wire-level check is what catches it)
//!   trunc@peer#N     shard server: truncate the Nth EXPERT reply mid-body
//!                    and drop the connection
//!   flip@xfer#N      loader: flip one bit of a chunk while the Nth
//!                    chunked transfer copies into its slot (caught by
//!                    commit-time verification, healed by re-acquire)
//!   stall@xfer#N:MS  stall the I/O lane for MS milliseconds at the start
//!                    of the Nth transfer (the watchdog's prey)
//!   tear@upgrade#N   corrupt the Nth staged upgrade record just before
//!                    `commit_upgrade` (a torn in-place upgrade)
//! ```
//!
//! `N` is a positive integer or `*`. Example:
//! `--fault-plan 7:flip@disk#1,trunc@peer#2,stall@xfer#4:250,tear@upgrade#1`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::Rng;

/// Where in the byte-moving hierarchy a fault fires. Each site keeps its
/// own 1-based occurrence counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Site {
    DiskRead,
    PeerReply,
    Transfer,
    UpgradeCommit,
}

/// Which occurrences of a site's event a fault applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Occurrence {
    Nth(u64),
    Every,
}

impl Occurrence {
    fn matches(&self, n: u64) -> bool {
        match self {
            Occurrence::Nth(want) => *want == n,
            Occurrence::Every => true,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Flip,
    Trunc,
    Stall { ms: u64 },
    Tear,
}

#[derive(Debug, Clone, Copy)]
struct Fault {
    site: Site,
    kind: Kind,
    when: Occurrence,
}

/// What the loader should do to the transfer it just started.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferFault {
    /// sleep this long before moving any bytes (a wedged lane)
    pub stall: Option<Duration>,
    /// corrupt one seeded bit of the record while copying; the draw keys
    /// the bit choice so reruns flip the same bit
    pub flip: Option<u64>,
}

/// What the shard server should do to the reply body it is about to send.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerFault {
    /// body already corrupted in place (one bit)
    Flipped,
    /// send only this many body bytes, then drop the connection
    Truncate(usize),
}

/// A seeded, reproducible fault schedule. Thread-safe: one plan is shared
/// by every lane, the tiered store, and (in-process) shard servers.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: String,
    faults: Vec<Fault>,
    counts: Mutex<HashMap<Site, u64>>,
    fired: AtomicU64,
}

impl FaultPlan {
    /// Parse a `seed:spec` plan string. An empty spec (`"7:"`) is a valid
    /// plan that never fires.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let (seed_s, spec) = s
            .split_once(':')
            .ok_or_else(|| format!("fault plan '{s}': expected seed:spec"))?;
        let seed: u64 = seed_s
            .trim()
            .parse()
            .map_err(|_| format!("fault plan seed '{seed_s}': not a u64"))?;
        let mut faults = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            faults.push(parse_fault(part)?);
        }
        Ok(FaultPlan {
            seed,
            spec: spec.to_string(),
            faults,
            counts: Mutex::new(HashMap::new()),
            fired: AtomicU64::new(0),
        })
    }

    /// The plan's spec text (diagnostics / reproduction lines).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Total faults fired so far.
    pub fn injected(&self) -> u64 {
        self.fired.load(Ordering::Relaxed)
    }

    /// Count one event at `site` and return the matching fault, if any,
    /// plus the seeded rng for its byte/bit draws.
    fn event(&self, site: Site) -> Option<(Kind, Rng)> {
        let n = {
            let mut counts = self.counts.lock().unwrap();
            let e = counts.entry(site).or_insert(0);
            *e += 1;
            *e
        };
        let f = self.faults.iter().find(|f| f.site == site && f.when.matches(n))?;
        self.fired.fetch_add(1, Ordering::Relaxed);
        Some((f.kind, Rng::new(self.seed ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15))))
    }

    /// A disk-tier record was read; maybe flip one bit in place. Returns
    /// true when the record was corrupted.
    pub fn on_disk_read(&self, bytes: &mut [u8]) -> bool {
        match self.event(Site::DiskRead) {
            Some((Kind::Flip, mut rng)) if !bytes.is_empty() => {
                flip_bit(bytes, &mut rng);
                true
            }
            _ => false,
        }
    }

    /// A shard server is about to stream `body`; maybe corrupt it. The
    /// caller passes a mutable copy (the no-fault path stays zero-copy).
    pub fn on_peer_reply(&self, body: &mut [u8]) -> Option<PeerFault> {
        match self.event(Site::PeerReply) {
            Some((Kind::Flip, mut rng)) if !body.is_empty() => {
                flip_bit(body, &mut rng);
                Some(PeerFault::Flipped)
            }
            Some((Kind::Trunc, mut rng)) => {
                // keep a strict prefix so the client's read_exact starves
                let keep = if body.is_empty() { 0 } else { rng.below(body.len()) };
                Some(PeerFault::Truncate(keep))
            }
            _ => None,
        }
    }

    /// A chunked transfer is starting on an I/O lane.
    pub fn on_transfer(&self) -> TransferFault {
        match self.event(Site::Transfer) {
            Some((Kind::Stall { ms }, _)) => {
                TransferFault { stall: Some(Duration::from_millis(ms)), flip: None }
            }
            Some((Kind::Flip, mut rng)) => {
                TransferFault { stall: None, flip: Some(rng.next_u64()) }
            }
            _ => TransferFault::default(),
        }
    }

    /// A staged upgrade record is about to land via `commit_upgrade`;
    /// maybe tear it (flip one bit of the staged bytes). Returns true when
    /// the record was corrupted.
    pub fn on_upgrade_commit(&self, staged: &mut [u8]) -> bool {
        match self.event(Site::UpgradeCommit) {
            Some((Kind::Tear, mut rng)) if !staged.is_empty() => {
                flip_bit(staged, &mut rng);
                true
            }
            _ => false,
        }
    }
}

/// Flip one rng-drawn bit in place (shared by every flip-style fault so
/// all sites corrupt identically for a given seed).
pub(crate) fn flip_bit(bytes: &mut [u8], rng: &mut Rng) {
    let byte = rng.below(bytes.len());
    let bit = rng.below(8);
    bytes[byte] ^= 1u8 << bit;
}

fn parse_fault(part: &str) -> Result<Fault, String> {
    let (head, tail) = part
        .split_once('@')
        .ok_or_else(|| format!("fault '{part}': expected kind@site#occurrence"))?;
    let (site_s, occ_s) = tail
        .split_once('#')
        .ok_or_else(|| format!("fault '{part}': expected kind@site#occurrence"))?;
    // stall carries a trailing :MS on the occurrence
    let (occ_s, ms) = match occ_s.split_once(':') {
        Some((o, ms_s)) => {
            let ms_s = ms_s.strip_suffix("ms").unwrap_or(ms_s);
            let ms: u64 =
                ms_s.parse().map_err(|_| format!("fault '{part}': bad stall millis '{ms_s}'"))?;
            (o, Some(ms))
        }
        None => (occ_s, None),
    };
    let when = if occ_s == "*" {
        Occurrence::Every
    } else {
        let n: u64 =
            occ_s.parse().map_err(|_| format!("fault '{part}': bad occurrence '{occ_s}'"))?;
        if n == 0 {
            return Err(format!("fault '{part}': occurrences are 1-based"));
        }
        Occurrence::Nth(n)
    };
    let (site, kind) = match (head, site_s) {
        ("flip", "disk") => (Site::DiskRead, Kind::Flip),
        ("flip", "peer") => (Site::PeerReply, Kind::Flip),
        ("trunc", "peer") => (Site::PeerReply, Kind::Trunc),
        ("flip", "xfer") => (Site::Transfer, Kind::Flip),
        ("stall", "xfer") => {
            let ms = ms.ok_or_else(|| format!("fault '{part}': stall needs :MS"))?;
            (Site::Transfer, Kind::Stall { ms })
        }
        ("tear", "upgrade") => (Site::UpgradeCommit, Kind::Tear),
        _ => {
            return Err(format!(
                "fault '{part}': unknown kind@site (flip@disk, flip@peer, trunc@peer, \
                 flip@xfer, stall@xfer, tear@upgrade)"
            ))
        }
    };
    if ms.is_some() && !matches!(kind, Kind::Stall { .. }) {
        return Err(format!("fault '{part}': only stall takes :MS"));
    }
    Ok(Fault { site, kind, when })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let plan = FaultPlan::parse(
            "7:flip@disk#1,flip@peer#2,trunc@peer#3,flip@xfer#4,stall@xfer#5:250ms,tear@upgrade#*",
        )
        .unwrap();
        assert_eq!(plan.faults.len(), 6);
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.injected(), 0);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "nocolon",
            "x:flip@disk#1",
            "7:flip@disk",
            "7:flip@disk#0",
            "7:flip@disk#q",
            "7:melt@disk#1",
            "7:stall@xfer#1",
            "7:flip@disk#1:50",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should not parse");
        }
        // empty spec: a valid plan that never fires
        let plan = FaultPlan::parse("3:").unwrap();
        assert!(plan.faults.is_empty());
    }

    #[test]
    fn occurrence_counting_is_per_site() {
        let plan = FaultPlan::parse("1:flip@disk#2,flip@xfer#1").unwrap();
        let mut rec = vec![0u8; 64];
        assert!(!plan.on_disk_read(&mut rec), "first disk read clean");
        assert!(plan.on_disk_read(&mut rec), "second disk read flipped");
        assert!(!plan.on_disk_read(&mut rec), "third disk read clean again");
        assert!(plan.on_transfer().flip.is_some(), "transfer counter independent");
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn same_plan_flips_same_bit() {
        let run = |_: ()| {
            let plan = FaultPlan::parse("42:flip@disk#1").unwrap();
            let mut rec = vec![0u8; 4096];
            plan.on_disk_read(&mut rec);
            rec
        };
        assert_eq!(run(()), run(()), "fault injection must be reproducible");
        assert_ne!(run(()), vec![0u8; 4096], "exactly one bit differs");
    }

    #[test]
    fn stall_and_trunc_payloads() {
        let plan = FaultPlan::parse("9:stall@xfer#1:150,trunc@peer#1").unwrap();
        let f = plan.on_transfer();
        assert_eq!(f.stall, Some(Duration::from_millis(150)));
        assert!(f.flip.is_none());
        assert_eq!(plan.on_transfer().stall, None, "second transfer unaffected");
        let mut body = vec![1u8; 100];
        match plan.on_peer_reply(&mut body) {
            Some(PeerFault::Truncate(keep)) => assert!(keep < 100),
            other => panic!("expected truncation, got {other:?}"),
        }
    }

    #[test]
    fn tear_corrupts_staged_bytes() {
        let plan = FaultPlan::parse("5:tear@upgrade#1").unwrap();
        let mut staged = vec![7u8; 256];
        assert!(plan.on_upgrade_commit(&mut staged));
        assert_ne!(staged, vec![7u8; 256]);
        let mut staged2 = vec![7u8; 256];
        assert!(!plan.on_upgrade_commit(&mut staged2), "one-shot fault");
        assert_eq!(staged2, vec![7u8; 256]);
    }
}
