//! The Adaptive Expert Predictor (§3.3, Fig 8).
//!
//! The Stacking Computer itself is the `gate_p{p}_s1` HLO artifact (all p
//! gating matmuls in one launch — L1 kernel `kernels/gating.py`); this
//! module owns the *decisions*: walk predicted layers outward from the
//! current one, stop at the first layer whose predicted experts are not
//! fully cached, issue mixed-precision prefetches for the gap, pin
//! ("mask") predictions against eviction, and track realized accuracy.

use std::collections::HashMap;

use crate::cache::{CacheManager, Pool};
use crate::loader::scorer::{self, Class};
use crate::tensor::topk;
use crate::ExpertKey;

/// Heat EMA decay: per observed token, `heat = (1-α)·heat + α·prob`.
const HEAT_ALPHA: f32 = 0.1;

/// Hotness threshold as a multiple of the uniform gate mass `1/n_experts`:
/// an expert whose smoothed gate share sits 25% above uniform is hot enough
/// to be worth a DRAM read-replica.
const HEAT_HOT_FACTOR: f32 = 1.25;

/// Prefetch plan for one predicted layer.
#[derive(Debug, Clone)]
pub struct LayerPrediction {
    pub layer: u32,
    /// predicted top-k experts with their precision classes
    pub experts: Vec<(ExpertKey, Class)>,
}

/// Rolling prediction-accuracy tracker, per layer-offset (Fig 7b).
#[derive(Debug, Clone)]
pub struct AccuracyTracker {
    /// [offset-1] -> (hits, total) of top-k prediction
    pub per_offset: Vec<(u64, u64)>,
}

impl AccuracyTracker {
    pub fn new(max_offset: usize) -> Self {
        Self { per_offset: vec![(0, 0); max_offset] }
    }

    pub fn record(&mut self, offset: usize, predicted: &[u32], actual: &[u32]) {
        if offset == 0 || offset > self.per_offset.len() {
            return;
        }
        let slot = &mut self.per_offset[offset - 1];
        for a in actual {
            slot.1 += 1;
            if predicted.contains(a) {
                slot.0 += 1;
            }
        }
    }

    /// Realized accuracy at `offset` (1-based, like [`Self::record`]).
    /// Out-of-range offsets — including 0 — report 0.0 instead of
    /// panicking on the `offset - 1` index.
    pub fn accuracy(&self, offset: usize) -> f64 {
        if offset == 0 || offset > self.per_offset.len() {
            return 0.0;
        }
        let (h, t) = self.per_offset[offset - 1];
        if t == 0 {
            0.0
        } else {
            h as f64 / t as f64
        }
    }
}

/// One layer's outstanding prediction: the predicted expert ids (accuracy
/// scoring) and exactly the (key, pool) pins taken for them (release).
/// Tracking pins explicitly — instead of blindly unpinning both pools —
/// keeps every `CachePool::unpin` matched to a real pin, which the pools
/// now assert.
struct PendingPrediction {
    experts: Vec<u32>,
    pinned: Vec<(ExpertKey, Pool)>,
}

/// The predictor proper.
pub struct Predictor {
    pub depth: usize,
    pub top_k: usize,
    pub t1: f64,
    pub t2: f64,
    /// mixed-precision prefetching on/off (Fig 17b ablation)
    pub dynamic: bool,
    pub tracker: AccuracyTracker,
    /// last predictions per absolute layer (for accuracy scoring + unpin)
    pending: Vec<Option<PendingPrediction>>,
    /// per-expert gate-score EMA over *observed* (realized) gate
    /// distributions — the hot-expert signal replica placement keys on
    heat: HashMap<ExpertKey, f32>,
    /// gate width learned from the first observed distribution (0 until
    /// then, which keeps [`Self::hot`] false before any evidence exists)
    n_experts: usize,
}

impl Predictor {
    pub fn new(depth: usize, top_k: usize, t1: f64, t2: f64, dynamic: bool, n_layers: u32) -> Self {
        Self {
            depth,
            top_k,
            t1,
            t2,
            dynamic,
            tracker: AccuracyTracker::new(depth.max(1)),
            pending: (0..n_layers).map(|_| None).collect(),
            heat: HashMap::new(),
            n_experts: 0,
        }
    }

    /// Decide prefetches from the stacked gate output.
    ///
    /// `stacked_probs[j]` is the predicted gate distribution for layer
    /// `current_layer + j` (index 0 = the current layer's real gating,
    /// which on-demand selection consumes — not this function).
    ///
    /// Walks j = 1.. while the predicted experts of layer j are already
    /// cached; the first uncovered layer yields the prefetch plan (Fig 8).
    /// Predicted experts of *covered* layers are pinned so they survive
    /// until use.
    pub fn plan(
        &mut self,
        cache: &mut CacheManager,
        current_layer: u32,
        n_layers: u32,
        stacked_probs: &[Vec<f32>],
    ) -> Option<LayerPrediction> {
        let mut plan = None;
        for j in 1..stacked_probs.len() {
            let layer = current_layer + j as u32;
            if layer >= n_layers {
                break;
            }
            let decisions =
                scorer::decide(&stacked_probs[j], self.top_k, self.t1, self.t2, self.dynamic);
            let mut experts = Vec::with_capacity(decisions.len());
            let mut predicted_ids = Vec::with_capacity(decisions.len());
            for d in &decisions {
                let key = ExpertKey::new(layer, d.expert);
                predicted_ids.push(d.expert);
                experts.push((key, d.class));
            }
            // release pins of a superseded prediction for this layer before
            // recording the new one (predictions refresh every token)
            if let Some(old) = self.pending[layer as usize].take() {
                release_pins(cache, &old.pinned);
            }
            // pin predictions in whichever pool they will be read from,
            // remembering exactly what was pinned for balanced release
            let mut covered = true;
            let mut pinned: Vec<(ExpertKey, Pool)> = Vec::new();
            for (key, class) in &experts {
                let pool = match class {
                    Class::Hi => Pool::Hi,
                    Class::Lo | Class::Skip => Pool::Lo,
                };
                if cache.contains(*key, pool) {
                    let live = match pool {
                        Pool::Hi => cache.hi.pin(*key),
                        Pool::Lo => cache.lo.pin(*key),
                    };
                    debug_assert!(live, "predicted {key:?} vanished between probe and pin");
                    pinned.push((*key, pool));
                } else if *class != Class::Skip {
                    covered = false;
                }
            }
            self.pending[layer as usize] =
                Some(PendingPrediction { experts: predicted_ids, pinned });
            if !covered {
                plan = Some(LayerPrediction { layer, experts });
                break; // first uncovered layer is where prefetching helps
            }
        }
        plan
    }

    /// Cross-tier staging candidates: every predicted (expert, class) over
    /// the WHOLE stacked horizon, with no pins taken, no cache probes, and
    /// no early stop at the first uncovered layer. [`Self::plan`] answers
    /// "what must move DRAM → HBM next"; this answers "what will be wanted
    /// over the next `depth` layers at all" — the remote tier uses it to
    /// pull peer-resident experts into local DRAM ahead of demand, a
    /// fetch whose latency is far too long to hide inside `plan`'s
    /// one-layer window.
    pub fn stage_candidates(
        &self,
        current_layer: u32,
        n_layers: u32,
        stacked_probs: &[Vec<f32>],
    ) -> Vec<(ExpertKey, Class)> {
        let mut out = Vec::new();
        for j in 1..stacked_probs.len() {
            let layer = current_layer + j as u32;
            if layer >= n_layers {
                break;
            }
            let decisions =
                scorer::decide(&stacked_probs[j], self.top_k, self.t1, self.t2, self.dynamic);
            for d in &decisions {
                if d.class != Class::Skip {
                    out.push((ExpertKey::new(layer, d.expert), d.class));
                }
            }
        }
        out
    }

    /// Score a layer's realized top-k against the pending prediction and
    /// release pins. Call when `layer` is actually executed.
    pub fn observe(&mut self, cache: &mut CacheManager, layer: u32, actual_probs: &[f32]) {
        // fold the realized gate distribution into the per-expert heat EMA
        // (the hot-expert replica signal); experts never observed decay
        // implicitly by staying at their last value until seen again
        self.n_experts = actual_probs.len();
        for (e, &p) in actual_probs.iter().enumerate() {
            let key = ExpertKey::new(layer, e as u32);
            let h = self.heat.entry(key).or_insert(0.0);
            *h = (1.0 - HEAT_ALPHA) * *h + HEAT_ALPHA * p;
        }
        let actual: Vec<u32> =
            topk(actual_probs, self.top_k).iter().map(|(i, _)| *i as u32).collect();
        if let Some(p) = self.pending[layer as usize].take() {
            // offset bookkeeping: predictions always come from layer-1..layer-depth;
            // we attribute to offset 1 (the paper reports next-1 dominant)
            self.tracker.record(1, &p.experts, &actual);
            release_pins(cache, &p.pinned);
        }
    }

    /// Hot-expert probe for replica placement: true when the expert's
    /// gate-score EMA sits [`HEAT_HOT_FACTOR`]× above the uniform share
    /// `1/n_experts`. False before any distribution has been observed.
    pub fn hot(&self, key: ExpertKey) -> bool {
        if self.n_experts == 0 {
            return false;
        }
        let threshold = HEAT_HOT_FACTOR / self.n_experts as f32;
        self.heat.get(&key).is_some_and(|&h| h >= threshold)
    }
}

/// Release exactly the pins a prediction took; every unpin must find a
/// matching pin (the pools report and we assert).
fn release_pins(cache: &mut CacheManager, pinned: &[(ExpertKey, Pool)]) {
    for (key, pool) in pinned {
        let had_pin = match pool {
            Pool::Hi => cache.hi.unpin(*key),
            Pool::Lo => cache.lo.unpin(*key),
        };
        debug_assert!(had_pin, "prediction unpin without matching pin for {key:?}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Policy;

    fn mk_cache() -> CacheManager {
        CacheManager::new(4, 4, 8, 8, 8, 4, Policy::Lru, 0.25)
    }

    fn probs(hot: usize, e: usize) -> Vec<f32> {
        let mut p = vec![0.02f32; e];
        p[hot] = 0.9;
        let s: f32 = p.iter().sum();
        p.iter().map(|x| x / s).collect()
    }

    #[test]
    fn plan_stops_at_first_uncovered_layer() {
        let mut cache = mk_cache();
        // layer 1's hot expert (0) cached; layer 2's (1) not
        cache.reserve(ExpertKey::new(1, 0), Pool::Hi, 0).unwrap();
        cache.commit(ExpertKey::new(1, 0), Pool::Hi);
        // skipping class for the weak second expert: also satisfied
        let mut pred = Predictor::new(3, 2, 0.6, 0.9, true, 4);
        let stacked = vec![probs(0, 4), probs(0, 4), probs(1, 4), probs(2, 4)];
        let plan = pred.plan(&mut cache, 0, 4, &stacked).expect("plan");
        assert_eq!(plan.layer, 2);
        assert!(plan.experts.iter().any(|(k, _)| k.expert == 1));
    }

    #[test]
    fn plan_none_when_all_covered() {
        let mut cache = mk_cache();
        for l in 1..4 {
            cache.reserve(ExpertKey::new(l, 0), Pool::Hi, 0).unwrap();
            cache.commit(ExpertKey::new(l, 0), Pool::Hi);
        }
        let mut pred = Predictor::new(3, 2, 0.6, 0.9, true, 4);
        let stacked = vec![probs(0, 4); 4];
        assert!(pred.plan(&mut cache, 0, 4, &stacked).is_none());
    }

    #[test]
    fn observe_tracks_accuracy_and_unpins() {
        let mut cache = mk_cache();
        cache.reserve(ExpertKey::new(1, 0), Pool::Hi, 0).unwrap();
        cache.commit(ExpertKey::new(1, 0), Pool::Hi);
        let mut pred = Predictor::new(2, 2, 0.6, 0.9, true, 4);
        let stacked = vec![probs(0, 4), probs(0, 4)];
        let _ = pred.plan(&mut cache, 0, 4, &stacked);
        // actual top-2 of layer 1 includes expert 0 -> 1 hit of 2
        pred.observe(&mut cache, 1, &probs(0, 4));
        assert!(pred.tracker.accuracy(1) > 0.49);
        assert!(!cache.hi.pinned_contains(ExpertKey::new(1, 0)));
    }

    #[test]
    fn accuracy_tracker_math() {
        let mut t = AccuracyTracker::new(2);
        t.record(1, &[0, 1], &[1, 2]);
        assert!((t.accuracy(1) - 0.5).abs() < 1e-12);
        t.record(2, &[5], &[5]);
        assert!((t.accuracy(2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_out_of_range_offsets_do_not_panic() {
        // regression: offset 0 and offset > len used to index out of bounds
        let mut t = AccuracyTracker::new(2);
        t.record(1, &[0], &[0]);
        assert_eq!(t.accuracy(0), 0.0);
        assert_eq!(t.accuracy(3), 0.0);
        assert_eq!(t.accuracy(usize::MAX), 0.0);
        // record() already guarded these; accuracy() now matches
        t.record(0, &[0], &[0]);
        t.record(9, &[0], &[0]);
        assert!((t.accuracy(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stage_candidates_cover_whole_horizon_without_pins() {
        let mut cache = mk_cache();
        // layer 1's hot expert already cached: plan() stops early, but the
        // staging view keeps walking and never pins anything
        cache.reserve(ExpertKey::new(1, 0), Pool::Hi, 0).unwrap();
        cache.commit(ExpertKey::new(1, 0), Pool::Hi);
        let pred = Predictor::new(3, 2, 0.6, 0.9, true, 4);
        let stacked = vec![probs(0, 4), probs(0, 4), probs(1, 4), probs(2, 4)];
        let cands = pred.stage_candidates(0, 4, &stacked);
        let layers: Vec<u32> = cands.iter().map(|(k, _)| k.layer).collect();
        assert!(layers.contains(&1) && layers.contains(&2) && layers.contains(&3));
        assert!(cands.iter().any(|(k, _)| k.layer == 2 && k.expert == 1));
        assert!(cands.iter().all(|(_, c)| *c != Class::Skip));
        assert!(!cache.hi.pinned_contains(ExpertKey::new(1, 0)));
        // clamps at the model end like plan()
        assert!(pred.stage_candidates(3, 4, &stacked).is_empty());
    }

    #[test]
    fn heat_ema_marks_skewed_experts_hot() {
        let mut cache = mk_cache();
        let mut pred = Predictor::new(2, 2, 0.6, 0.9, true, 4);
        // no evidence yet: nothing is hot
        assert!(!pred.hot(ExpertKey::new(1, 0)));
        // a steady 0.9 gate share converges the EMA well past 1.25/4
        for _ in 0..20 {
            pred.observe(&mut cache, 1, &probs(0, 4));
        }
        assert!(pred.hot(ExpertKey::new(1, 0)), "skewed expert should be hot");
        assert!(!pred.hot(ExpertKey::new(1, 1)), "cold expert stays cold");
        assert!(!pred.hot(ExpertKey::new(2, 0)), "heat is per (layer, expert)");
        // shifting the distribution cools the old favourite
        for _ in 0..60 {
            pred.observe(&mut cache, 1, &probs(3, 4));
        }
        assert!(!pred.hot(ExpertKey::new(1, 0)), "EMA decays when traffic moves");
        assert!(pred.hot(ExpertKey::new(1, 3)));
    }

    #[test]
    fn plan_clamps_at_model_end() {
        let mut cache = mk_cache();
        let mut pred = Predictor::new(4, 2, 0.6, 0.9, true, 4);
        let stacked = vec![probs(0, 4); 5];
        // current layer 3 of 4: nothing to predict
        assert!(pred.plan(&mut cache, 3, 4, &stacked).is_none());
    }
}
