//! Compute executors: the engine's per-layer math units behind one
//! dispatch point.
//!
//! The engine composes four units per layer — attention, stacked gating,
//! expert FFN, LM head. [`Exec`] is the seam between that composition and
//! *how* the units run:
//!
//! * [`PjrtExec`] — the production path: AOT-compiled HLO artifacts
//!   executed through the PJRT C API (moved here from `Engine`). Batched
//!   decode widths run as one launch when the manifest carries the
//!   `*_s{w}` variants (`runtime::Manifest::decode_batch_widths`, up to
//!   the grouped-width ladder) and fall back to per-row s=1 launches when
//!   it does not; the fallback is bit-identical per row, so batching never
//!   changes a sequence's logits. Grouped expert execution
//!   ([`Exec::expert_grouped`]) gathers each expert's routed rows into a
//!   compact slab padded to the smallest compiled expert width
//!   (`runtime::Manifest::grouped_expert_widths`), so a (batch, layer)
//!   step costs one launch per *unique expert* instead of one per
//!   (row, expert) pair.
//! * [`RefExec`] — pure-Rust reference kernels mirroring
//!   `python/compile/model.py` (RMSNorm + RoPE GQA attention, softmax
//!   gating, SwiGLU experts with group-dequant, tied-embedding head).
//!   Needs no artifacts, so the batched-decode regression suite — and CI —
//!   can drive the full engine/coordinator/residency stack from a
//!   synthesized weight directory (`model::synth`). Every op is computed
//!   row-independently in a fixed accumulation order, which is what makes
//!   the batch-vs-sequential equivalence tests exact (bit-identical), not
//!   approximate.
//!
//! Attention is per-row even in a batched decode step: each sequence has
//! its own KV cache and position, which the `attn_s{w}` artifact signature
//! (one cache, consecutive positions) cannot express. Gate, expert FFN,
//! and head batch across the padded launch width.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use xla::Literal;

use crate::config::ModelConfig;
use crate::model::{expert_literals, NonExpertWeights};
use crate::quant;
use crate::runtime::{lit_f32, lit_i32, lit_to_f32, Runtime};
use crate::tensor::softmax;
use crate::{ExpertKey, Precision};

use super::{EngineOptions, KvState};

/// Norm epsilon / RoPE base of the compiled models
/// (`python/compile/configs.py` defaults; not carried by the manifest).
const NORM_EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 10000.0;

/// One expert group of a grouped FFN step: the expert's record at the
/// tier it is resident at, plus the full-width gate weights (zero for
/// rows not routed here — exactly the per-row path's contract, so the
/// group's routed-row set is `gatew[r] != 0`).
pub(crate) struct GroupSpec<'a> {
    pub key: ExpertKey,
    pub prec: Precision,
    pub record: &'a [u8],
    pub gatew: &'a [f32],
}

/// What a grouped FFN step actually cost: launches issued, routed rows
/// served, and per-row dequants avoided by parsing each group's record
/// once (`routed - 1` per group — the dequant-once invariant).
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct GroupedExecStats {
    pub launches: u64,
    pub rows: u64,
    pub dequant_reuses: u64,
}

/// One executor behind the engine: either the AOT PJRT artifacts or the
/// pure-Rust reference kernels.
pub(crate) enum Exec {
    Pjrt(PjrtExec),
    Reference(RefExec),
}

impl Exec {
    /// Attention for layer `li` over `s` rows of one sequence (prefill
    /// chunk or a single decode row); updates `kv` in place.
    pub fn attn(
        &mut self,
        li: usize,
        s: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: i32,
    ) -> Result<Vec<f32>> {
        match self {
            Exec::Pjrt(e) => e.attn(li, s, x, kv, pos),
            Exec::Reference(e) => e.attn(li, s, x, kv, pos),
        }
    }

    /// Gating for layer `li`: stacked (Stacking Computer) on decode,
    /// single on prefill. Returns (p_eff, probs [p_eff, s, e], normed
    /// hidden [s, d]). `live` marks the rows whose outputs the caller
    /// will read (None = all): per-row fallbacks and the reference
    /// kernels skip dead/padding rows, leaving zeros.
    pub fn gate(
        &mut self,
        li: usize,
        s: usize,
        decode: bool,
        x: &[f32],
        live: Option<&[bool]>,
    ) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        match self {
            Exec::Pjrt(e) => e.gate(li, s, decode, x, live),
            Exec::Reference(e) => e.gate(li, s, decode, x, live),
        }
    }

    /// One expert's weighted SwiGLU FFN over `s` rows; `gatew[r] == 0`
    /// rows are not routed here and contribute zero.
    pub fn expert(
        &mut self,
        s: usize,
        prec: Precision,
        record: &[u8],
        hn: &[f32],
        gatew: &[f32],
        key: ExpertKey,
    ) -> Result<Vec<f32>> {
        match self {
            Exec::Pjrt(e) => e.expert(s, prec, record, hn, gatew, key),
            Exec::Reference(e) => e.expert(s, prec, record, hn, gatew),
        }
    }

    /// The whole FFN of one (batch, layer) step as grouped launches: one
    /// entry per expert group (tokens pre-sorted by expert — the caller's
    /// group order is the accumulation order). Returns each group's
    /// full-width output plus launch/dequant accounting. Every group's
    /// record is parsed exactly once; rows are computed with the same
    /// row-local arithmetic as [`Self::expert`], so grouped execution is
    /// bit-identical to the per-row path.
    pub fn expert_grouped(
        &mut self,
        s: usize,
        hn: &[f32],
        groups: &[GroupSpec<'_>],
    ) -> Result<(Vec<Vec<f32>>, GroupedExecStats)> {
        match self {
            Exec::Pjrt(e) => e.expert_grouped(s, hn, groups),
            Exec::Reference(e) => e.expert_grouped(s, hn, groups),
        }
    }

    /// LM head over `s` rows: final norm + tied-embedding logits
    /// [s, vocab]. `live` as in [`Self::gate`].
    pub fn head(&mut self, s: usize, x: &[f32], live: Option<&[bool]>) -> Result<Vec<f32>> {
        match self {
            Exec::Pjrt(e) => e.head(s, x, live),
            Exec::Reference(e) => e.head(s, x, live),
        }
    }

    pub fn platform(&self) -> String {
        match self {
            Exec::Pjrt(e) => e.rt.platform(),
            Exec::Reference(_) => "reference-cpu".to_string(),
        }
    }

    /// Cumulative wall time inside the executor's compute calls.
    pub fn compute_time(&self) -> Duration {
        match self {
            Exec::Pjrt(e) => e.rt.compute_time.get(),
            Exec::Reference(e) => e.compute.get(),
        }
    }

    /// Decode widths served as one native launch (vs the per-row
    /// fallback). The reference kernels batch natively at every width.
    pub fn batched_widths(&self) -> &[usize] {
        match self {
            Exec::Pjrt(e) => &e.batched,
            Exec::Reference(e) => &e.batched,
        }
    }

    pub fn runtime(&self) -> Option<&Runtime> {
        match self {
            Exec::Pjrt(e) => Some(&e.rt),
            Exec::Reference(_) => None,
        }
    }

    pub fn runtime_mut(&mut self) -> Option<&mut Runtime> {
        match self {
            Exec::Pjrt(e) => Some(&mut e.rt),
            Exec::Reference(_) => None,
        }
    }
}

// ---------------------------------------------------------------------
// PJRT executor (the production path)
// ---------------------------------------------------------------------

/// Precomputed per-layer literal sets (built once; the request path never
/// re-creates weight literals — perf-critical).
struct LayerLits {
    attn: [Literal; 5], // norm, wq, wk, wv, wo
    /// decode gate stack for this layer: (p_eff, pn[p,d], wg[p,d,E])
    gate_stack: (usize, Literal, Literal),
    /// prefill gate (p = 1)
    gate_single: (Literal, Literal),
}

pub(crate) struct PjrtExec {
    pub(crate) rt: Runtime,
    cfg: ModelConfig,
    layers: Vec<LayerLits>,
    emb_lit: Literal,
    final_norm_lit: Literal,
    pub(crate) ffn_prefix: &'static str,
    /// sequence-chunk widths with compiled artifacts (s=1 + prefill)
    chunk_s: Vec<usize>,
    /// batched decode widths with a full compiled variant set
    batched: Vec<usize>,
    /// expert-group launch widths (ascending) with compiled FFN variants
    /// for every precision in use; a routed group pads to the smallest
    /// one that fits and chunks at the largest
    grouped_ws: Vec<usize>,
}

impl PjrtExec {
    pub fn new(
        mut rt: Runtime,
        cfg: &ModelConfig,
        nonexpert: &NonExpertWeights,
        opts: &EngineOptions,
    ) -> Result<Self> {
        // ---- compile the artifacts this configuration uses ----------------
        let hi = opts.policy.hi_precision;
        let lo = opts.policy.lo_precision;
        // older artifact sets may not carry the fast lowerings
        let fast = opts.use_fast_ffn
            && rt.manifest.artifacts.contains_key("expert_fast_f32_s1");
        let ffn_prefix = if fast { "expert_fast" } else { "expert" };
        let depth = opts.policy.prefetch_depth;
        let stack_p = (depth + 1).min(4).max(1);
        // a pinned fetch precision may be a third tier (neither hi nor
        // lo): its FFN variants must be compiled too, or tier-at-use
        // execution would have no artifact to launch
        let mut precs = vec![hi, lo];
        if let Some(p) = opts.policy.pin_precision {
            if !precs.contains(&p) {
                precs.push(p);
            }
        }
        let mut names: Vec<String> = Vec::new();
        for s in [1usize, 16, 128] {
            names.push(format!("attn_s{s}"));
            names.push(format!("head_s{s}"));
            for p in &precs {
                names.push(format!("{ffn_prefix}_{}_s{s}", p.name()));
            }
        }
        for p in 1..=stack_p {
            names.push(format!("gate_p{p}_s1"));
        }
        for s in [16usize, 128] {
            names.push(format!("gate_p1_s{s}"));
        }
        // batched decode variants, where the artifact set carries them
        let batched =
            rt.manifest.decode_batch_widths(stack_p, ffn_prefix, hi.name(), lo.name());
        for &w in &batched {
            names.push(format!("head_s{w}"));
            for p in &precs {
                names.push(format!("{ffn_prefix}_{}_s{w}", p.name()));
            }
            for p in 1..=stack_p {
                names.push(format!("gate_p{p}_s{w}"));
            }
        }
        // expert-group widths for ragged grouped execution: a width is
        // usable only when *every* precision in use is compiled at it
        // (a mid-step tier flip must never change the launch width)
        let mut grouped_ws =
            rt.manifest.grouped_expert_widths(ffn_prefix, hi.name(), lo.name());
        grouped_ws.retain(|&w| {
            precs
                .iter()
                .all(|p| rt.manifest.has_variant(&format!("{ffn_prefix}_{}", p.name()), w))
        });
        for &w in &grouped_ws {
            for p in &precs {
                names.push(format!("{ffn_prefix}_{}_s{w}", p.name()));
            }
        }
        // the prefill chunk widths are compiled unconditionally above and
        // double as group widths
        for w in [16usize, 128] {
            if !grouped_ws.contains(&w) {
                grouped_ws.push(w);
            }
        }
        grouped_ws.sort_unstable();
        rt.ensure_all(names.iter().map(|s| s.as_str()))?;

        // ---- per-layer literals -------------------------------------------
        let l = cfg.n_layers as usize;
        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let mk = |name: &str| -> Result<Literal> {
                let (shape, data) = nonexpert.get(name)?;
                lit_f32(shape, data)
            };
            let attn = [
                mk(&format!("attn_norm.{li}"))?,
                mk(&format!("wq.{li}"))?,
                mk(&format!("wk.{li}"))?,
                mk(&format!("wv.{li}"))?,
                mk(&format!("wo.{li}"))?,
            ];
            // decode gate stack: layers li .. li+p_eff-1
            let p_eff = stack_p.min(l - li);
            let mut pn = Vec::with_capacity(p_eff * cfg.d_model);
            let mut wg = Vec::with_capacity(p_eff * cfg.d_model * cfg.n_experts as usize);
            for j in 0..p_eff {
                let (_, pnj) = nonexpert.get(&format!("post_norm.{}", li + j))?;
                pn.extend_from_slice(pnj);
                let (_, wgj) = nonexpert.get(&format!("wg.{}", li + j))?;
                wg.extend_from_slice(wgj);
            }
            let e = cfg.n_experts as usize;
            let gate_stack = (
                p_eff,
                lit_f32(&[p_eff, cfg.d_model], &pn)?,
                lit_f32(&[p_eff, cfg.d_model, e], &wg)?,
            );
            let (_, pn0) = nonexpert.get(&format!("post_norm.{li}"))?;
            let (_, wg0) = nonexpert.get(&format!("wg.{li}"))?;
            let gate_single = (
                lit_f32(&[1, cfg.d_model], pn0)?,
                lit_f32(&[1, cfg.d_model, e], wg0)?,
            );
            layers.push(LayerLits { attn, gate_stack, gate_single });
        }

        let (emb_shape, emb) = nonexpert.get("emb")?;
        let emb_lit = lit_f32(emb_shape, emb)?;
        let (_, fnorm) = nonexpert.get("final_norm")?;
        let final_norm_lit = lit_f32(&[cfg.d_model], fnorm)?;

        let mut chunk_s = vec![1usize, 16, 128];
        chunk_s.extend(batched.iter().copied());

        Ok(Self {
            rt,
            cfg: cfg.clone(),
            layers,
            emb_lit,
            final_norm_lit,
            ffn_prefix,
            chunk_s,
            batched,
            grouped_ws,
        })
    }

    /// Whether a single launch of width `s` is compiled.
    fn has_width(&self, s: usize) -> bool {
        self.chunk_s.contains(&s)
    }

    /// Smallest compiled group width that fits `g` routed rows; the
    /// largest one when `g` exceeds them all (the group then chunks).
    fn group_width(&self, g: usize) -> Option<usize> {
        self.grouped_ws
            .iter()
            .copied()
            .find(|&w| w >= g)
            .or_else(|| self.grouped_ws.last().copied())
    }

    fn attn(
        &mut self,
        li: usize,
        s: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: i32,
    ) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let x_lit = lit_f32(&[s, d], x)?;
        let kdims = [self.cfg.max_seq, self.cfg.n_kv_heads, self.cfg.head_dim()];
        let k_lit = lit_f32(&kdims, &kv.k[li])?;
        let v_lit = lit_f32(&kdims, &kv.v[li])?;
        let pos_lit = lit_i32(pos);
        let ll = &self.layers[li];
        let args: Vec<&Literal> = vec![
            &x_lit, &ll.attn[0], &ll.attn[1], &ll.attn[2], &ll.attn[3], &ll.attn[4],
            &k_lit, &v_lit, &pos_lit,
        ];
        let outs = self.rt.execute(&format!("attn_s{s}"), &args)?;
        anyhow::ensure!(outs.len() == 3, "attn outputs");
        let y = lit_to_f32(&outs[0])?;
        kv.k[li] = lit_to_f32(&outs[1])?;
        kv.v[li] = lit_to_f32(&outs[2])?;
        Ok(y)
    }

    fn gate(
        &mut self,
        li: usize,
        s: usize,
        decode: bool,
        x: &[f32],
        live: Option<&[bool]>,
    ) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        let d = self.cfg.d_model;
        let e = self.cfg.n_experts as usize;
        if decode {
            let (p_eff, ref pn, ref wg) = self.layers[li].gate_stack;
            if s == 1 || self.batched.contains(&s) {
                let x_lit = lit_f32(&[s, d], x)?;
                let args: Vec<&Literal> = vec![&x_lit, pn, wg];
                let outs = self.rt.execute(&format!("gate_p{p_eff}_s{s}"), &args)?;
                return Ok((p_eff, lit_to_f32(&outs[0])?, lit_to_f32(&outs[1])?));
            }
            // batched width with no compiled variant: per-row s=1 launches,
            // stitched into the [p_eff, s, e] layout (bit-identical per
            // row); padding/dead rows are not worth a launch
            let mut probs = vec![0.0f32; p_eff * s * e];
            let mut hn = vec![0.0f32; s * d];
            for r in 0..s {
                if live.map(|m| !m[r]).unwrap_or(false) {
                    continue;
                }
                let x_lit = lit_f32(&[1, d], &x[r * d..(r + 1) * d])?;
                let args: Vec<&Literal> = vec![&x_lit, pn, wg];
                let outs = self.rt.execute(&format!("gate_p{p_eff}_s1"), &args)?;
                let pr = lit_to_f32(&outs[0])?;
                let hr = lit_to_f32(&outs[1])?;
                for j in 0..p_eff {
                    probs[j * s * e + r * e..j * s * e + (r + 1) * e]
                        .copy_from_slice(&pr[j * e..(j + 1) * e]);
                }
                hn[r * d..(r + 1) * d].copy_from_slice(&hr);
            }
            Ok((p_eff, probs, hn))
        } else {
            let (ref pn, ref wg) = self.layers[li].gate_single;
            let x_lit = lit_f32(&[s, d], x)?;
            let args: Vec<&Literal> = vec![&x_lit, pn, wg];
            let outs = self.rt.execute(&format!("gate_p1_s{s}"), &args)?;
            Ok((1usize, lit_to_f32(&outs[0])?, lit_to_f32(&outs[1])?))
        }
    }

    fn expert(
        &mut self,
        s: usize,
        prec: Precision,
        record: &[u8],
        hn: &[f32],
        gatew: &[f32],
        key: ExpertKey,
    ) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        if self.has_width(s) {
            let name = format!("{}_{}_s{s}", self.ffn_prefix, prec.name());
            let mut args: Vec<Literal> = Vec::with_capacity(8);
            args.push(lit_f32(&[s, d], hn)?);
            args.extend(expert_literals(&self.cfg, prec, record)?);
            args.push(lit_f32(&[s], gatew)?);
            let outs = self
                .rt
                .execute(&name, &args)
                .with_context(|| format!("expert {key:?} via {name}"))?;
            return lit_to_f32(&outs[0]);
        }
        // padded width with no compiled variant: one s=1 launch per routed
        // row (zero-weight rows contribute zero and are skipped)
        let name = format!("{}_{}_s1", self.ffn_prefix, prec.name());
        let wlits = expert_literals(&self.cfg, prec, record)?;
        let mut out = vec![0.0f32; s * d];
        for r in 0..s {
            if gatew[r] == 0.0 {
                continue;
            }
            let x_lit = lit_f32(&[1, d], &hn[r * d..(r + 1) * d])?;
            let gw_lit = lit_f32(&[1], &gatew[r..r + 1])?;
            let mut args: Vec<&Literal> = Vec::with_capacity(8);
            args.push(&x_lit);
            args.extend(wlits.iter());
            args.push(&gw_lit);
            let outs = self
                .rt
                .execute(&name, &args)
                .with_context(|| format!("expert {key:?} via {name} (row {r})"))?;
            let y = lit_to_f32(&outs[0])?;
            out[r * d..(r + 1) * d].copy_from_slice(&y);
        }
        Ok(out)
    }

    fn expert_grouped(
        &mut self,
        s: usize,
        hn: &[f32],
        groups: &[GroupSpec<'_>],
    ) -> Result<(Vec<Vec<f32>>, GroupedExecStats)> {
        let d = self.cfg.d_model;
        let mut outs = Vec::with_capacity(groups.len());
        let mut st = GroupedExecStats::default();
        for g in groups {
            let routed: Vec<usize> = (0..s).filter(|&r| g.gatew[r] != 0.0).collect();
            if routed.is_empty() {
                outs.push(vec![0.0f32; s * d]);
                continue;
            }
            st.rows += routed.len() as u64;
            st.dequant_reuses += routed.len() as u64 - 1;
            // gather only when a group width is tighter than the full
            // batch width (or the full width has no compiled variant)
            let gather = self
                .group_width(routed.len())
                .filter(|&w| !self.has_width(s) || w < s);
            let y = match gather {
                Some(w) => {
                    let name = format!("{}_{}_s{w}", self.ffn_prefix, g.prec.name());
                    let wlits = expert_literals(&self.cfg, g.prec, g.record)?;
                    let mut out = vec![0.0f32; s * d];
                    // pad the group's routed rows to the compiled width;
                    // oversized groups chunk in ascending-row order (row
                    // outputs are row-local, so order is cosmetic)
                    for chunk in routed.chunks(w) {
                        let mut xg = vec![0.0f32; w * d];
                        let mut gwv = vec![0.0f32; w];
                        for (i, &r) in chunk.iter().enumerate() {
                            xg[i * d..(i + 1) * d].copy_from_slice(&hn[r * d..(r + 1) * d]);
                            gwv[i] = g.gatew[r];
                        }
                        let x_lit = lit_f32(&[w, d], &xg)?;
                        let gw_lit = lit_f32(&[w], &gwv)?;
                        let mut args: Vec<&Literal> = Vec::with_capacity(8);
                        args.push(&x_lit);
                        args.extend(wlits.iter());
                        args.push(&gw_lit);
                        let louts = self
                            .rt
                            .execute(&name, &args)
                            .with_context(|| format!("expert {:?} via {name} (group)", g.key))?;
                        st.launches += 1;
                        let yg = lit_to_f32(&louts[0])?;
                        for (i, &r) in chunk.iter().enumerate() {
                            out[r * d..(r + 1) * d].copy_from_slice(&yg[i * d..(i + 1) * d]);
                        }
                    }
                    out
                }
                None => {
                    // one compiled full-width launch, or the bit-identical
                    // per-row s=1 ladder when nothing wider exists
                    st.launches +=
                        if self.has_width(s) { 1 } else { routed.len() as u64 };
                    self.expert(s, g.prec, g.record, hn, g.gatew, g.key)?
                }
            };
            outs.push(y);
        }
        Ok((outs, st))
    }

    fn head(&mut self, s: usize, x: &[f32], live: Option<&[bool]>) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        if self.has_width(s) {
            let x_lit = lit_f32(&[s, d], x)?;
            let args: Vec<&Literal> = vec![&x_lit, &self.final_norm_lit, &self.emb_lit];
            let outs = self.rt.execute(&format!("head_s{s}"), &args)?;
            return lit_to_f32(&outs[0]);
        }
        let mut out = vec![0.0f32; s * v];
        for r in 0..s {
            if live.map(|m| !m[r]).unwrap_or(false) {
                continue;
            }
            let x_lit = lit_f32(&[1, d], &x[r * d..(r + 1) * d])?;
            let args: Vec<&Literal> = vec![&x_lit, &self.final_norm_lit, &self.emb_lit];
            let outs = self.rt.execute("head_s1", &args)?;
            let y = lit_to_f32(&outs[0])?;
            out[r * v..(r + 1) * v].copy_from_slice(&y);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// Reference executor (pure Rust, artifact-free)
// ---------------------------------------------------------------------

pub(crate) struct RefExec {
    cfg: ModelConfig,
    stack_p: usize,
    emb: Vec<f32>,            // [v, d]
    final_norm: Vec<f32>,     // [d]
    attn_norm: Vec<Vec<f32>>, // per layer [d]
    wq: Vec<Vec<f32>>,        // per layer [d, h*hd]
    wk: Vec<Vec<f32>>,        // per layer [d, hkv*hd]
    wv: Vec<Vec<f32>>,        // per layer [d, hkv*hd]
    wo: Vec<Vec<f32>>,        // per layer [h*hd, d]
    post_norm: Vec<Vec<f32>>, // per layer [d]
    wg: Vec<Vec<f32>>,        // per layer [d, e]
    batched: Vec<usize>,
    compute: std::cell::Cell<Duration>,
}

/// out[r, c] = sum_i x[r, i] * w[i, c] with a fixed (ascending-i)
/// accumulation order — determinism is the point, not speed.
fn matmul(x: &[f32], w: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let xr = &x[r * inner..(r + 1) * inner];
        let or = &mut out[r * cols..(r + 1) * cols];
        for (i, xv) in xr.iter().enumerate() {
            if *xv == 0.0 {
                // skipping exact-zero terms adds exact zeros — identical sum
                continue;
            }
            let wrow = &w[i * cols..(i + 1) * cols];
            for (o, wv) in or.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

fn rmsnorm_row(x: &[f32], w: &[f32]) -> Vec<f32> {
    let var = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (var + NORM_EPS).sqrt();
    x.iter().zip(w).map(|(xv, wv)| xv * r * wv).collect()
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotary embedding of one row's heads in place: q is [n_heads, hd].
fn rope_row(q: &mut [f32], n_heads: usize, hd: usize, pos: f32) {
    let half = hd / 2;
    for h in 0..n_heads {
        let head = &mut q[h * hd..(h + 1) * hd];
        for i in 0..half {
            let freq = 1.0 / ROPE_THETA.powf(i as f32 / half as f32);
            let t = pos * freq;
            let (sin, cos) = t.sin_cos();
            let a = head[i];
            let b = head[half + i];
            head[i] = a * cos - b * sin;
            head[half + i] = a * sin + b * cos;
        }
    }
}

fn le_f32(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

impl RefExec {
    pub fn new(cfg: &ModelConfig, nonexpert: &NonExpertWeights, stack_p: usize) -> Result<Self> {
        let l = cfg.n_layers as usize;
        let grab = |name: &str| -> Result<Vec<f32>> {
            let (_, data) = nonexpert.get(name)?;
            Ok(data.to_vec())
        };
        let mut attn_norm = Vec::with_capacity(l);
        let mut wq = Vec::with_capacity(l);
        let mut wk = Vec::with_capacity(l);
        let mut wv = Vec::with_capacity(l);
        let mut wo = Vec::with_capacity(l);
        let mut post_norm = Vec::with_capacity(l);
        let mut wg = Vec::with_capacity(l);
        for li in 0..l {
            attn_norm.push(grab(&format!("attn_norm.{li}"))?);
            wq.push(grab(&format!("wq.{li}"))?);
            wk.push(grab(&format!("wk.{li}"))?);
            wv.push(grab(&format!("wv.{li}"))?);
            wo.push(grab(&format!("wo.{li}"))?);
            post_norm.push(grab(&format!("post_norm.{li}"))?);
            wg.push(grab(&format!("wg.{li}"))?);
        }
        Ok(Self {
            cfg: cfg.clone(),
            stack_p: stack_p.clamp(1, 4),
            emb: grab("emb")?,
            final_norm: grab("final_norm")?,
            attn_norm,
            wq,
            wk,
            wv,
            wo,
            post_norm,
            wg,
            batched: crate::runtime::GROUPED_WIDTHS.to_vec(),
            compute: std::cell::Cell::new(Duration::ZERO),
        })
    }

    fn clock<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.compute.set(self.compute.get() + t0.elapsed());
        out
    }

    fn attn(
        &mut self,
        li: usize,
        s: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: i32,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(li < self.attn_norm.len(), "layer {li} out of range");
        let cfg = self.cfg.clone();
        let t0 = Instant::now();
        let d = cfg.d_model;
        let (h, hkv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let rep = h / hkv;
        let mut hn = vec![0.0f32; s * d];
        for r in 0..s {
            hn[r * d..(r + 1) * d]
                .copy_from_slice(&rmsnorm_row(&x[r * d..(r + 1) * d], &self.attn_norm[li]));
        }
        let mut q = matmul(&hn, &self.wq[li], s, d, h * hd);
        let mut kx = matmul(&hn, &self.wk[li], s, d, hkv * hd);
        let vx = matmul(&hn, &self.wv[li], s, d, hkv * hd);
        for r in 0..s {
            let p = (pos + r as i32) as f32;
            rope_row(&mut q[r * h * hd..(r + 1) * h * hd], h, hd, p);
            rope_row(&mut kx[r * hkv * hd..(r + 1) * hkv * hd], hkv, hd, p);
        }
        // write the new keys/values into the cache at pos..pos+s
        for r in 0..s {
            let at = (pos as usize + r) * hkv * hd;
            kv.k[li][at..at + hkv * hd].copy_from_slice(&kx[r * hkv * hd..(r + 1) * hkv * hd]);
            kv.v[li][at..at + hkv * hd].copy_from_slice(&vx[r * hkv * hd..(r + 1) * hkv * hd]);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        let mut ctx = vec![0.0f32; s * h * hd];
        for r in 0..s {
            // causal + length mask: row r (absolute pos+r) sees keys <= pos+r
            let visible = pos as usize + r + 1;
            for qh in 0..h {
                let g = qh / rep;
                let qrow = &q[(r * h + qh) * hd..(r * h + qh + 1) * hd];
                let mut scores = vec![0.0f32; visible];
                for (tt, sc) in scores.iter_mut().enumerate() {
                    let krow = &kv.k[li][(tt * hkv + g) * hd..(tt * hkv + g + 1) * hd];
                    *sc = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                let probs = softmax(&scores);
                let orow = &mut ctx[(r * h + qh) * hd..(r * h + qh + 1) * hd];
                for (tt, p) in probs.iter().enumerate() {
                    let vrow = &kv.v[li][(tt * hkv + g) * hd..(tt * hkv + g + 1) * hd];
                    for (o, vv) in orow.iter_mut().zip(vrow) {
                        *o += p * vv;
                    }
                }
            }
        }
        let proj = matmul(&ctx, &self.wo[li], s, h * hd, d);
        let y: Vec<f32> = x.iter().zip(&proj).map(|(a, b)| a + b).collect();
        self.compute.set(self.compute.get() + t0.elapsed());
        Ok(y)
    }

    fn gate(
        &mut self,
        li: usize,
        s: usize,
        decode: bool,
        x: &[f32],
        live: Option<&[bool]>,
    ) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(li < self.post_norm.len(), "layer {li} out of range");
        let l = self.cfg.n_layers as usize;
        let d = self.cfg.d_model;
        let e = self.cfg.n_experts as usize;
        let p_eff = if decode { self.stack_p.min(l - li).max(1) } else { 1 };
        let dead = |r: usize| live.map(|m| !m[r]).unwrap_or(false);
        self.clock(|| {
            let mut probs = vec![0.0f32; p_eff * s * e];
            for j in 0..p_eff {
                let lw = li + j;
                for r in 0..s {
                    if dead(r) {
                        continue;
                    }
                    let hnr = rmsnorm_row(&x[r * d..(r + 1) * d], &self.post_norm[lw]);
                    let logits = matmul(&hnr, &self.wg[lw], 1, d, e);
                    probs[j * s * e + r * e..j * s * e + (r + 1) * e]
                        .copy_from_slice(&softmax(&logits));
                }
            }
            let mut hn0 = vec![0.0f32; s * d];
            for r in 0..s {
                if dead(r) {
                    continue;
                }
                hn0[r * d..(r + 1) * d]
                    .copy_from_slice(&rmsnorm_row(&x[r * d..(r + 1) * d], &self.post_norm[li]));
            }
            Ok((p_eff, probs, hn0))
        })
    }

    /// Slice + (if quantized) group-dequantize an expert record into its
    /// three SwiGLU matrices, mirroring `model::expert_literals`.
    fn parse_record(&self, prec: Precision, record: &[u8]) -> Result<[Vec<f32>; 3]> {
        let d = self.cfg.d_model;
        let ff = self.cfg.d_ff;
        let g = self.cfg.quant_group;
        match prec {
            Precision::F32 => {
                let floats = le_f32(record);
                let n1 = d * ff;
                let n2 = ff * d;
                anyhow::ensure!(floats.len() == 2 * n1 + n2, "f32 record size mismatch");
                Ok([
                    floats[..n1].to_vec(),
                    floats[n1..2 * n1].to_vec(),
                    floats[2 * n1..].to_vec(),
                ])
            }
            _ => {
                let pack = prec.pack();
                let mut off = 0usize;
                let mut out: Vec<Vec<f32>> = Vec::with_capacity(3);
                for (rows, cols) in [(d, ff), (d, ff), (ff, d)] {
                    let nb = rows / pack * cols;
                    let packed = &record[off..off + nb];
                    off += nb;
                    let ns = rows / g * cols * 4;
                    let scales = le_f32(&record[off..off + ns]);
                    off += ns;
                    out.push(quant::dequantize(packed, &scales, rows, cols, g, prec));
                }
                anyhow::ensure!(off == record.len(), "quant record size mismatch");
                out.try_into().map_err(|_| anyhow!("record matrix count"))
            }
        }
    }

    fn expert(
        &mut self,
        s: usize,
        prec: Precision,
        record: &[u8],
        hn: &[f32],
        gatew: &[f32],
    ) -> Result<Vec<f32>> {
        let [w1, w3, w2] = self.parse_record(prec, record)?;
        let d = self.cfg.d_model;
        let ff = self.cfg.d_ff;
        self.clock(|| {
            let mut out = vec![0.0f32; s * d];
            for r in 0..s {
                if gatew[r] == 0.0 {
                    continue;
                }
                let xr = &hn[r * d..(r + 1) * d];
                let a = matmul(xr, &w1, 1, d, ff);
                let b = matmul(xr, &w3, 1, d, ff);
                let hrow: Vec<f32> =
                    a.iter().zip(&b).map(|(av, bv)| silu(*av) * bv).collect();
                let y = matmul(&hrow, &w2, 1, ff, d);
                for (o, yv) in out[r * d..(r + 1) * d].iter_mut().zip(&y) {
                    *o = yv * gatew[r];
                }
            }
            Ok(out)
        })
    }

    fn expert_grouped(
        &mut self,
        s: usize,
        hn: &[f32],
        groups: &[GroupSpec<'_>],
    ) -> Result<(Vec<Vec<f32>>, GroupedExecStats)> {
        let mut outs = Vec::with_capacity(groups.len());
        let mut st = GroupedExecStats::default();
        for g in groups {
            let routed = g.gatew.iter().filter(|w| **w != 0.0).count() as u64;
            // `expert` parses the record once and computes every routed
            // row from it — the dequant-once invariant; one "launch" per
            // group, identical per-row arithmetic
            let y = self.expert(s, g.prec, g.record, hn, g.gatew)?;
            if routed > 0 {
                st.launches += 1;
                st.rows += routed;
                st.dequant_reuses += routed - 1;
            }
            outs.push(y);
        }
        Ok((outs, st))
    }

    fn head(&mut self, s: usize, x: &[f32], live: Option<&[bool]>) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        self.clock(|| {
            let mut out = vec![0.0f32; s * v];
            for r in 0..s {
                if live.map(|m| !m[r]).unwrap_or(false) {
                    continue;
                }
                let hnr = rmsnorm_row(&x[r * d..(r + 1) * d], &self.final_norm);
                let orow = &mut out[r * v..(r + 1) * v];
                for (t, o) in orow.iter_mut().enumerate() {
                    *o = hnr.iter().zip(&self.emb[t * d..(t + 1) * d]).map(|(a, b)| a * b).sum();
                }
            }
            Ok(out)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        // 2x2 identity leaves rows unchanged
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let x = vec![3.0, -2.0, 0.5, 7.0];
        assert_eq!(matmul(&x, &w, 2, 2, 2), x);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![2.0f32, -2.0];
        let w = vec![1.0f32, 1.0];
        let y = rmsnorm_row(&x, &w);
        // var = 4, rsqrt(4 + eps) ~ 0.5
        assert!((y[0] - 1.0).abs() < 1e-3 && (y[1] + 1.0).abs() < 1e-3);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut q = vec![1.0f32, 0.0, 0.0, 1.0]; // one head, hd=4
        let n0: f32 = q.iter().map(|v| v * v).sum();
        rope_row(&mut q, 1, 4, 3.0);
        let n1: f32 = q.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4, "rotation must preserve norm");
    }

    #[test]
    fn silu_matches_definition() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 1.0 / (1.0 + (-1.0f32).exp())).abs() < 1e-7);
    }
}
