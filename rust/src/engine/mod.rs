//! The inference engine: composes the AOT PJRT artifacts (attention,
//! stacked gating, expert FFNs, LM head) into prefill/decode steps, with
//! the paper's three mechanisms wired in:
//!
//! * on a cache miss the **Expert Scorer** picks the precision to fetch
//!   (token-level dynamic loading, §3.2);
//! * the **Stacking Computer** gate artifact predicts subsequent layers'
//!   experts and the predictor issues mixed-precision prefetches (§3.3);
//! * the **Multidimensional Cache Manager** owns eviction (§3.4).
//!
//! The engine is single-threaded on the compute side; the loader's
//! scheduler thread moves expert bytes concurrently with compute, which is
//! exactly the overlap the paper's prefetching exploits.

mod capture;
mod state;

pub use capture::{Capture, GateObs, HiddenObs, RoutingObs};
pub use state::KvState;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};
use xla::Literal;

use crate::cache::{CacheManager, Policy, Pool};
use crate::config::{HardwareConfig, ModelConfig, PolicyConfig};
use crate::loader::scorer::{self, Class};
use crate::loader::{ExpertLoader, TaskKind};
use crate::memory::{LinkModel, ThrottledCopier};
use crate::model::{expert_literals, ExpertStore, NonExpertWeights};
use crate::predictor::Predictor;
use crate::runtime::{lit_f32, lit_i32, lit_to_f32, Runtime};
use crate::{ExpertKey, Precision};

/// Prefill chunk sizes with compiled artifacts, largest first.
pub const PREFILL_CHUNKS: [usize; 3] = [128, 16, 1];

pub struct EngineOptions {
    pub hardware: HardwareConfig,
    pub policy: PolicyConfig,
    /// cache replacement policy (default: the paper's multidimensional)
    pub cache_policy: Option<Policy>,
    /// capture instrumentation channels
    pub capture: Capture,
    /// serve expert FFNs from the XLA-fused `expert_fast_*` lowerings
    /// instead of the interpret-mode Pallas ones (§Perf: ~11x on the CPU
    /// PJRT client; on a real TPU the Pallas kernels are the fast path)
    pub use_fast_ffn: bool,
}

impl EngineOptions {
    pub fn new(hardware: HardwareConfig, policy: PolicyConfig) -> Self {
        Self {
            hardware,
            policy,
            cache_policy: None,
            capture: Capture::none(),
            use_fast_ffn: true,
        }
    }
}

/// Precomputed per-layer literal sets (built once; the request path never
/// re-creates weight literals — perf-critical).
struct LayerLits {
    attn: [Literal; 5], // norm, wq, wk, wv, wo
    /// decode gate stack for this layer: (p_eff, pn[p,d], wg[p,d,E])
    gate_stack: (usize, Literal, Literal),
    /// prefill gate (p = 1)
    gate_single: (Literal, Literal),
}

pub struct Engine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub policy: PolicyConfig,
    pub hardware: HardwareConfig,
    pub store: Arc<ExpertStore>,
    pub cache: Arc<Mutex<CacheManager>>,
    pub loader: ExpertLoader,
    pub predictor: Predictor,
    pub capture: Capture,
    /// retained for instrumentation (Fig 7 offline prediction accuracy)
    pub nonexpert: NonExpertWeights,
    nonexpert_emb: Vec<f32>,
    layers: Vec<LayerLits>,
    emb_lit: Literal,
    final_norm_lit: Literal,
    /// decode-loop accounting
    pub load_wait: Duration,
    token_counter: u64,
    ffn_prefix: &'static str,
}

impl Engine {
    /// Build an engine from `artifacts/<model>` + `artifacts/weights/<model>`.
    pub fn new(artifacts_root: &Path, model: &str, opts: EngineOptions) -> Result<Self> {
        let art_dir = artifacts_root.join(model);
        let weights_dir = artifacts_root.join("weights").join(model);
        let mut rt = Runtime::open(&art_dir)?;
        let cfg = ModelConfig::from_manifest(&rt.manifest.model_json())
            .map_err(|e| anyhow!("model config: {e}"))?;
        opts.policy.validate().map_err(|e| anyhow!("policy: {e}"))?;
        anyhow::ensure!(
            opts.hardware.hi_cache_experts >= cfg.top_k,
            "hi cache must hold at least top_k experts"
        );

        let nonexpert = NonExpertWeights::load(&weights_dir)?;
        let store = Arc::new(ExpertStore::load(&weights_dir, &cfg)?);

        // ---- compile the artifacts this configuration uses -----------------
        let hi = opts.policy.hi_precision;
        let lo = opts.policy.lo_precision;
        // older artifact sets may not carry the fast lowerings
        let fast = opts.use_fast_ffn
            && rt.manifest.artifacts.contains_key("expert_fast_f32_s1");
        let ffn_prefix = if fast { "expert_fast" } else { "expert" };
        let mut names: Vec<String> = Vec::new();
        for s in [1usize, 16, 128] {
            names.push(format!("attn_s{s}"));
            names.push(format!("head_s{s}"));
            names.push(format!("{ffn_prefix}_{}_s{s}", hi.name()));
            names.push(format!("{ffn_prefix}_{}_s{s}", lo.name()));
        }
        let depth = opts.policy.prefetch_depth;
        for p in 1..=(depth + 1).min(4) {
            names.push(format!("gate_p{p}_s1"));
        }
        for s in [16usize, 128] {
            names.push(format!("gate_p1_s{s}"));
        }
        rt.ensure_all(names.iter().map(|s| s.as_str()))?;

        // ---- per-layer literals --------------------------------------------
        let l = cfg.n_layers as usize;
        let stack_p = (depth + 1).min(4).max(1);
        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let get2 = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
                let (shape, data) = nonexpert.get(name)?;
                Ok((shape.to_vec(), data.to_vec()))
            };
            let mk = |name: &str| -> Result<Literal> {
                let (shape, data) = get2(name)?;
                lit_f32(&shape, &data)
            };
            let attn = [
                mk(&format!("attn_norm.{li}"))?,
                mk(&format!("wq.{li}"))?,
                mk(&format!("wk.{li}"))?,
                mk(&format!("wv.{li}"))?,
                mk(&format!("wo.{li}"))?,
            ];
            // decode gate stack: layers li .. li+p_eff-1
            let p_eff = stack_p.min(l - li);
            let mut pn = Vec::with_capacity(p_eff * cfg.d_model);
            let mut wg = Vec::with_capacity(p_eff * cfg.d_model * cfg.n_experts as usize);
            for j in 0..p_eff {
                let (_, pnj) = nonexpert.get(&format!("post_norm.{}", li + j))?;
                pn.extend_from_slice(pnj);
                let (_, wgj) = nonexpert.get(&format!("wg.{}", li + j))?;
                wg.extend_from_slice(wgj);
            }
            let e = cfg.n_experts as usize;
            let gate_stack = (
                p_eff,
                lit_f32(&[p_eff, cfg.d_model], &pn)?,
                lit_f32(&[p_eff, cfg.d_model, e], &wg)?,
            );
            let (_, pn0) = nonexpert.get(&format!("post_norm.{li}"))?;
            let (_, wg0) = nonexpert.get(&format!("wg.{li}"))?;
            let gate_single = (
                lit_f32(&[1, cfg.d_model], pn0)?,
                lit_f32(&[1, cfg.d_model, e], wg0)?,
            );
            layers.push(LayerLits { attn, gate_stack, gate_single });
        }

        let (emb_shape, emb) = nonexpert.get("emb")?;
        let emb_lit = lit_f32(emb_shape, emb)?;
        let nonexpert_emb = emb.to_vec();
        let (_, fnorm) = nonexpert.get("final_norm")?;
        let final_norm_lit = lit_f32(&[cfg.d_model], fnorm)?;

        // ---- cache + loader -------------------------------------------------
        let penalty_ratio = opts.policy.penalty_ratio(&cfg);
        let cache_policy = opts.cache_policy.clone().unwrap_or(Policy::Multidim {
            w: [opts.policy.w_lru, opts.policy.w_lfu, opts.policy.w_lhu, opts.policy.w_fld],
        });
        let cache = Arc::new(Mutex::new(CacheManager::new(
            cfg.n_layers,
            cfg.n_experts,
            opts.hardware.hi_cache_experts,
            cfg.bytes_for(hi),
            opts.hardware.lo_cache_experts,
            cfg.bytes_for(lo),
            cache_policy,
            penalty_ratio,
        )));
        let copier = Arc::new(ThrottledCopier::new(LinkModel {
            bytes_per_s: opts.hardware.load_bw,
            latency_s: opts.hardware.load_latency,
        }));
        let loader = ExpertLoader::start(store.clone(), cache.clone(), copier);
        let predictor = Predictor::new(
            depth,
            cfg.top_k,
            opts.policy.t1,
            opts.policy.t2,
            opts.policy.dynamic_loading,
            cfg.n_layers,
        );

        Ok(Self {
            rt,
            cfg,
            policy: opts.policy,
            hardware: opts.hardware,
            store,
            cache,
            loader,
            predictor,
            capture: opts.capture,
            nonexpert,
            nonexpert_emb,
            layers,
            emb_lit,
            final_norm_lit,
            load_wait: Duration::ZERO,
            token_counter: 0,
            ffn_prefix: if fast { "expert_fast" } else { "expert" },
        })
    }

    /// Start a new sequence: fresh KV state + per-sequence cache records.
    pub fn new_sequence(&mut self) -> KvState {
        self.cache.lock().unwrap().reset_sequence();
        KvState::new(&self.cfg)
    }

    /// Prefill `tokens`, returning the logits after the last token.
    pub fn prefill(&mut self, kv: &mut KvState, tokens: &[u32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(tokens.len() <= kv.remaining(), "prompt exceeds KV capacity");
        let mut i = 0usize;
        let mut logits = None;
        while i < tokens.len() {
            let remaining = tokens.len() - i;
            let chunk = *PREFILL_CHUNKS
                .iter()
                .find(|&&c| c <= remaining)
                .unwrap_or(&1usize);
            let is_last = i + chunk >= tokens.len();
            let out = self.forward_chunk(kv, &tokens[i..i + chunk], chunk, is_last)?;
            if is_last {
                logits = out;
            }
            i += chunk;
        }
        logits.ok_or_else(|| anyhow!("prefill produced no logits"))
    }

    /// One decode step for `token`; returns next-token logits.
    pub fn decode_step(&mut self, kv: &mut KvState, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(kv.remaining() >= 1, "KV cache full");
        self.forward_chunk(kv, &[token], 1, true)?
            .ok_or_else(|| anyhow!("decode produced no logits"))
    }

    /// Run `tokens` through the model with chunk-size `s` artifacts.
    /// Padded rows (when tokens.len() < s) are masked out of routing.
    fn forward_chunk(
        &mut self,
        kv: &mut KvState,
        tokens: &[u32],
        s: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        let real = tokens.len();
        anyhow::ensure!(real <= s);
        let d = self.cfg.d_model;
        let e = self.cfg.n_experts as usize;
        let decode = s == 1;

        // embed (pad rows use PAD)
        let mut x = vec![0.0f32; s * d];
        for (r, slot) in x.chunks_mut(d).enumerate() {
            let tok = if r < real { tokens[r] } else { crate::tokenizer::PAD } as usize;
            slot.copy_from_slice(&self.nonexpert_emb[tok * d..(tok + 1) * d]);
        }
        let pos = kv.pos as i32;

        for li in 0..self.cfg.n_layers as usize {
            // ---- attention ---------------------------------------------------
            let x_lit = lit_f32(&[s, d], &x)?;
            let kdims = [self.cfg.max_seq, self.cfg.n_kv_heads, self.cfg.head_dim()];
            let k_lit = lit_f32(&kdims, &kv.k[li])?;
            let v_lit = lit_f32(&kdims, &kv.v[li])?;
            let pos_lit = lit_i32(pos);
            let ll = &self.layers[li];
            let args: Vec<&Literal> = vec![
                &x_lit, &ll.attn[0], &ll.attn[1], &ll.attn[2], &ll.attn[3], &ll.attn[4],
                &k_lit, &v_lit, &pos_lit,
            ];
            let outs = self.rt.execute(&format!("attn_s{s}"), &args)?;
            anyhow::ensure!(outs.len() == 3, "attn outputs");
            let y = lit_to_f32(&outs[0])?;
            kv.k[li] = lit_to_f32(&outs[1])?;
            kv.v[li] = lit_to_f32(&outs[2])?;
            x = y;

            // ---- gating (stacked on decode; single on prefill) --------------
            let x_lit = lit_f32(&[s, d], &x)?;
            let (p_eff, probs, hn) = if decode {
                let (p_eff, ref pn, ref wg) = ll.gate_stack;
                let args: Vec<&Literal> = vec![&x_lit, pn, wg];
                let outs = self.rt.execute(&format!("gate_p{p_eff}_s1"), &args)?;
                (p_eff, lit_to_f32(&outs[0])?, lit_to_f32(&outs[1])?)
            } else {
                let (ref pn, ref wg) = ll.gate_single;
                let args: Vec<&Literal> = vec![&x_lit, pn, wg];
                let outs = self.rt.execute(&format!("gate_p1_s{s}"), &args)?;
                (1usize, lit_to_f32(&outs[0])?, lit_to_f32(&outs[1])?)
            };
            // probs layout [p, s, e]; row-major
            let layer_probs = &probs[..s * e];

            // ---- routing + scoring -------------------------------------------
            let li_u32 = li as u32;
            if self.capture.hidden_states {
                // raw gating input (attention output, pre-norm): the
                // quantity whose cross-layer similarity Fig 7 measures
                self.capture.hiddens.push(HiddenObs {
                    token: self.token_counter,
                    layer: li_u32,
                    hidden: x[..d].to_vec(),
                });
            }
            let mut per_expert: HashMap<u32, (Class, Vec<f32>, f64)> = HashMap::new();
            for r in 0..real {
                let row = &layer_probs[r * e..(r + 1) * e];
                let decisions = scorer::decide(
                    row,
                    self.cfg.top_k,
                    self.policy.t1,
                    self.policy.t2,
                    self.policy.dynamic_loading,
                );
                if self.capture.routing {
                    self.capture.routes.push(RoutingObs {
                        token: self.token_counter + r as u64,
                        layer: li_u32,
                        experts: decisions.iter().map(|dd| dd.expert).collect(),
                        probs: row.to_vec(),
                    });
                }
                for dd in decisions {
                    let ent = per_expert
                        .entry(dd.expert)
                        .or_insert((Class::Skip, vec![0.0; s], dd.score));
                    ent.0 = max_class(ent.0, dd.class);
                    ent.1[r] = dd.gate_weight;
                    ent.2 = ent.2.min(dd.score);
                }
            }

            // predictor: plan prefetches for subsequent layers (decode only)
            if decode && p_eff > 1 && self.policy.prefetch_depth > 0 {
                let stacked: Vec<Vec<f32>> =
                    (0..p_eff).map(|j| probs[j * e..(j + 1) * e].to_vec()).collect();
                self.loader.bump_prefetch_generation();
                let mut cache = self.cache.lock().unwrap();
                let plan =
                    self.predictor
                        .plan(&mut cache, li_u32, self.cfg.n_layers, &stacked);
                drop(cache);
                if let Some(plan) = plan {
                    let mut stats = self.loader.stats.lock().unwrap();
                    stats.prefetch_total += plan.experts.len() as u64;
                    drop(stats);
                    for (key, class) in plan.experts {
                        let (prec, pool) = self.class_target(class);
                        if class != Class::Skip {
                            let _ = self.loader.submit(
                                key,
                                prec,
                                pool,
                                TaskKind::Prefetch,
                                li_u32,
                            );
                        }
                    }
                }
            }
            if decode {
                // score the pending prediction of this layer + release pins
                // (unconditional: even layers with p_eff == 1 may have been
                // predicted from an earlier layer)
                let mut cache = self.cache.lock().unwrap();
                self.predictor.observe(&mut cache, li_u32, &layer_probs[..e]);
                let hits = self.predictor.tracker.per_offset[0].0;
                let mut st = self.loader.stats.lock().unwrap();
                st.prefetch_hits = hits;
            }

            // ---- ensure on-demand experts resident ---------------------------
            let mut waits: Vec<u64> = Vec::new();
            let mut uses: Vec<(ExpertKey, Class, Vec<f32>)> = Vec::new();
            {
                let mut cache = self.cache.lock().unwrap();
                cache.records.note_token();
                for (&expert, (class, gatew, _score)) in &per_expert {
                    if *class == Class::Skip {
                        let mut st = self.loader.stats.lock().unwrap();
                        st.skipped += 1;
                        continue;
                    }
                    let key = ExpertKey::new(li_u32, expert);
                    let (_prec, pool) = self.class_target(*class);
                    let mut hit = cache.access(key, pool);
                    // a Lo request served by a resident Hi copy is a free upgrade
                    let mut eff_class = *class;
                    if !hit && pool == Pool::Lo && cache.hi.contains_ready(key) {
                        hit = true;
                        eff_class = Class::Hi;
                        cache.stats.hits_hi += 1;
                        // undo the lo-miss penalty charged by access()
                        cache.stats.misses_lo -= 1;
                        cache.stats.miss_penalty -= cache.penalty_ratio();
                    }
                    match eff_class {
                        Class::Hi => cache.hi.pin(key),
                        _ => cache.lo.pin(key),
                    }
                    uses.push((key, eff_class, gatew.clone()));
                    if !hit {
                        drop(cache);
                        let (prec, pool) = self.class_target(eff_class);
                        if let Some(id) =
                            self.loader.submit(key, prec, pool, TaskKind::OnDemand, li_u32)
                        {
                            waits.push(id);
                        }
                        cache = self.cache.lock().unwrap();
                    }
                }
            }
            if !waits.is_empty() {
                let waited = self.loader.wait(&waits);
                self.load_wait += waited;
                let mut st = self.loader.stats.lock().unwrap();
                st.wait_time += waited;
            }

            // ---- expert FFNs --------------------------------------------------
            let x_norm_lit = lit_f32(&[s, d], &hn)?;
            let mut moe_out = vec![0.0f32; s * d];
            for (key, class, gatew) in uses {
                let (prec, pool) = self.class_target(class);
                let buf = {
                    let cache = self.cache.lock().unwrap();
                    let pool_ref = match pool {
                        Pool::Hi => &cache.hi,
                        Pool::Lo => &cache.lo,
                    };
                    pool_ref.buffer(key)
                };
                let Some(buf) = buf else {
                    // evicted between load and use under extreme pressure:
                    // execute directly from next-level memory (bypass)
                    let record = self.store.record(key, prec).to_vec();
                    self.run_expert(&x_norm_lit, s, prec, &record, &gatew, &mut moe_out, key)?;
                    self.unpin(key, pool);
                    continue;
                };
                let record = buf.lock().unwrap().clone();
                self.run_expert(&x_norm_lit, s, prec, &record, &gatew, &mut moe_out, key)?;
                {
                    let mut cache = self.cache.lock().unwrap();
                    cache.note_use(key, pool);
                }
                self.unpin(key, pool);
            }
            for (xv, mv) in x.iter_mut().zip(&moe_out) {
                *xv += mv;
            }
        }

        kv.pos += real;
        self.token_counter += real as u64;

        if !want_logits {
            return Ok(None);
        }
        let x_lit = lit_f32(&[s, d], &x)?;
        let args: Vec<&Literal> = vec![&x_lit, &self.final_norm_lit, &self.emb_lit];
        let outs = self.rt.execute(&format!("head_s{s}"), &args)?;
        let logits = lit_to_f32(&outs[0])?;
        let v = self.cfg.vocab;
        Ok(Some(logits[(real - 1) * v..real * v].to_vec()))
    }

    fn unpin(&self, key: ExpertKey, pool: Pool) {
        let mut cache = self.cache.lock().unwrap();
        match pool {
            Pool::Hi => cache.hi.unpin(key),
            Pool::Lo => cache.lo.unpin(key),
        }
    }

    fn run_expert(
        &mut self,
        x_norm_lit: &Literal,
        s: usize,
        prec: Precision,
        record: &[u8],
        gatew: &[f32],
        moe_out: &mut [f32],
        key: ExpertKey,
    ) -> Result<()> {
        let mut args: Vec<Literal> = Vec::with_capacity(8);
        args.push(x_norm_lit.clone());
        args.extend(expert_literals(&self.cfg, prec, record)?);
        args.push(lit_f32(&[s], gatew)?);
        let name = format!("{}_{}_s{s}", self.ffn_prefix, prec.name());
        let outs = self
            .rt
            .execute(&name, &args)
            .with_context(|| format!("expert {key:?} via {name}"))?;
        let y = lit_to_f32(&outs[0])?;
        if self.capture.gate_stats {
            let d = self.cfg.d_model;
            for (r, w) in gatew.iter().enumerate() {
                if *w > 0.0 {
                    let row = &y[r * d..(r + 1) * d];
                    let norm =
                        row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
                    self.capture.gates.push(GateObs {
                        key,
                        token: self.token_counter + r as u64,
                        gate: *w,
                        out_norm: norm as f32,
                        score: 0.0,
                    });
                }
            }
        }
        for (o, yv) in moe_out.iter_mut().zip(&y) {
            *o += yv;
        }
        Ok(())
    }

    /// Map a scorer class to (precision, pool) under the active config.
    fn class_target(&self, class: Class) -> (Precision, Pool) {
        match class {
            Class::Hi => (self.policy.hi_precision, Pool::Hi),
            Class::Lo | Class::Skip => (self.policy.lo_precision, Pool::Lo),
        }
    }

    /// Compute-time spent inside PJRT (for Fig 3a-real).
    pub fn compute_time(&self) -> Duration {
        self.rt.compute_time.get()
    }
}

fn max_class(a: Class, b: Class) -> Class {
    use Class::*;
    match (a, b) {
        (Hi, _) | (_, Hi) => Hi,
        (Lo, _) | (_, Lo) => Lo,
        _ => Skip,
    }
}
