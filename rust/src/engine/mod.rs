//! The inference engine: composes the per-layer compute units (attention,
//! stacked gating, expert FFNs, LM head) into prefill/decode steps, with
//! the paper's three mechanisms wired in:
//!
//! * on a cache miss the **Expert Scorer** picks the precision to fetch
//!   (token-level dynamic loading, §3.2);
//! * the **Stacking Computer** gate artifact predicts subsequent layers'
//!   experts and the predictor issues mixed-precision prefetches (§3.3);
//! * the **Multidimensional Cache Manager** owns eviction (§3.4).
//!
//! The compute units run behind the [`exec`] seam: the production path is
//! the AOT PJRT artifacts (`exec::PjrtExec`); the artifact-free reference
//! kernels (`exec::RefExec`, [`Engine::new_reference`]) drive the same
//! engine from a synthesized weight directory for the regression suites.
//! The engine is single-threaded on the compute side; the loader's
//! scheduler thread moves expert bytes concurrently with compute, which is
//! exactly the overlap the paper's prefetching exploits.
//!
//! All three mechanisms reach the expert pools through one API: the
//! [`crate::residency::ExpertResidency`] facade (`Engine::residency`),
//! which owns the loader + cache + predictor interaction, hands out typed
//! [`Ticket`]s for in-flight loads, and scopes per-sequence state in RAII
//! [`SequenceSession`]s. The engine never touches `ExpertLoader::submit`
//! or `CacheManager::reserve` directly.
//!
//! Decode comes in three shapes:
//!
//! * [`Engine::decode_step`] — the blocking batch-1 step the paper
//!   evaluates.
//! * [`Engine::decode_begin`]/[`Engine::decode_poll`] — the suspendable
//!   per-token state machine ([`DecodeCursor`]) the interleaved scheduler
//!   time-multiplexes: it parks at the ensure-resident barrier
//!   (`DecodeProgress::Pending`) instead of blocking.
//! * [`Engine::decode_begin_batch`]/[`Engine::decode_poll_batch`] — *true
//!   batched decode* ([`BatchCursor`]): one token for a whole group of
//!   sequences. In the default **grouped** mode the step runs *ragged* at
//!   its exact row count (no padding, any width up to
//!   `MAX_GROUPED_BATCH`): each layer's routed (token, expert) pairs are
//!   regrouped by expert and the whole FFN executes as one grouped pass —
//!   each unique expert's record is parsed/dequantized ONCE per step and
//!   reused across every row routed to it (`Exec::expert_grouped`). With
//!   grouped mode off the legacy path pads to the nearest compiled launch
//!   width in {2, 4, 8}. Per layer the engine computes the union of routed
//!   experts across the batch and issues a single merged
//!   `ExpertResidency::acquire_merged`, parking the whole group on one
//!   `TicketSet` — cross-sequence load sharing, not just latency hiding.
//!   Attention stays per-row (each sequence owns its KV cache and
//!   position); gate/expert/head launch at batch width when the artifact
//!   set carries the width variants and fall back to bit-identical per-row
//!   s=1 launches when it does not. A row whose loads block while the rest
//!   of the group is runnable is *evicted* into a solo [`DecodeCursor`]
//!   ([`Engine::decode_evict_row`]), taking exactly its own ticket subset
//!   and cache pins with it.

mod capture;
mod exec;
mod state;

pub use capture::{Capture, GateObs, HiddenObs, RoutingObs};
pub use state::KvState;

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cache::{CacheManager, Policy, Pool};
use crate::config::{HardwareConfig, IoConfig, ModelConfig, PolicyConfig, RemoteConfig};
use crate::loader::scorer::{self, Class};
use crate::loader::GLOBAL_SCOPE;
use crate::memory::{LinkModel, ThrottledCopier, ONDEMAND_WEIGHT};
use crate::model::{ExpertStore, NonExpertWeights};
use crate::predictor::Predictor;
use crate::remote::TieredStore;
use crate::residency::{ExpertResidency, MergedUse, SequenceSession, Ticket, TicketSet};
use crate::runtime::{pad_batch_width, Runtime, MAX_DECODE_BATCH, MAX_GROUPED_BATCH};
use crate::{ExpertKey, Precision};

use exec::{Exec, GroupSpec, PjrtExec, RefExec};

/// Prefill chunk sizes with compiled artifacts, largest first.
pub const PREFILL_CHUNKS: [usize; 3] = [128, 16, 1];

/// Largest [`PREFILL_CHUNKS`] width that fits `remaining` prompt tokens —
/// the greedy split step every prefill path (blocking, chunked cursor,
/// and the DES admission model) takes.
pub fn next_prefill_chunk(remaining: usize) -> usize {
    *PREFILL_CHUNKS.iter().find(|&&c| c <= remaining).unwrap_or(&1usize)
}

/// The full greedy 128/16/1 chunk schedule for a prompt.
pub fn prefill_chunk_schedule(mut prompt_len: usize) -> Vec<usize> {
    let mut out = Vec::new();
    while prompt_len > 0 {
        let c = next_prefill_chunk(prompt_len);
        out.push(c);
        prompt_len -= c;
    }
    out
}

pub struct EngineOptions {
    pub hardware: HardwareConfig,
    pub policy: PolicyConfig,
    /// cache replacement policy (default: the paper's multidimensional)
    pub cache_policy: Option<Policy>,
    /// capture instrumentation channels
    pub capture: Capture,
    /// serve expert FFNs from the XLA-fused `expert_fast_*` lowerings
    /// instead of the interpret-mode Pallas ones (§Perf: ~11x on the CPU
    /// PJRT client; on a real TPU the Pallas kernels are the fast path)
    pub use_fast_ffn: bool,
    /// transfer-pipeline knobs: lanes + preemption chunk size
    /// (`--io-lanes` / `--io-chunk-bytes`; default 2 lanes, 256 KiB)
    pub io: IoConfig,
    /// remote expert tier (`--peers`/`--shard`/`--net-gbps`): this node's
    /// local DRAM shard, the peer shard servers, and the network link
    /// budget. None = every expert local (the single-node hierarchy).
    pub remote: Option<RemoteConfig>,
    /// deterministic fault injection (`--fault-plan seed:spec`): seeded
    /// corruption/stall/tear events at the tier boundaries, for exercising
    /// the integrity layer. None in production.
    pub faults: Option<Arc<crate::faults::FaultPlan>>,
    /// ragged grouped expert execution (`--no-grouped` turns it off):
    /// batched decode runs at its exact row count and each layer's FFN
    /// executes as one grouped pass — dequantize each unique expert once
    /// per step, reuse across its rows. Off = the legacy padded-width path.
    pub grouped: bool,
    /// hot-expert read-replica budget per pool (`--max-replicas`; 0 = off):
    /// predictor-hot experts demanded by several rows get DRAM-to-DRAM
    /// replicas that rotate snapshot reads across slots.
    pub max_replicas: usize,
}

impl EngineOptions {
    pub fn new(hardware: HardwareConfig, policy: PolicyConfig) -> Self {
        Self {
            hardware,
            policy,
            cache_policy: None,
            capture: Capture::none(),
            use_fast_ffn: true,
            io: IoConfig::default(),
            remote: None,
            faults: None,
            grouped: true,
            max_replicas: 0,
        }
    }
}

/// Routing outcome of one layer for one chunk: expert -> (precision class,
/// per-row gate weights, min unimportance score). Ordered by expert id so
/// FFN output accumulation — and therefore the float results — are
/// deterministic run to run (a `HashMap` here made logits depend on hash
/// iteration order).
type PerExpert = BTreeMap<u32, (Class, Vec<f32>, f64)>;

/// Progress of a suspended decode token.
pub enum DecodeProgress {
    /// an ensure-resident barrier is waiting on in-flight expert loads
    Pending,
    /// token finished; next-token logits
    Done(Vec<f32>),
}

/// Progress of a suspended chunked prefill ([`PrefillCursor`]).
pub enum PrefillProgress {
    /// the current chunk's ensure-resident barrier is waiting on loads
    Pending,
    /// a chunk boundary was crossed: `done` of `total` prompt tokens are
    /// through every layer, and the next chunk's layer-0 expert loads were
    /// kicked before returning (they stream while the scheduler runs other
    /// sequences' decode). One `Chunk` per poll = one scheduler slice.
    Chunk { done: usize, total: usize },
    /// prefill finished; logits after the last prompt token
    Done(Vec<f32>),
}

/// One layer suspended at the ensure-resident barrier.
struct PendingLayer {
    /// post-gate normed hidden (expert FFN input)
    hn: Vec<f32>,
    /// pinned experts to execute once resident
    uses: Vec<(ExpertKey, Class, Vec<f32>)>,
    /// residency tickets the barrier waits on
    waits: TicketSet,
    /// when the barrier was reached (stall accounting)
    t0: Instant,
    /// waits already resolved (via `decode_block` or a ready poll)
    satisfied: bool,
}

/// Per-token decode state machine: the layer cursor plus activations,
/// suspendable at the ensure-resident barrier and resumable later.
pub struct DecodeCursor {
    /// next layer to execute (or the layer suspended in `pending`)
    layer: usize,
    /// current activations [1, d_model]
    x: Vec<f32>,
    /// KV position of this token (fixed for the whole token)
    pos: i32,
    /// capture token id, reserved at begin so a suspended token's
    /// observations stay under one id however long other sequences (or a
    /// batch eviction) interleave with it
    token_id: u64,
    pending: Option<PendingLayer>,
    /// total stall attributed to this token (barrier-reach → barrier-clear,
    /// whether hidden by other sequences' compute or not)
    pub load_wait: Duration,
    finished: bool,
}

impl DecodeCursor {
    /// Residency tickets the cursor is currently suspended on (empty when
    /// runnable).
    pub fn pending_tickets(&self) -> &[Ticket] {
        match &self.pending {
            Some(p) if !p.satisfied => p.waits.tickets(),
            _ => &[],
        }
    }

    /// True when suspended on unconsumed in-flight loads.
    pub fn is_pending(&self) -> bool {
        self.pending.as_ref().map(|p| !p.satisfied).unwrap_or(false)
    }

    /// True when suspended AND at least one awaited load is still moving:
    /// a cursor whose tickets all completed is runnable (the next poll
    /// clears its barrier without blocking), which `is_pending` cannot
    /// see. Schedulers that *select* rather than sweep (SJF) must use
    /// this, or a ready-to-run sequence parks forever.
    pub fn is_blocked(&self) -> bool {
        self.pending
            .as_ref()
            .map(|p| !p.satisfied && !p.waits.all_ready())
            .unwrap_or(false)
    }
}

// ---------------------------------------------------------------------
// Chunked prefill
// ---------------------------------------------------------------------

/// One prefill chunk mid-flight: the layer cursor plus activations of a
/// `PREFILL_CHUNKS`-wide slice of the prompt.
struct ChunkState {
    /// launch width (128, 16, or 1 — prefill chunks are never padded:
    /// the greedy split always fills the chosen width exactly)
    s: usize,
    /// real tokens in this chunk (== `s`; kept for the head/KV commit)
    real: usize,
    /// next layer to execute (or the layer suspended in `pending`)
    layer: usize,
    /// current activations [s, d]
    x: Vec<f32>,
    /// KV position of the chunk's first token
    pos: i32,
    /// capture token-id base, reserved at chunk start
    token_base: u64,
    pending: Option<PendingLayer>,
}

/// Suspendable chunked prefill: the prompt advances one
/// `PREFILL_CHUNKS`-sized chunk per scheduler slice, parking at each
/// layer's ensure-resident barrier (`PrefillProgress::Pending`) instead of
/// blocking — the scheduler steps live decode sequences while a chunk's
/// experts stream in. Mirrors [`DecodeCursor`]; the blocking
/// [`Engine::prefill`] stays as the FCFS batch-1 path.
pub struct PrefillCursor {
    tokens: Vec<u32>,
    /// prompt tokens already through every layer (committed to KV)
    done: usize,
    /// the chunk mid-flight, if any
    chunk: Option<ChunkState>,
    /// widths of completed chunk launches, in execution order (the
    /// scheduler's chunk histogram reads this at completion)
    chunk_widths: Vec<usize>,
    /// total stall attributed to this prefill (barrier reach → clear,
    /// whether hidden by other sequences' compute or not)
    pub load_wait: Duration,
    finished: bool,
}

impl PrefillCursor {
    /// Residency tickets the cursor is currently suspended on (empty when
    /// runnable).
    pub fn pending_tickets(&self) -> &[Ticket] {
        match self.chunk.as_ref().and_then(|c| c.pending.as_ref()) {
            Some(p) if !p.satisfied => p.waits.tickets(),
            _ => &[],
        }
    }

    /// True when suspended on unconsumed in-flight loads.
    pub fn is_pending(&self) -> bool {
        self.chunk
            .as_ref()
            .and_then(|c| c.pending.as_ref())
            .map(|p| !p.satisfied)
            .unwrap_or(false)
    }

    /// True when suspended AND at least one awaited load is still moving
    /// (see [`DecodeCursor::is_blocked`] for why selecting schedulers need
    /// this rather than `is_pending`).
    pub fn is_blocked(&self) -> bool {
        self.chunk
            .as_ref()
            .and_then(|c| c.pending.as_ref())
            .map(|p| !p.satisfied && !p.waits.all_ready())
            .unwrap_or(false)
    }

    /// Prompt tokens already through every layer.
    pub fn prefilled(&self) -> usize {
        self.done
    }

    /// Total prompt tokens this cursor is prefilling.
    pub fn total(&self) -> usize {
        self.tokens.len()
    }

    /// Prompt tokens not yet through every layer (SJF treats these as the
    /// sequence's extra remaining work).
    pub fn remaining(&self) -> usize {
        self.tokens.len() - self.done
    }

    /// Widths of the chunks completed so far, in execution order.
    pub fn chunk_widths(&self) -> &[usize] {
        &self.chunk_widths
    }
}

// ---------------------------------------------------------------------
// Batched decode
// ---------------------------------------------------------------------

/// One sequence's slot in a batched decode step: the token to decode and
/// ownership of its KV state for the duration of the step.
pub struct BatchItem {
    /// live session id (cache-record attribution; None = unattributed)
    pub seq: Option<u64>,
    pub token: u32,
    pub kv: KvState,
}

/// A finished row of a batched step.
pub struct BatchDone {
    pub seq: Option<u64>,
    pub kv: KvState,
    pub logits: Vec<f32>,
}

/// Progress of a suspended batched decode step.
pub enum BatchProgress {
    /// the merged ensure-resident barrier is waiting on in-flight loads
    Pending,
    /// every remaining row finished; per-row logits + returned KV states
    Done(Vec<BatchDone>),
}

struct BatchRow {
    seq: Option<u64>,
    kv: KvState,
    pos: i32,
    /// false once the row was evicted into a solo cursor
    alive: bool,
}

/// One batched layer suspended at the *merged* ensure-resident barrier.
struct PendingBatch {
    /// post-gate normed hidden [s, d]
    hn: Vec<f32>,
    /// unique (expert, class) execution set with per-row gate weights
    uses: Vec<MergedUse>,
    /// per row: indices into `waits` the row's own demands wait on
    row_tickets: Vec<Vec<usize>>,
    /// per row: (expert, effective class) it demanded — pin bookkeeping
    /// for eviction/abort
    row_demands: Vec<Vec<(ExpertKey, Class)>>,
    waits: TicketSet,
    t0: Instant,
    satisfied: bool,
}

/// The batched decode state machine: one token for a group of sequences,
/// padded to launch width `s`, sharing one merged residency barrier per
/// layer.
pub struct BatchCursor {
    layer: usize,
    /// activations [s, d]; rows >= n (padding) and evicted rows are dead
    x: Vec<f32>,
    /// launch width: the exact row count in grouped mode (ragged), or the
    /// padded width (2, 4, or 8) on the legacy path
    s: usize,
    rows: Vec<BatchRow>,
    /// capture token-id base: ids `token_base..token_base+rows` were
    /// reserved at `decode_begin_batch`, so row r's observations
    /// (hidden/routing/gate) share one stable id across the whole step
    token_base: u64,
    pending: Option<PendingBatch>,
    /// shared stall of the group (barrier reach → clear), accrued once;
    /// every row waited through it
    pub load_wait: Duration,
    finished: bool,
}

impl BatchCursor {
    /// Padded launch width.
    pub fn width(&self) -> usize {
        self.s
    }

    /// Real rows at formation (evicted rows included).
    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn rows_alive(&self) -> usize {
        self.rows.iter().filter(|r| r.alive).count()
    }

    /// Session id of row `r` if it is still in the batch.
    pub fn row_seq_alive(&self, r: usize) -> Option<u64> {
        self.rows.get(r).filter(|row| row.alive).and_then(|row| row.seq)
    }

    /// Tickets the merged barrier is suspended on (empty when runnable).
    pub fn pending_tickets(&self) -> &[Ticket] {
        match &self.pending {
            Some(p) if !p.satisfied => p.waits.tickets(),
            _ => &[],
        }
    }

    /// True when suspended on an unconsumed merged barrier.
    pub fn is_pending(&self) -> bool {
        self.pending.as_ref().map(|p| !p.satisfied).unwrap_or(false)
    }

    /// True when suspended AND at least one awaited load is still moving.
    pub fn is_blocked(&self) -> bool {
        self.pending
            .as_ref()
            .map(|p| !p.satisfied && !p.waits.all_ready())
            .unwrap_or(false)
    }

    /// True when row `r` is alive, the barrier is unresolved, and at least
    /// one of the row's *own* awaited loads is still moving. Such a row is
    /// a candidate for eviction when the rest of the group is runnable.
    pub fn row_blocked(&self, r: usize) -> bool {
        let Some(row) = self.rows.get(r) else { return false };
        if !row.alive {
            return false;
        }
        match &self.pending {
            Some(p) if !p.satisfied => p.row_tickets[r]
                .iter()
                .any(|&ti| !p.waits.tickets()[ti].is_ready()),
            _ => false,
        }
    }

    /// Launch-width mask of the rows actually carrying sequences (padding
    /// and evicted rows are false) — the executor skips the rest in its
    /// per-row fallbacks.
    fn live_mask(&self) -> Vec<bool> {
        (0..self.s)
            .map(|r| self.rows.get(r).map(|row| row.alive).unwrap_or(false))
            .collect()
    }

    /// True when some alive row's own waits have all completed — the group
    /// can make progress (directly, or after evicting the blocked rows).
    pub fn any_row_runnable(&self) -> bool {
        match &self.pending {
            Some(p) if !p.satisfied => (0..self.rows.len())
                .any(|r| self.rows[r].alive && !self.row_blocked(r)),
            // no unresolved barrier: the next poll advances everyone
            _ => true,
        }
    }
}

pub struct Engine {
    exec: Exec,
    pub cfg: ModelConfig,
    pub policy: PolicyConfig,
    pub hardware: HardwareConfig,
    pub store: Arc<ExpertStore>,
    /// the session-scoped residency facade (loader + cache + predictor):
    /// the ONLY path through which experts become resident
    pub residency: ExpertResidency,
    pub capture: Capture,
    /// retained for instrumentation (Fig 7 offline prediction accuracy)
    pub nonexpert: NonExpertWeights,
    nonexpert_emb: Vec<f32>,
    /// decode-loop accounting: wall time spent *blocked* on expert loads
    pub load_wait: Duration,
    token_counter: u64,
    /// sequence whose cache records the current compute is attributed to
    /// (interleaved serving; None on the batch-1 path)
    current_seq: Option<u64>,
    /// ragged grouped expert execution (see [`EngineOptions::grouped`])
    grouped: bool,
}

impl Engine {
    /// Build an engine from `artifacts/<model>` + `artifacts/weights/<model>`.
    pub fn new(artifacts_root: &Path, model: &str, opts: EngineOptions) -> Result<Self> {
        let art_dir = artifacts_root.join(model);
        let weights_dir = artifacts_root.join("weights").join(model);
        let rt = Runtime::open(&art_dir)?;
        let cfg = ModelConfig::from_manifest(&rt.manifest.model_json())
            .map_err(|e| anyhow!("model config: {e}"))?;
        opts.policy.validate().map_err(|e| anyhow!("policy: {e}"))?;
        let nonexpert = NonExpertWeights::load(&weights_dir)?;
        let store = Arc::new(ExpertStore::load(&weights_dir, &cfg)?);
        let exec = Exec::Pjrt(PjrtExec::new(rt, &cfg, &nonexpert, &opts)?);
        Self::assemble(exec, cfg, opts, store, nonexpert, &weights_dir)
    }

    /// Build an engine over the pure-Rust reference kernels from a weight
    /// directory alone — no AOT artifacts, no PJRT. The compute units
    /// mirror `python/compile/model.py` row-for-row, so batched and
    /// sequential decode are bit-identical by construction; the loader,
    /// cache, predictor, and schedulers above them are the *real* ones.
    /// This is what the artifact-free regression suites (and CI) drive.
    pub fn new_reference(
        weights_dir: &Path,
        cfg: ModelConfig,
        opts: EngineOptions,
    ) -> Result<Self> {
        opts.policy.validate().map_err(|e| anyhow!("policy: {e}"))?;
        let nonexpert = NonExpertWeights::load(weights_dir)?;
        let store = Arc::new(ExpertStore::load(weights_dir, &cfg)?);
        let stack_p = (opts.policy.prefetch_depth + 1).min(4);
        let exec = Exec::Reference(RefExec::new(&cfg, &nonexpert, stack_p)?);
        Self::assemble(exec, cfg, opts, store, nonexpert, weights_dir)
    }

    /// Shared tail of the constructors: cache + loader + predictor +
    /// residency facade over an already-built executor. `weights_dir` is
    /// the remote tier's disk fallback (peer-down failover reads expert
    /// records straight from the weight files there).
    fn assemble(
        exec: Exec,
        cfg: ModelConfig,
        opts: EngineOptions,
        store: Arc<ExpertStore>,
        nonexpert: NonExpertWeights,
        weights_dir: &Path,
    ) -> Result<Self> {
        anyhow::ensure!(
            opts.hardware.hi_cache_experts >= cfg.top_k,
            "hi cache must hold at least top_k experts"
        );
        opts.io.validate().map_err(|e| anyhow!("io config: {e}"))?;
        let hi = opts.policy.hi_precision;
        let lo = opts.policy.lo_precision;
        let (_, emb) = nonexpert.get("emb")?;
        let nonexpert_emb = emb.to_vec();

        let penalty_ratio = opts.policy.penalty_ratio(&cfg);
        let cache_policy = opts.cache_policy.clone().unwrap_or(Policy::Multidim {
            w: [opts.policy.w_lru, opts.policy.w_lfu, opts.policy.w_lhu, opts.policy.w_fld],
        });
        let mut manager = CacheManager::new(
            cfg.n_layers,
            cfg.n_experts,
            opts.hardware.hi_cache_experts,
            cfg.bytes_for(hi),
            opts.hardware.lo_cache_experts,
            cfg.bytes_for(lo),
            cache_policy,
            penalty_ratio,
        );
        manager.set_max_replicas(opts.max_replicas);
        let cache = Arc::new(Mutex::new(manager));
        let copier = Arc::new(ThrottledCopier::new(LinkModel {
            bytes_per_s: opts.hardware.load_bw,
            latency_s: opts.hardware.load_latency,
        }));
        let predictor = Predictor::new(
            opts.policy.prefetch_depth,
            cfg.top_k,
            opts.policy.t1,
            opts.policy.t2,
            opts.policy.dynamic_loading,
            cfg.n_layers,
        );
        // The next-level store: local DRAM only, or — with a remote
        // config — the tiered hierarchy whose misses walk staged-cache →
        // peer shard servers → the weight files on disk. A fault plan
        // (engine option, or one already on the remote config) rides into
        // the store before construction: the stager thread holds a core
        // ref from birth, so post-share attachment would be a no-op.
        let plan = opts
            .faults
            .clone()
            .or_else(|| opts.remote.as_ref().and_then(|rc| rc.faults.clone()));
        let tiered = match &opts.remote {
            Some(rc) => {
                let mut rc = rc.clone();
                rc.faults = plan;
                Arc::new(
                    TieredStore::from_config(store.clone(), &rc, weights_dir)
                        .map_err(|e| anyhow!("remote tier: {e}"))?,
                )
            }
            None => Arc::new(TieredStore::local_only(store.clone()).with_faults(plan)),
        };
        let residency = ExpertResidency::with_tiered(
            tiered,
            cache,
            copier,
            predictor,
            hi,
            lo,
            opts.io.clone(),
        )
        .with_precision_mode(
            opts.policy.pin_precision,
            opts.policy.progressive,
            opts.policy.t1,
        );

        Ok(Self {
            exec,
            cfg,
            policy: opts.policy,
            hardware: opts.hardware,
            store,
            residency,
            capture: opts.capture,
            nonexpert,
            nonexpert_emb,
            load_wait: Duration::ZERO,
            token_counter: 0,
            current_seq: None,
            grouped: opts.grouped,
        })
    }

    /// Executor platform name ("cpu"/"cuda" via PJRT, or "reference-cpu").
    pub fn platform(&self) -> String {
        self.exec.platform()
    }

    /// The PJRT runtime, when this engine runs on one (None on the
    /// reference executor). Benches poke raw artifacts through this.
    pub fn runtime(&self) -> Option<&Runtime> {
        self.exec.runtime()
    }

    pub fn runtime_mut(&mut self) -> Option<&mut Runtime> {
        self.exec.runtime_mut()
    }

    /// Decode widths the executor serves as one native launch; other
    /// widths fall back to per-row s=1 launches (same logits, less FLOP
    /// sharing).
    pub fn native_batch_widths(&self) -> &[usize] {
        self.exec.batched_widths()
    }

    /// Largest batched-decode group this engine accepts: grouped execution
    /// has no compiled-width ceiling (bounded only by the bookkeeping cap
    /// `MAX_GROUPED_BATCH`); the legacy padded path tops out at the widest
    /// padded launch width.
    pub fn batch_ceiling(&self) -> usize {
        if self.grouped {
            MAX_GROUPED_BATCH
        } else {
            MAX_DECODE_BATCH
        }
    }

    /// The batched-decode execution mode this engine runs, surfaced in the
    /// `"serving"` report: "grouped" (ragged expert-grouped FFN),
    /// "padded" (legacy width-padded launches), or "per-row" (no batched
    /// artifacts compiled — every launch falls back to s=1).
    pub fn exec_mode(&self) -> &'static str {
        if self.grouped {
            "grouped"
        } else if !self.exec.batched_widths().is_empty() {
            "padded"
        } else {
            "per-row"
        }
    }

    /// Start a new sequence: fresh KV state + per-sequence cache records.
    /// Batch-1 semantics: resets the (global) sequence-level records, so it
    /// must not be used while other sequences are live — interleaved
    /// serving uses [`Self::begin_session`] instead.
    pub fn new_sequence(&mut self) -> KvState {
        self.residency.reset_batch1();
        self.current_seq = None;
        KvState::new(&self.cfg)
    }

    /// Register a live sequence for interleaved serving: an RAII residency
    /// session (per-sequence cache records + private prefetch-generation
    /// scope, both retired when the session drops) and fresh KV state.
    pub fn begin_session(&self) -> (SequenceSession, KvState) {
        (self.residency.begin_session(), KvState::new(&self.cfg))
    }

    /// Attribute subsequent compute to `seq`'s cache records (the
    /// scheduler's context switch; None = batch-1 global records).
    pub fn set_active_sequence(&mut self, seq: Option<u64>) {
        self.current_seq = seq;
    }

    /// Prefill `tokens`, returning the logits after the last token.
    pub fn prefill(&mut self, kv: &mut KvState, tokens: &[u32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(tokens.len() <= kv.remaining(), "prompt exceeds KV capacity");
        let mut i = 0usize;
        let mut logits = None;
        while i < tokens.len() {
            let chunk = next_prefill_chunk(tokens.len() - i);
            let is_last = i + chunk >= tokens.len();
            let out = self.forward_chunk(kv, &tokens[i..i + chunk], chunk, is_last)?;
            if is_last {
                logits = out;
            }
            i += chunk;
        }
        logits.ok_or_else(|| anyhow!("prefill produced no logits"))
    }

    /// One blocking decode step for `token`; returns next-token logits.
    /// (The paper's batch-1 path: blocks on the residency tickets at every
    /// ensure-resident barrier.)
    pub fn decode_step(&mut self, kv: &mut KvState, token: u32) -> Result<Vec<f32>> {
        let mut cur = self.decode_begin(kv, token)?;
        loop {
            match self.decode_poll(kv, &mut cur)? {
                DecodeProgress::Done(logits) => return Ok(logits),
                DecodeProgress::Pending => self.decode_block(&mut cur),
            }
        }
    }

    // ------------------------------------------------------------------
    // Suspendable decode (the interleaved scheduler's unit of work)
    // ------------------------------------------------------------------

    /// Begin one decode token: embed it and position the layer cursor.
    pub fn decode_begin(&mut self, kv: &KvState, token: u32) -> Result<DecodeCursor> {
        anyhow::ensure!(kv.remaining() >= 1, "KV cache full");
        // reserve the capture token id now: on the blocking batch-1 path
        // this matches the old increment-at-completion numbering exactly,
        // and on the interleaved path it keeps a suspended token's
        // observations under one id
        let token_id = self.token_counter;
        self.token_counter += 1;
        Ok(DecodeCursor {
            layer: 0,
            x: self.embed(&[token], 1),
            pos: kv.pos as i32,
            token_id,
            pending: None,
            load_wait: Duration::ZERO,
            finished: false,
        })
    }

    /// Advance the cursor as far as possible without blocking: runs layers
    /// until either the token completes (`Done`) or an ensure-resident
    /// barrier's loads are still in flight (`Pending`). Never sleeps — a
    /// `Pending` cursor costs the caller nothing but this poll.
    pub fn decode_poll(
        &mut self,
        kv: &mut KvState,
        cur: &mut DecodeCursor,
    ) -> Result<DecodeProgress> {
        anyhow::ensure!(!cur.finished, "decode cursor already finished");
        loop {
            // resolve the outstanding barrier first
            let still_loading = match &cur.pending {
                Some(p) => !p.satisfied && !p.waits.all_ready(),
                None => false,
            };
            if still_loading {
                return Ok(DecodeProgress::Pending);
            }
            if let Some(p) = cur.pending.take() {
                cur.load_wait += p.t0.elapsed();
                let moe_out = self.layer_ffn(1, &p.hn, p.uses, cur.token_id)?;
                for (xv, mv) in cur.x.iter_mut().zip(&moe_out) {
                    *xv += mv;
                }
                cur.layer += 1;
            }
            if cur.layer == self.cfg.n_layers as usize {
                cur.finished = true;
                kv.pos += 1;
                // the capture token id was reserved at decode_begin
                let logits = self.head(1, 1, &cur.x)?;
                return Ok(DecodeProgress::Done(logits));
            }

            let li = cur.layer;
            let li_u32 = li as u32;
            let e = self.cfg.n_experts as usize;
            cur.x = self.layer_attention(kv, li, 1, &cur.x, cur.pos)?;
            let (p_eff, probs, hn) = self.layer_gate(li, 1, true, &cur.x, None)?;
            let per_expert = self.layer_route(li_u32, 1, 1, &probs[..e], &cur.x, cur.token_id);
            self.layer_plan_prefetch(li_u32, p_eff, &probs);
            self.layer_observe(li_u32, &probs[..e]);
            let (uses, waits) = self.layer_ensure_resident(li_u32, &per_expert);
            cur.pending = Some(PendingLayer {
                hn,
                uses,
                waits,
                t0: Instant::now(),
                satisfied: false,
            });
            // loop: an empty/already-complete wait set clears immediately
        }
    }

    /// Block until the cursor's outstanding loads complete (the batch-1
    /// path, and the scheduler's nothing-else-runnable fallback). The
    /// blocked time is *unhidden* load wait: it lands in
    /// [`Engine::load_wait`] and the loader's `wait_time`, exactly like the
    /// pre-scheduler blocking decode.
    pub fn decode_block(&mut self, cur: &mut DecodeCursor) {
        if let Some(p) = &mut cur.pending {
            if !p.satisfied {
                let waited = self.residency.wait(&p.waits);
                p.satisfied = true;
                self.load_wait += waited;
            }
        }
    }

    /// Abandon a suspended cursor (scheduler abort path): release the
    /// cache pins its barrier holds so the slots stay evictable. The
    /// in-flight loads themselves are left to complete harmlessly.
    pub fn decode_abort(&self, cur: DecodeCursor) {
        if let Some(p) = cur.pending {
            for (key, class, _gatew) in p.uses {
                let (_prec, pool) = self.class_target(class);
                self.residency.release(key, pool);
            }
        }
    }

    // ------------------------------------------------------------------
    // Suspendable chunked prefill (the scheduler's admission unit of work)
    // ------------------------------------------------------------------

    /// Begin a chunked prefill of `tokens`: validation only — the first
    /// chunk embeds lazily at the first poll, so admission itself costs
    /// nothing (non-blocking admission in the interleaved scheduler).
    pub fn prefill_begin(&mut self, kv: &KvState, tokens: &[u32]) -> Result<PrefillCursor> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(tokens.len() <= kv.remaining(), "prompt exceeds KV capacity");
        Ok(PrefillCursor {
            tokens: tokens.to_vec(),
            done: 0,
            chunk: None,
            chunk_widths: Vec::new(),
            load_wait: Duration::ZERO,
            finished: false,
        })
    }

    /// Start the cursor's next chunk: greedy `PREFILL_CHUNKS` split (the
    /// same split the blocking [`Engine::prefill`] takes, so the two paths
    /// run identical launches), capture ids reserved up front.
    fn prefill_chunk_begin(&mut self, kv: &KvState, cur: &PrefillCursor) -> ChunkState {
        let s = next_prefill_chunk(cur.tokens.len() - cur.done);
        let toks = &cur.tokens[cur.done..cur.done + s];
        let token_base = self.token_counter;
        self.token_counter += s as u64;
        ChunkState {
            s,
            real: s,
            layer: 0,
            x: self.embed(toks, s),
            pos: kv.pos as i32,
            token_base,
            pending: None,
        }
    }

    /// Advance the prefill as far as one chunk boundary without blocking:
    /// runs layers until the current chunk's barrier has loads in flight
    /// (`Pending`), the chunk completes (`Chunk` — after kicking the next
    /// chunk's layer-0 loads across the boundary so they stream during
    /// other sequences' decode), or the whole prompt is through (`Done`).
    /// One chunk per poll keeps live decode's inter-token latency bounded
    /// by one chunk's work, not the whole admission.
    pub fn prefill_poll(
        &mut self,
        kv: &mut KvState,
        cur: &mut PrefillCursor,
    ) -> Result<PrefillProgress> {
        anyhow::ensure!(!cur.finished, "prefill cursor already finished");
        let mut crossed = false;
        loop {
            if cur.chunk.is_none() {
                let ch = self.prefill_chunk_begin(kv, cur);
                cur.chunk = Some(ch);
            }
            let still_loading = {
                let ch = cur.chunk.as_ref().unwrap();
                match &ch.pending {
                    Some(p) => !p.satisfied && !p.waits.all_ready(),
                    None => false,
                }
            };
            if still_loading {
                return Ok(if crossed {
                    PrefillProgress::Chunk { done: cur.done, total: cur.tokens.len() }
                } else {
                    PrefillProgress::Pending
                });
            }
            if crossed && cur.chunk.as_ref().unwrap().pending.is_some() {
                // the next chunk's layer-0 loads are issued (and may even
                // be resident already): the slice ends at the boundary
                // regardless, so decode gets the engine back. This branch
                // is only reachable with an all-ready barrier (in-flight
                // loads returned above), so resolve its stall clock NOW —
                // the inter-slice scheduling gap is not load stall
                let ch = cur.chunk.as_mut().unwrap();
                if let Some(p) = ch.pending.as_mut() {
                    if !p.satisfied {
                        cur.load_wait += p.t0.elapsed();
                        p.satisfied = true;
                    }
                }
                return Ok(PrefillProgress::Chunk {
                    done: cur.done,
                    total: cur.tokens.len(),
                });
            }
            // resolve the cleared barrier: execute the layer's experts
            {
                let ch = cur.chunk.as_mut().unwrap();
                if let Some(p) = ch.pending.take() {
                    // stall (reach → clear) was already accrued if the
                    // barrier resolved earlier (boundary kick / block)
                    if !p.satisfied {
                        cur.load_wait += p.t0.elapsed();
                    }
                    let moe_out = self.layer_ffn(ch.s, &p.hn, p.uses, ch.token_base)?;
                    for (xv, mv) in ch.x.iter_mut().zip(&moe_out) {
                        *xv += mv;
                    }
                    ch.layer += 1;
                }
            }
            if cur.chunk.as_ref().unwrap().layer == self.cfg.n_layers as usize {
                // chunk complete: commit its tokens to the sequence
                let ch = cur.chunk.take().unwrap();
                kv.pos += ch.real;
                cur.done += ch.real;
                cur.chunk_widths.push(ch.s);
                if cur.done == cur.tokens.len() {
                    cur.finished = true;
                    let logits = self.head(ch.s, ch.real, &ch.x)?;
                    return Ok(PrefillProgress::Done(logits));
                }
                // loop once more: beginning the next chunk and running its
                // layer 0 to the barrier is the cross-boundary prefetch kick
                crossed = true;
                continue;
            }
            // run the next layer of the current chunk up to its barrier
            let ch = cur.chunk.as_mut().unwrap();
            let li = ch.layer;
            let li_u32 = li as u32;
            let e = self.cfg.n_experts as usize;
            let s = ch.s;
            // width-1 remainder chunks take the decode path end to end
            // (stacked gate + prefetch + observe), exactly like the
            // blocking prefill's 1-wide chunks
            let decode = s == 1;
            ch.x = self.layer_attention(kv, li, s, &ch.x, ch.pos)?;
            let (p_eff, probs, hn) = self.layer_gate(li, s, decode, &ch.x, None)?;
            let per_expert =
                self.layer_route(li_u32, s, ch.real, &probs[..s * e], &ch.x, ch.token_base);
            if decode {
                self.layer_plan_prefetch(li_u32, p_eff, &probs);
                self.layer_observe(li_u32, &probs[..e]);
            }
            let (uses, waits) = self.layer_ensure_resident_chunk(li_u32, &per_expert);
            ch.pending = Some(PendingLayer {
                hn,
                uses,
                waits,
                t0: Instant::now(),
                satisfied: false,
            });
            // loop: an empty/already-complete wait set clears immediately
        }
    }

    /// Block until the prefill cursor's outstanding loads complete (the
    /// scheduler's nothing-else-runnable fallback). Blocked time is
    /// unhidden stall, same contract as [`Engine::decode_block`].
    pub fn prefill_block(&mut self, cur: &mut PrefillCursor) {
        if let Some(ch) = &mut cur.chunk {
            if let Some(p) = &mut ch.pending {
                if !p.satisfied {
                    let waited = self.residency.wait(&p.waits);
                    // the cursor's stall clock stops when the barrier
                    // clears (the next poll must not re-charge it)
                    cur.load_wait += p.t0.elapsed();
                    p.satisfied = true;
                    self.load_wait += waited;
                }
            }
        }
    }

    /// Abandon a suspended prefill (abort/error paths): release the cache
    /// pins its chunk barrier holds, exactly like batch eviction drains a
    /// row's pins. In-flight loads complete harmlessly.
    pub fn prefill_abort(&self, cur: PrefillCursor) {
        if let Some(ch) = cur.chunk {
            if let Some(p) = ch.pending {
                for (key, class, _gatew) in p.uses {
                    let (_prec, pool) = self.class_target(class);
                    self.residency.release(key, pool);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Batched decode (the coordinator's group unit of work)
    // ------------------------------------------------------------------

    /// Begin one batched decode step for a group of runnable sequences
    /// (one token each). Takes ownership of each row's KV state for the
    /// duration; `BatchProgress::Done` (or eviction/abort) hands it back.
    /// Grouped mode runs the step *ragged* at its exact row count (up to
    /// `MAX_GROUPED_BATCH`); the legacy path pads to the nearest compiled
    /// launch width in {2, 4, 8}.
    pub fn decode_begin_batch(&mut self, items: Vec<BatchItem>) -> Result<BatchCursor> {
        let ceiling = self.batch_ceiling();
        anyhow::ensure!(
            (2..=ceiling).contains(&items.len()),
            "batch of {} (want 2..={ceiling})",
            items.len()
        );
        for it in &items {
            anyhow::ensure!(it.kv.remaining() >= 1, "KV cache full in batch");
        }
        let s = if self.grouped {
            // ragged: grouped execution serves any width, so padded rows
            // (and their wasted FLOPs) are simply never created
            items.len()
        } else {
            pad_batch_width(items.len()).expect("len checked above")
        };
        let tokens: Vec<u32> = items.iter().map(|it| it.token).collect();
        let x = self.embed(&tokens, s);
        let rows: Vec<BatchRow> = items
            .into_iter()
            .map(|it| BatchRow { pos: it.kv.pos as i32, seq: it.seq, kv: it.kv, alive: true })
            .collect();
        // reserve one capture token id per row up front: a later step's
        // base can then never collide with this step's per-row ids, even
        // when rows are evicted mid-step
        let token_base = self.token_counter;
        self.token_counter += rows.len() as u64;
        Ok(BatchCursor {
            layer: 0,
            x,
            s,
            rows,
            token_base,
            pending: None,
            load_wait: Duration::ZERO,
            finished: false,
        })
    }

    /// Advance the batched cursor as far as possible without blocking.
    /// Per layer: per-row attention (each sequence's own KV), one gate
    /// launch over the padded width, per-row routing/prefetch, then ONE
    /// merged residency acquire for the union of routed experts and one
    /// FFN launch per unique (expert, class). `Pending` means the merged
    /// barrier still has bytes on the link.
    pub fn decode_poll_batch(&mut self, cur: &mut BatchCursor) -> Result<BatchProgress> {
        anyhow::ensure!(!cur.finished, "batch cursor already finished");
        let d = self.cfg.d_model;
        loop {
            let still_loading = match &cur.pending {
                Some(p) => !p.satisfied && !p.waits.all_ready(),
                None => false,
            };
            if still_loading {
                return Ok(BatchProgress::Pending);
            }
            if let Some(p) = cur.pending.take() {
                cur.load_wait += p.t0.elapsed();
                let moe_out = self.layer_ffn_batch(cur.s, &p.hn, p.uses, cur.token_base)?;
                for (xv, mv) in cur.x.iter_mut().zip(&moe_out) {
                    *xv += mv;
                }
                cur.layer += 1;
            }
            if cur.layer == self.cfg.n_layers as usize {
                cur.finished = true;
                let live = cur.live_mask();
                let logits_all = self.exec.head(cur.s, &cur.x, Some(&live))?;
                let v = self.cfg.vocab;
                let mut done = Vec::new();
                for (r, row) in cur.rows.iter_mut().enumerate() {
                    if !row.alive {
                        continue;
                    }
                    row.kv.pos += 1;
                    // token ids were reserved at decode_begin_batch
                    done.push(BatchDone {
                        seq: row.seq,
                        kv: std::mem::replace(&mut row.kv, KvState::empty()),
                        logits: logits_all[r * v..(r + 1) * v].to_vec(),
                    });
                }
                return Ok(BatchProgress::Done(done));
            }

            let li = cur.layer;
            let li_u32 = li as u32;
            let e = self.cfg.n_experts as usize;
            let s = cur.s;

            // per-row attention: each sequence owns its KV cache/position
            for r in 0..cur.rows.len() {
                if !cur.rows[r].alive {
                    continue;
                }
                let x_row: Vec<f32> = cur.x[r * d..(r + 1) * d].to_vec();
                let pos = cur.rows[r].pos;
                let y = {
                    let row = &mut cur.rows[r];
                    self.layer_attention(&mut row.kv, li, 1, &x_row, pos)?
                };
                cur.x[r * d..(r + 1) * d].copy_from_slice(&y);
            }

            // one gate launch over the padded width (pad/dead rows are
            // masked out of the per-row fallbacks)
            let live = cur.live_mask();
            let (p_eff, probs, hn) = self.layer_gate(li, s, true, &cur.x, Some(&live))?;

            // per-row routing into the merged (expert, class) union
            let mut merged: BTreeMap<(u32, u8), MergedUse> = BTreeMap::new();
            let mut batch_seqs: Vec<Option<u64>> = Vec::with_capacity(cur.rows.len());
            for (r, row) in cur.rows.iter().enumerate() {
                if !row.alive {
                    continue;
                }
                batch_seqs.push(row.seq);
                let row_probs = &probs[r * e..(r + 1) * e];
                // reserved per-row token ids, consistent with the GateObs
                // stream layer_ffn_batch emits for the same step
                if self.capture.hidden_states {
                    self.capture.hiddens.push(HiddenObs {
                        token: cur.token_base + r as u64,
                        layer: li_u32,
                        hidden: cur.x[r * d..(r + 1) * d].to_vec(),
                    });
                }
                let decisions = scorer::decide(
                    row_probs,
                    self.cfg.top_k,
                    self.policy.t1,
                    self.policy.t2,
                    self.policy.dynamic_loading,
                );
                if self.capture.routing {
                    self.capture.routes.push(RoutingObs {
                        token: cur.token_base + r as u64,
                        layer: li_u32,
                        experts: decisions.iter().map(|dd| dd.expert).collect(),
                        probs: row_probs.to_vec(),
                    });
                }
                for dd in decisions {
                    let ent =
                        merged.entry((dd.expert, class_rank(dd.class))).or_insert_with(|| {
                            MergedUse {
                                key: ExpertKey::new(li_u32, dd.expert),
                                class: dd.class,
                                gatew: vec![0.0; s],
                                rows: Vec::new(),
                                seqs: Vec::new(),
                                score: dd.score,
                            }
                        });
                    ent.gatew[r] = dd.gate_weight;
                    ent.rows.push(r);
                    ent.seqs.push(row.seq);
                    // the group's most critical row decides the floor
                    ent.score = ent.score.min(dd.score);
                }
            }

            // per-row predictor step under each row's own generation scope
            if p_eff > 1 && self.policy.prefetch_depth > 0 {
                for (r, row) in cur.rows.iter().enumerate() {
                    if !row.alive {
                        continue;
                    }
                    let stacked: Vec<Vec<f32>> = (0..p_eff)
                        .map(|j| probs[j * s * e + r * e..j * s * e + (r + 1) * e].to_vec())
                        .collect();
                    let scope = row.seq.unwrap_or(GLOBAL_SCOPE);
                    self.residency.plan_prefetch(scope, li_u32, self.cfg.n_layers, &stacked);
                }
            }
            for (r, row) in cur.rows.iter().enumerate() {
                if !row.alive {
                    continue;
                }
                self.residency.observe(li_u32, &probs[r * e..(r + 1) * e]);
            }

            // ONE merged acquire for the whole group
            let demands: Vec<MergedUse> = merged.into_values().collect();
            let (uses, waits) = self.residency.acquire_merged(li_u32, demands, &batch_seqs);

            // hot-expert replication: an expert demanded by several rows
            // whose gate-score EMA marks it hot earns a DRAM read-replica
            // (no-op when the budget is 0, no Free slot exists, or the
            // primary is not Ready yet — replicas never fetch via the link)
            for u in &uses {
                if u.rows.len() >= 2 && self.residency.is_hot(u.key) {
                    let (_prec, pool) = self.class_target(u.class);
                    self.residency.add_replica(u.key, pool);
                }
            }

            // map each row to its subset of the shared ticket set
            let mut ticket_idx: HashMap<(ExpertKey, Pool), usize> = HashMap::new();
            for (i, t) in waits.tickets().iter().enumerate() {
                ticket_idx.insert((t.key(), t.pool()), i);
            }
            let mut row_tickets: Vec<Vec<usize>> = vec![Vec::new(); cur.rows.len()];
            let mut row_demands: Vec<Vec<(ExpertKey, Class)>> =
                vec![Vec::new(); cur.rows.len()];
            for u in &uses {
                let (_prec, pool) = self.class_target(u.class);
                let ti = ticket_idx.get(&(u.key, pool)).copied();
                for &r in &u.rows {
                    if let Some(i) = ti {
                        row_tickets[r].push(i);
                    }
                    row_demands[r].push((u.key, u.class));
                }
            }
            cur.pending = Some(PendingBatch {
                hn,
                uses,
                row_tickets,
                row_demands,
                waits,
                t0: Instant::now(),
                satisfied: false,
            });
            // loop: an empty/already-complete wait set clears immediately
        }
    }

    /// Block until the batch's merged barrier resolves (the scheduler's
    /// nothing-else-runnable fallback). Blocked time is unhidden stall.
    pub fn decode_block_batch(&mut self, cur: &mut BatchCursor) {
        if let Some(p) = &mut cur.pending {
            if !p.satisfied {
                let waited = self.residency.wait(&p.waits);
                p.satisfied = true;
                self.load_wait += waited;
            }
        }
    }

    /// Evict a blocked row from a suspended batch so the rest of the group
    /// does not stall on its loads. The row leaves with exactly its own
    /// share of the shared barrier — a solo [`DecodeCursor`] parked on its
    /// ticket subset, its gate weights and cache pins carved out of the
    /// merged execution set — and the batch's barrier drops every ticket
    /// no remaining row demands (without this, one cold expert would stall
    /// the whole group anyway). Returns the row's session id, its KV state
    /// (hand it back to the sequence), and the solo continuation. None if
    /// the row is not evictable (already finished, dead, or no barrier).
    pub fn decode_evict_row(
        &self,
        cur: &mut BatchCursor,
        row: usize,
    ) -> Option<(Option<u64>, KvState, DecodeCursor)> {
        if cur.finished || row >= cur.rows.len() || !cur.rows[row].alive {
            return None;
        }
        let d = self.cfg.d_model;
        let layer = cur.layer;
        let shared_wait = cur.load_wait;
        let p = cur.pending.as_mut()?;
        if p.satisfied {
            return None;
        }
        // carve the row's demands out of the merged execution set
        let mut solo_uses: Vec<(ExpertKey, Class, Vec<f32>)> = Vec::new();
        for u in p.uses.iter_mut() {
            if let Some(i) = u.rows.iter().position(|&r| r == row) {
                solo_uses.push((u.key, u.class, vec![u.gatew[row]]));
                u.rows.remove(i);
                u.seqs.remove(i);
                u.gatew[row] = 0.0;
            }
        }
        p.uses.retain(|u| !u.rows.is_empty());
        // the solo continuation waits on exactly the row's ticket subset
        let mut solo_waits = TicketSet::new();
        for &ti in &p.row_tickets[row] {
            solo_waits.push(p.waits.tickets()[ti].clone());
        }
        p.row_tickets[row].clear();
        p.row_demands[row].clear();
        // drop shared-barrier tickets no remaining row demands, remapping
        // the surviving rows' indices
        let needed: std::collections::BTreeSet<usize> =
            p.row_tickets.iter().flatten().copied().collect();
        if needed.len() != p.waits.len() {
            let old = p.waits.tickets().to_vec();
            let mut remap: HashMap<usize, usize> = HashMap::new();
            let mut kept = TicketSet::new();
            for (ni, &oi) in needed.iter().enumerate() {
                remap.insert(oi, ni);
                kept.push(old[oi].clone());
            }
            for rt in p.row_tickets.iter_mut() {
                for idx in rt.iter_mut() {
                    *idx = remap[idx];
                }
            }
            p.waits = kept;
        }
        let pending = PendingLayer {
            hn: p.hn[row * d..(row + 1) * d].to_vec(),
            uses: solo_uses,
            waits: solo_waits,
            t0: p.t0,
            satisfied: false,
        };
        let row_state = &mut cur.rows[row];
        row_state.alive = false;
        let kv = std::mem::replace(&mut row_state.kv, KvState::empty());
        let cursor = DecodeCursor {
            layer,
            x: cur.x[row * d..(row + 1) * d].to_vec(),
            pos: row_state.pos,
            // the row keeps the token id reserved for it at batch begin,
            // so its capture stream stays whole across the eviction
            token_id: cur.token_base + row as u64,
            pending: Some(pending),
            // earlier layers' shared stall: the row waited through it too
            load_wait: shared_wait,
            finished: false,
        };
        Some((row_state.seq, kv, cursor))
    }

    /// Abandon a suspended batch cursor (scheduler abort path): release
    /// every remaining row's cache pins. In-flight loads complete
    /// harmlessly; the rows' KV states are dropped with the cursor.
    pub fn decode_abort_batch(&self, cur: BatchCursor) {
        if let Some(p) = cur.pending {
            for (r, demands) in p.row_demands.iter().enumerate() {
                if !cur.rows[r].alive {
                    continue;
                }
                for (key, class) in demands {
                    let (_prec, pool) = self.class_target(*class);
                    self.residency.release(*key, pool);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Per-layer building blocks (shared by prefill chunks and the cursors)
    // ------------------------------------------------------------------

    /// Embed `tokens` into an [s, d] activation buffer (pad rows use PAD).
    fn embed(&self, tokens: &[u32], s: usize) -> Vec<f32> {
        let d = self.cfg.d_model;
        let real = tokens.len();
        let mut x = vec![0.0f32; s * d];
        for (r, slot) in x.chunks_mut(d).enumerate() {
            let tok = if r < real { tokens[r] } else { crate::tokenizer::PAD } as usize;
            slot.copy_from_slice(&self.nonexpert_emb[tok * d..(tok + 1) * d]);
        }
        x
    }

    /// Attention for layer `li`; returns the new activations and writes the
    /// updated KV back into `kv`.
    fn layer_attention(
        &mut self,
        kv: &mut KvState,
        li: usize,
        s: usize,
        x: &[f32],
        pos: i32,
    ) -> Result<Vec<f32>> {
        self.exec.attn(li, s, x, kv, pos)
    }

    /// Gating for layer `li`: stacked on decode, single on prefill.
    /// Returns (p_eff, probs [p_eff, s, e], normed hidden [s, d]).
    /// `live` marks the launch rows actually carrying sequences (None =
    /// all; the batched step excludes padding and evicted rows).
    fn layer_gate(
        &mut self,
        li: usize,
        s: usize,
        decode: bool,
        x: &[f32],
        live: Option<&[bool]>,
    ) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        self.exec.gate(li, s, decode, x, live)
    }

    /// Route the chunk's tokens through the Expert Scorer, merging per-row
    /// decisions into the layer's per-expert execution set.
    fn layer_route(
        &mut self,
        li_u32: u32,
        s: usize,
        real: usize,
        layer_probs: &[f32],
        x: &[f32],
        token_base: u64,
    ) -> PerExpert {
        let d = self.cfg.d_model;
        let e = self.cfg.n_experts as usize;
        if self.capture.hidden_states {
            // raw gating input (attention output, pre-norm): the
            // quantity whose cross-layer similarity Fig 7 measures
            self.capture.hiddens.push(HiddenObs {
                token: token_base,
                layer: li_u32,
                hidden: x[..d].to_vec(),
            });
        }
        let mut per_expert: PerExpert = BTreeMap::new();
        for r in 0..real {
            let row = &layer_probs[r * e..(r + 1) * e];
            let decisions = scorer::decide(
                row,
                self.cfg.top_k,
                self.policy.t1,
                self.policy.t2,
                self.policy.dynamic_loading,
            );
            if self.capture.routing {
                self.capture.routes.push(RoutingObs {
                    token: token_base + r as u64,
                    layer: li_u32,
                    experts: decisions.iter().map(|dd| dd.expert).collect(),
                    probs: row.to_vec(),
                });
            }
            for dd in decisions {
                let ent = per_expert
                    .entry(dd.expert)
                    .or_insert((Class::Skip, vec![0.0; s], dd.score));
                ent.0 = max_class(ent.0, dd.class);
                ent.1[r] = dd.gate_weight;
                ent.2 = ent.2.min(dd.score);
            }
        }
        per_expert
    }

    /// Predictor step (decode only): plan mixed-precision prefetches for
    /// subsequent layers from the stacked gate output, under the active
    /// sequence's generation scope so other sequences' queued prefetches
    /// survive this token.
    fn layer_plan_prefetch(&mut self, li_u32: u32, p_eff: usize, probs: &[f32]) {
        if p_eff <= 1 || self.policy.prefetch_depth == 0 {
            return;
        }
        let e = self.cfg.n_experts as usize;
        let stacked: Vec<Vec<f32>> =
            (0..p_eff).map(|j| probs[j * e..(j + 1) * e].to_vec()).collect();
        let scope = self.current_seq.unwrap_or(GLOBAL_SCOPE);
        self.residency.plan_prefetch(scope, li_u32, self.cfg.n_layers, &stacked);
    }

    /// Score the pending prediction of this layer + release pins
    /// (unconditional on decode: even layers with p_eff == 1 may have been
    /// predicted from an earlier layer).
    fn layer_observe(&mut self, li_u32: u32, layer_probs_first: &[f32]) {
        self.residency.observe(li_u32, layer_probs_first);
    }

    /// Ensure-resident barrier: hand the layer's routed experts to the
    /// residency facade, which probes/pins, submits (or joins) on-demand
    /// loads for misses, and returns the execution set plus the tickets to
    /// wait on. Does NOT wait — blocking vs suspension is the caller's
    /// policy.
    fn layer_ensure_resident(
        &self,
        li_u32: u32,
        per_expert: &PerExpert,
    ) -> (Vec<(ExpertKey, Class, Vec<f32>)>, TicketSet) {
        // the scorer's unimportance score rides along: residency's
        // progressive plan reads it as the criticality input
        let demands: Vec<crate::residency::Demand> = per_expert
            .iter()
            .map(|(&expert, (class, gatew, score))| {
                (ExpertKey::new(li_u32, expert), *class, gatew.clone(), *score)
            })
            .collect();
        self.residency.acquire(li_u32, demands, self.current_seq)
    }

    /// The chunked-prefill ensure-resident barrier: like
    /// [`Self::layer_ensure_resident`], but hands the residency facade the
    /// per-expert row multiplicity (how many chunk rows routed each
    /// expert) so the in-chunk load sharing is accounted — prefill's
    /// near-all-expert union is the merged-acquire story at chunk width.
    /// Class decisions and pins are identical to the blocking path (one
    /// pin per expert, released by the chunk's FFN execution), so the two
    /// prefill implementations stay bit-equivalent.
    fn layer_ensure_resident_chunk(
        &self,
        li_u32: u32,
        per_expert: &PerExpert,
    ) -> (Vec<(ExpertKey, Class, Vec<f32>)>, TicketSet) {
        let demands: Vec<(ExpertKey, Class, Vec<f32>, f64, usize)> = per_expert
            .iter()
            .map(|(&expert, (class, gatew, score))| {
                let rows = gatew.iter().filter(|w| **w != 0.0).count().max(1);
                (ExpertKey::new(li_u32, expert), *class, gatew.clone(), *score, rows)
            })
            .collect();
        self.residency.acquire_chunk(li_u32, demands, self.current_seq)
    }

    /// Execute the layer's resident experts and return the MoE output to
    /// add back into the residual stream.
    fn layer_ffn(
        &mut self,
        s: usize,
        hn: &[f32],
        uses: Vec<(ExpertKey, Class, Vec<f32>)>,
        token_base: u64,
    ) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let mut moe_out = vec![0.0f32; s * d];
        let seq = self.current_seq;
        // an executor error must not leak the remaining uses' pins (the
        // barrier is already consumed, so nobody else can release them):
        // keep walking the use list releasing, then surface the error
        let mut first_err: Option<anyhow::Error> = None;
        for (key, class, gatew) in uses {
            let (prec, pool) = self.class_target(class);
            if first_err.is_none() {
                // execute at whatever tier the slot holds right now: a
                // progressive slot may still be at its lo floor while the
                // background upgrade streams in
                let resident = self.residency.resident_record(key, pool);
                // a missing record means the slot was evicted between load
                // and use under extreme pressure (or the joined load was
                // dropped as stale): execute directly from next-level
                // memory (bypass), without a cache-record use
                let bypass = resident.is_none();
                let (prec, record): (Precision, Vec<u8>) = match resident {
                    Some((tier, bytes)) => (tier, bytes),
                    None => (
                        prec,
                        self.residency.store().fetch_owned(key, prec, ONDEMAND_WEIGHT),
                    ),
                };
                match self.exec_expert(s, prec, &record, hn, &gatew, key, token_base) {
                    Ok(y) => {
                        accumulate(&mut moe_out, &y);
                        if !bypass {
                            self.residency.note_use(key, pool, seq);
                        }
                    }
                    Err(e) => first_err = Some(e),
                }
            }
            self.residency.release(key, pool);
        }
        match first_err {
            None => Ok(moe_out),
            Some(e) => Err(e),
        }
    }

    /// Execute the batch's merged execution set: one launch per unique
    /// (expert, class) over the padded width, with cache records
    /// attributed per demanding sequence and one pin released per
    /// demanding row (mirroring `acquire_merged`'s per-row pins).
    /// Grouped mode takes [`Self::layer_ffn_batch_grouped`] instead.
    fn layer_ffn_batch(
        &mut self,
        s: usize,
        hn: &[f32],
        uses: Vec<MergedUse>,
        token_base: u64,
    ) -> Result<Vec<f32>> {
        if self.grouped {
            return self.layer_ffn_batch_grouped(s, hn, uses, token_base);
        }
        let d = self.cfg.d_model;
        let mut moe_out = vec![0.0f32; s * d];
        // same contract as layer_ffn: release every remaining use's
        // per-row pins even when one expert launch errors
        let mut first_err: Option<anyhow::Error> = None;
        for u in uses {
            let (prec, pool) = self.class_target(u.class);
            if first_err.is_none() {
                // tier-at-use, same contract as layer_ffn
                let resident = self.residency.resident_record(u.key, pool);
                let bypass = resident.is_none();
                let (prec, record): (Precision, Vec<u8>) = match resident {
                    Some((tier, bytes)) => (tier, bytes),
                    None => (
                        prec,
                        self.residency.store().fetch_owned(u.key, prec, ONDEMAND_WEIGHT),
                    ),
                };
                match self.exec_expert(s, prec, &record, hn, &u.gatew, u.key, token_base) {
                    Ok(y) => {
                        accumulate(&mut moe_out, &y);
                        if !bypass {
                            for seq in &u.seqs {
                                self.residency.note_use(u.key, pool, *seq);
                            }
                        }
                    }
                    Err(e) => first_err = Some(e),
                }
            }
            for _ in &u.rows {
                self.residency.release(u.key, pool);
            }
        }
        match first_err {
            None => Ok(moe_out),
            Some(e) => Err(e),
        }
    }

    /// The grouped FFN pass: one snapshot + one dequant per unique expert
    /// of the step, every routed row reusing it.
    ///
    /// * **Snapshot arena** — one owned (tier, bytes) copy per unique
    ///   (expert, pool) via [`ExpertResidency::snapshot_records`]; uses
    ///   that collide on the same record (a Hi-upgraded Lo demand next to
    ///   a native Hi demand) share the copy (`snapshot_reuses`).
    /// * **Grouping** — resident same-record uses merge into one group
    ///   (their demanding rows are disjoint, so folding gate weights is an
    ///   assignment, not arithmetic); bypass uses (record evicted between
    ///   load and use) group alone over a direct next-level fetch, exactly
    ///   like the per-row path's bypass.
    /// * **One executor call** — [`Exec::expert_grouped`] dequantizes or
    ///   uploads each group's record once and runs all its rows, counting
    ///   launches/rows/dequant-reuses.
    /// * **Bit-identity** — groups accumulate in first-occurrence
    ///   (expert-ascending) order and every (row, expert) pair contributes
    ///   exactly once, so each output element sees the same addition
    ///   sequence as the per-row path (zero rows contribute exact zeros,
    ///   and the residual can never hold -0.0, so dropping them is exact).
    fn layer_ffn_batch_grouped(
        &mut self,
        s: usize,
        hn: &[f32],
        uses: Vec<MergedUse>,
        token_base: u64,
    ) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let pools: Vec<Pool> = uses.iter().map(|u| self.class_target(u.class).1).collect();
        let wants: Vec<(ExpertKey, Pool)> =
            uses.iter().zip(&pools).map(|(u, &p)| (u.key, p)).collect();
        let arena = self.residency.snapshot_records(&wants);

        enum Rec {
            Arena((ExpertKey, Pool)),
            Owned(Vec<u8>),
        }
        struct GroupBuild {
            key: ExpertKey,
            prec: Precision,
            gatew: Vec<f32>,
            rec: Rec,
            bypass: bool,
        }
        let mut groups: Vec<GroupBuild> = Vec::new();
        let mut gidx: HashMap<(ExpertKey, Pool), usize> = HashMap::new();
        let mut use_group: Vec<usize> = Vec::with_capacity(uses.len());
        for (u, &pool) in uses.iter().zip(&pools) {
            match arena.get(&(u.key, pool)) {
                Some(&(tier, _)) => {
                    let gi = *gidx.entry((u.key, pool)).or_insert_with(|| {
                        groups.push(GroupBuild {
                            key: u.key,
                            prec: tier,
                            gatew: vec![0.0; s],
                            rec: Rec::Arena((u.key, pool)),
                            bypass: false,
                        });
                        groups.len() - 1
                    });
                    for (gw, uw) in groups[gi].gatew.iter_mut().zip(&u.gatew) {
                        if *uw != 0.0 {
                            *gw = *uw;
                        }
                    }
                    use_group.push(gi);
                }
                None => {
                    let (prec, _) = self.class_target(u.class);
                    let record =
                        self.residency.store().fetch_owned(u.key, prec, ONDEMAND_WEIGHT);
                    groups.push(GroupBuild {
                        key: u.key,
                        prec,
                        gatew: u.gatew.clone(),
                        rec: Rec::Owned(record),
                        bypass: true,
                    });
                    use_group.push(groups.len() - 1);
                }
            }
        }
        let specs: Vec<GroupSpec<'_>> = groups
            .iter()
            .map(|g| GroupSpec {
                key: g.key,
                prec: g.prec,
                record: match &g.rec {
                    Rec::Arena(k) => &arena[k].1,
                    Rec::Owned(v) => v,
                },
                gatew: &g.gatew,
            })
            .collect();
        let (ys, st) = match self.exec.expert_grouped(s, hn, &specs) {
            Ok(out) => out,
            Err(e) => {
                // same contract as the per-row path: an executor error
                // must not leak the uses' per-row pins
                for (u, &pool) in uses.iter().zip(&pools) {
                    for _ in &u.rows {
                        self.residency.release(u.key, pool);
                    }
                }
                return Err(e);
            }
        };
        self.residency.note_grouped_exec(st.launches, st.rows, st.dequant_reuses);
        let mut moe_out = vec![0.0f32; s * d];
        for y in &ys {
            accumulate(&mut moe_out, y);
        }
        // per-use tail in merge order: Fig-5 capture off the group output,
        // cache-record uses per demanding sequence, one pin per row
        for (ui, u) in uses.iter().enumerate() {
            let pool = pools[ui];
            let g = use_group[ui];
            if self.capture.gate_stats {
                let y = &ys[g];
                for (r, w) in u.gatew.iter().enumerate() {
                    if *w > 0.0 {
                        let row = &y[r * d..(r + 1) * d];
                        let norm =
                            row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
                        self.capture.gates.push(GateObs {
                            key: u.key,
                            token: token_base + r as u64,
                            gate: *w,
                            out_norm: norm as f32,
                            score: 0.0,
                        });
                    }
                }
            }
            if !groups[g].bypass {
                for seq in &u.seqs {
                    self.residency.note_use(u.key, pool, *seq);
                }
            }
            for _ in &u.rows {
                self.residency.release(u.key, pool);
            }
        }
        Ok(moe_out)
    }

    /// LM head over the final activations; returns the last real row's
    /// logits.
    fn head(&mut self, s: usize, real: usize, x: &[f32]) -> Result<Vec<f32>> {
        let logits = self.exec.head(s, x, None)?;
        let v = self.cfg.vocab;
        Ok(logits[(real - 1) * v..real * v].to_vec())
    }

    /// Run `tokens` through the model with chunk-size `s` artifacts,
    /// blocking at every ensure-resident barrier (prefill and the batch-1
    /// decode path). Padded rows (when tokens.len() < s) are masked out of
    /// routing.
    fn forward_chunk(
        &mut self,
        kv: &mut KvState,
        tokens: &[u32],
        s: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        let real = tokens.len();
        anyhow::ensure!(real <= s);
        let e = self.cfg.n_experts as usize;
        let decode = s == 1;

        let mut x = self.embed(tokens, s);
        let pos = kv.pos as i32;

        for li in 0..self.cfg.n_layers as usize {
            let li_u32 = li as u32;
            x = self.layer_attention(kv, li, s, &x, pos)?;
            let (p_eff, probs, hn) = self.layer_gate(li, s, decode, &x, None)?;
            let per_expert =
                self.layer_route(li_u32, s, real, &probs[..s * e], &x, self.token_counter);
            if decode {
                self.layer_plan_prefetch(li_u32, p_eff, &probs);
                self.layer_observe(li_u32, &probs[..e]);
            }
            let (uses, waits) = self.layer_ensure_resident(li_u32, &per_expert);
            if !waits.is_empty() {
                let waited = self.residency.wait(&waits);
                self.load_wait += waited;
            }
            let moe_out = self.layer_ffn(s, &hn, uses, self.token_counter)?;
            for (xv, mv) in x.iter_mut().zip(&moe_out) {
                *xv += mv;
            }
        }

        kv.pos += real;
        self.token_counter += real as u64;

        if !want_logits {
            return Ok(None);
        }
        Ok(Some(self.head(s, real, &x)?))
    }

    /// One expert FFN launch through the executor, plus the Fig-5 capture
    /// channel (weighted output norms per routed row, ids `token_base + r`).
    #[allow(clippy::too_many_arguments)]
    fn exec_expert(
        &mut self,
        s: usize,
        prec: Precision,
        record: &[u8],
        hn: &[f32],
        gatew: &[f32],
        key: ExpertKey,
        token_base: u64,
    ) -> Result<Vec<f32>> {
        let y = self.exec.expert(s, prec, record, hn, gatew, key)?;
        if self.capture.gate_stats {
            let d = self.cfg.d_model;
            for (r, w) in gatew.iter().enumerate() {
                if *w > 0.0 {
                    let row = &y[r * d..(r + 1) * d];
                    let norm =
                        row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
                    self.capture.gates.push(GateObs {
                        key,
                        token: token_base + r as u64,
                        gate: *w,
                        out_norm: norm as f32,
                        score: 0.0,
                    });
                }
            }
        }
        Ok(y)
    }

    /// Map a scorer class to (precision, pool) under the active config.
    fn class_target(&self, class: Class) -> (Precision, Pool) {
        self.residency.class_target(class)
    }

    /// Compute-time spent inside the executor (for Fig 3a-real).
    pub fn compute_time(&self) -> Duration {
        self.exec.compute_time()
    }
}

fn accumulate(acc: &mut [f32], y: &[f32]) {
    for (o, yv) in acc.iter_mut().zip(y) {
        *o += yv;
    }
}

fn max_class(a: Class, b: Class) -> Class {
    use Class::*;
    match (a, b) {
        (Hi, _) | (_, Hi) => Hi,
        (Lo, _) | (_, Lo) => Lo,
        _ => Skip,
    }
}

/// Deterministic merge order for the batched execution set: experts
/// ascending, Hi before Lo before Skip — each row's accumulation order
/// then matches its solo decode exactly.
fn class_rank(c: Class) -> u8 {
    match c {
        Class::Hi => 0,
        Class::Lo => 1,
        Class::Skip => 2,
    }
}
