//! The inference engine: composes the AOT PJRT artifacts (attention,
//! stacked gating, expert FFNs, LM head) into prefill/decode steps, with
//! the paper's three mechanisms wired in:
//!
//! * on a cache miss the **Expert Scorer** picks the precision to fetch
//!   (token-level dynamic loading, §3.2);
//! * the **Stacking Computer** gate artifact predicts subsequent layers'
//!   experts and the predictor issues mixed-precision prefetches (§3.3);
//! * the **Multidimensional Cache Manager** owns eviction (§3.4).
//!
//! The engine is single-threaded on the compute side; the loader's
//! scheduler thread moves expert bytes concurrently with compute, which is
//! exactly the overlap the paper's prefetching exploits.
//!
//! All three mechanisms reach the expert pools through one API: the
//! [`crate::residency::ExpertResidency`] facade (`Engine::residency`),
//! which owns the loader + cache + predictor interaction, hands out typed
//! [`Ticket`]s for in-flight loads, and scopes per-sequence state in RAII
//! [`SequenceSession`]s. The engine never touches `ExpertLoader::submit`
//! or `CacheManager::reserve` directly.
//!
//! Decode comes in two shapes. [`Engine::decode_step`] is the blocking
//! batch-1 step the paper evaluates. Underneath it, each token runs as a
//! small per-layer state machine — a [`DecodeCursor`] — that can *suspend*
//! at the ensure-resident barrier instead of blocking on its tickets:
//! [`Engine::decode_begin`] embeds the token, [`Engine::decode_poll`]
//! advances layer-by-layer until either the token's logits are ready or an
//! on-demand expert transfer is still in flight
//! (`DecodeProgress::Pending`). The interleaved scheduler
//! (`coordinator::SchedulerMode::Interleaved`) exploits this to advance
//! another sequence's decode while this one's expert bytes are on the link.

mod capture;
mod state;

pub use capture::{Capture, GateObs, HiddenObs, RoutingObs};
pub use state::KvState;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use xla::Literal;

use crate::cache::{CacheManager, Policy, Pool};
use crate::config::{HardwareConfig, ModelConfig, PolicyConfig};
use crate::loader::scorer::{self, Class};
use crate::loader::GLOBAL_SCOPE;
use crate::memory::{LinkModel, ThrottledCopier};
use crate::model::{expert_literals, ExpertStore, NonExpertWeights};
use crate::predictor::Predictor;
use crate::residency::{ExpertResidency, SequenceSession, Ticket, TicketSet};
use crate::runtime::{lit_f32, lit_i32, lit_to_f32, Runtime};
use crate::{ExpertKey, Precision};

/// Prefill chunk sizes with compiled artifacts, largest first.
pub const PREFILL_CHUNKS: [usize; 3] = [128, 16, 1];

pub struct EngineOptions {
    pub hardware: HardwareConfig,
    pub policy: PolicyConfig,
    /// cache replacement policy (default: the paper's multidimensional)
    pub cache_policy: Option<Policy>,
    /// capture instrumentation channels
    pub capture: Capture,
    /// serve expert FFNs from the XLA-fused `expert_fast_*` lowerings
    /// instead of the interpret-mode Pallas ones (§Perf: ~11x on the CPU
    /// PJRT client; on a real TPU the Pallas kernels are the fast path)
    pub use_fast_ffn: bool,
}

impl EngineOptions {
    pub fn new(hardware: HardwareConfig, policy: PolicyConfig) -> Self {
        Self {
            hardware,
            policy,
            cache_policy: None,
            capture: Capture::none(),
            use_fast_ffn: true,
        }
    }
}

/// Precomputed per-layer literal sets (built once; the request path never
/// re-creates weight literals — perf-critical).
struct LayerLits {
    attn: [Literal; 5], // norm, wq, wk, wv, wo
    /// decode gate stack for this layer: (p_eff, pn[p,d], wg[p,d,E])
    gate_stack: (usize, Literal, Literal),
    /// prefill gate (p = 1)
    gate_single: (Literal, Literal),
}

/// Routing outcome of one layer for one chunk: expert -> (precision class,
/// per-row gate weights, min unimportance score). Ordered by expert id so
/// FFN output accumulation — and therefore the float results — are
/// deterministic run to run (a `HashMap` here made logits depend on hash
/// iteration order).
type PerExpert = BTreeMap<u32, (Class, Vec<f32>, f64)>;

/// Progress of a suspended decode token.
pub enum DecodeProgress {
    /// an ensure-resident barrier is waiting on in-flight expert loads
    Pending,
    /// token finished; next-token logits
    Done(Vec<f32>),
}

/// One layer suspended at the ensure-resident barrier.
struct PendingLayer {
    /// post-gate normed hidden (expert FFN input)
    hn: Vec<f32>,
    /// pinned experts to execute once resident
    uses: Vec<(ExpertKey, Class, Vec<f32>)>,
    /// residency tickets the barrier waits on
    waits: TicketSet,
    /// when the barrier was reached (stall accounting)
    t0: Instant,
    /// waits already resolved (via `decode_block` or a ready poll)
    satisfied: bool,
}

/// Per-token decode state machine: the layer cursor plus activations,
/// suspendable at the ensure-resident barrier and resumable later.
pub struct DecodeCursor {
    /// next layer to execute (or the layer suspended in `pending`)
    layer: usize,
    /// current activations [1, d_model]
    x: Vec<f32>,
    /// KV position of this token (fixed for the whole token)
    pos: i32,
    pending: Option<PendingLayer>,
    /// total stall attributed to this token (barrier-reach → barrier-clear,
    /// whether hidden by other sequences' compute or not)
    pub load_wait: Duration,
    finished: bool,
}

impl DecodeCursor {
    /// Residency tickets the cursor is currently suspended on (empty when
    /// runnable).
    pub fn pending_tickets(&self) -> &[Ticket] {
        match &self.pending {
            Some(p) if !p.satisfied => p.waits.tickets(),
            _ => &[],
        }
    }

    /// True when suspended on unconsumed in-flight loads.
    pub fn is_pending(&self) -> bool {
        self.pending.as_ref().map(|p| !p.satisfied).unwrap_or(false)
    }

    /// True when suspended AND at least one awaited load is still moving:
    /// a cursor whose tickets all completed is runnable (the next poll
    /// clears its barrier without blocking), which `is_pending` cannot
    /// see. Schedulers that *select* rather than sweep (SJF) must use
    /// this, or a ready-to-run sequence parks forever.
    pub fn is_blocked(&self) -> bool {
        self.pending
            .as_ref()
            .map(|p| !p.satisfied && !p.waits.all_ready())
            .unwrap_or(false)
    }
}

pub struct Engine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub policy: PolicyConfig,
    pub hardware: HardwareConfig,
    pub store: Arc<ExpertStore>,
    /// the session-scoped residency facade (loader + cache + predictor):
    /// the ONLY path through which experts become resident
    pub residency: ExpertResidency,
    pub capture: Capture,
    /// retained for instrumentation (Fig 7 offline prediction accuracy)
    pub nonexpert: NonExpertWeights,
    nonexpert_emb: Vec<f32>,
    layers: Vec<LayerLits>,
    emb_lit: Literal,
    final_norm_lit: Literal,
    /// decode-loop accounting: wall time spent *blocked* on expert loads
    pub load_wait: Duration,
    token_counter: u64,
    ffn_prefix: &'static str,
    /// sequence whose cache records the current compute is attributed to
    /// (interleaved serving; None on the batch-1 path)
    current_seq: Option<u64>,
}

impl Engine {
    /// Build an engine from `artifacts/<model>` + `artifacts/weights/<model>`.
    pub fn new(artifacts_root: &Path, model: &str, opts: EngineOptions) -> Result<Self> {
        let art_dir = artifacts_root.join(model);
        let weights_dir = artifacts_root.join("weights").join(model);
        let mut rt = Runtime::open(&art_dir)?;
        let cfg = ModelConfig::from_manifest(&rt.manifest.model_json())
            .map_err(|e| anyhow!("model config: {e}"))?;
        opts.policy.validate().map_err(|e| anyhow!("policy: {e}"))?;
        anyhow::ensure!(
            opts.hardware.hi_cache_experts >= cfg.top_k,
            "hi cache must hold at least top_k experts"
        );

        let nonexpert = NonExpertWeights::load(&weights_dir)?;
        let store = Arc::new(ExpertStore::load(&weights_dir, &cfg)?);

        // ---- compile the artifacts this configuration uses -----------------
        let hi = opts.policy.hi_precision;
        let lo = opts.policy.lo_precision;
        // older artifact sets may not carry the fast lowerings
        let fast = opts.use_fast_ffn
            && rt.manifest.artifacts.contains_key("expert_fast_f32_s1");
        let ffn_prefix = if fast { "expert_fast" } else { "expert" };
        let mut names: Vec<String> = Vec::new();
        for s in [1usize, 16, 128] {
            names.push(format!("attn_s{s}"));
            names.push(format!("head_s{s}"));
            names.push(format!("{ffn_prefix}_{}_s{s}", hi.name()));
            names.push(format!("{ffn_prefix}_{}_s{s}", lo.name()));
        }
        let depth = opts.policy.prefetch_depth;
        for p in 1..=(depth + 1).min(4) {
            names.push(format!("gate_p{p}_s1"));
        }
        for s in [16usize, 128] {
            names.push(format!("gate_p1_s{s}"));
        }
        rt.ensure_all(names.iter().map(|s| s.as_str()))?;

        // ---- per-layer literals --------------------------------------------
        let l = cfg.n_layers as usize;
        let stack_p = (depth + 1).min(4).max(1);
        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let get2 = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
                let (shape, data) = nonexpert.get(name)?;
                Ok((shape.to_vec(), data.to_vec()))
            };
            let mk = |name: &str| -> Result<Literal> {
                let (shape, data) = get2(name)?;
                lit_f32(&shape, &data)
            };
            let attn = [
                mk(&format!("attn_norm.{li}"))?,
                mk(&format!("wq.{li}"))?,
                mk(&format!("wk.{li}"))?,
                mk(&format!("wv.{li}"))?,
                mk(&format!("wo.{li}"))?,
            ];
            // decode gate stack: layers li .. li+p_eff-1
            let p_eff = stack_p.min(l - li);
            let mut pn = Vec::with_capacity(p_eff * cfg.d_model);
            let mut wg = Vec::with_capacity(p_eff * cfg.d_model * cfg.n_experts as usize);
            for j in 0..p_eff {
                let (_, pnj) = nonexpert.get(&format!("post_norm.{}", li + j))?;
                pn.extend_from_slice(pnj);
                let (_, wgj) = nonexpert.get(&format!("wg.{}", li + j))?;
                wg.extend_from_slice(wgj);
            }
            let e = cfg.n_experts as usize;
            let gate_stack = (
                p_eff,
                lit_f32(&[p_eff, cfg.d_model], &pn)?,
                lit_f32(&[p_eff, cfg.d_model, e], &wg)?,
            );
            let (_, pn0) = nonexpert.get(&format!("post_norm.{li}"))?;
            let (_, wg0) = nonexpert.get(&format!("wg.{li}"))?;
            let gate_single = (
                lit_f32(&[1, cfg.d_model], pn0)?,
                lit_f32(&[1, cfg.d_model, e], wg0)?,
            );
            layers.push(LayerLits { attn, gate_stack, gate_single });
        }

        let (emb_shape, emb) = nonexpert.get("emb")?;
        let emb_lit = lit_f32(emb_shape, emb)?;
        let nonexpert_emb = emb.to_vec();
        let (_, fnorm) = nonexpert.get("final_norm")?;
        let final_norm_lit = lit_f32(&[cfg.d_model], fnorm)?;

        // ---- cache + loader -------------------------------------------------
        let penalty_ratio = opts.policy.penalty_ratio(&cfg);
        let cache_policy = opts.cache_policy.clone().unwrap_or(Policy::Multidim {
            w: [opts.policy.w_lru, opts.policy.w_lfu, opts.policy.w_lhu, opts.policy.w_fld],
        });
        let cache = Arc::new(Mutex::new(CacheManager::new(
            cfg.n_layers,
            cfg.n_experts,
            opts.hardware.hi_cache_experts,
            cfg.bytes_for(hi),
            opts.hardware.lo_cache_experts,
            cfg.bytes_for(lo),
            cache_policy,
            penalty_ratio,
        )));
        let copier = Arc::new(ThrottledCopier::new(LinkModel {
            bytes_per_s: opts.hardware.load_bw,
            latency_s: opts.hardware.load_latency,
        }));
        let predictor = Predictor::new(
            depth,
            cfg.top_k,
            opts.policy.t1,
            opts.policy.t2,
            opts.policy.dynamic_loading,
            cfg.n_layers,
        );
        let residency =
            ExpertResidency::new(store.clone(), cache, copier, predictor, hi, lo);

        Ok(Self {
            rt,
            cfg,
            policy: opts.policy,
            hardware: opts.hardware,
            store,
            residency,
            capture: opts.capture,
            nonexpert,
            nonexpert_emb,
            layers,
            emb_lit,
            final_norm_lit,
            load_wait: Duration::ZERO,
            token_counter: 0,
            ffn_prefix: if fast { "expert_fast" } else { "expert" },
            current_seq: None,
        })
    }

    /// Start a new sequence: fresh KV state + per-sequence cache records.
    /// Batch-1 semantics: resets the (global) sequence-level records, so it
    /// must not be used while other sequences are live — interleaved
    /// serving uses [`Self::begin_session`] instead.
    pub fn new_sequence(&mut self) -> KvState {
        self.residency.reset_batch1();
        self.current_seq = None;
        KvState::new(&self.cfg)
    }

    /// Register a live sequence for interleaved serving: an RAII residency
    /// session (per-sequence cache records + private prefetch-generation
    /// scope, both retired when the session drops) and fresh KV state.
    pub fn begin_session(&self) -> (SequenceSession, KvState) {
        (self.residency.begin_session(), KvState::new(&self.cfg))
    }

    /// Attribute subsequent compute to `seq`'s cache records (the
    /// scheduler's context switch; None = batch-1 global records).
    pub fn set_active_sequence(&mut self, seq: Option<u64>) {
        self.current_seq = seq;
    }

    /// Prefill `tokens`, returning the logits after the last token.
    pub fn prefill(&mut self, kv: &mut KvState, tokens: &[u32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(tokens.len() <= kv.remaining(), "prompt exceeds KV capacity");
        let mut i = 0usize;
        let mut logits = None;
        while i < tokens.len() {
            let remaining = tokens.len() - i;
            let chunk = *PREFILL_CHUNKS
                .iter()
                .find(|&&c| c <= remaining)
                .unwrap_or(&1usize);
            let is_last = i + chunk >= tokens.len();
            let out = self.forward_chunk(kv, &tokens[i..i + chunk], chunk, is_last)?;
            if is_last {
                logits = out;
            }
            i += chunk;
        }
        logits.ok_or_else(|| anyhow!("prefill produced no logits"))
    }

    /// One blocking decode step for `token`; returns next-token logits.
    /// (The paper's batch-1 path: blocks on the residency tickets at every
    /// ensure-resident barrier.)
    pub fn decode_step(&mut self, kv: &mut KvState, token: u32) -> Result<Vec<f32>> {
        let mut cur = self.decode_begin(kv, token)?;
        loop {
            match self.decode_poll(kv, &mut cur)? {
                DecodeProgress::Done(logits) => return Ok(logits),
                DecodeProgress::Pending => self.decode_block(&mut cur),
            }
        }
    }

    // ------------------------------------------------------------------
    // Suspendable decode (the interleaved scheduler's unit of work)
    // ------------------------------------------------------------------

    /// Begin one decode token: embed it and position the layer cursor.
    pub fn decode_begin(&mut self, kv: &KvState, token: u32) -> Result<DecodeCursor> {
        anyhow::ensure!(kv.remaining() >= 1, "KV cache full");
        Ok(DecodeCursor {
            layer: 0,
            x: self.embed(&[token], 1),
            pos: kv.pos as i32,
            pending: None,
            load_wait: Duration::ZERO,
            finished: false,
        })
    }

    /// Advance the cursor as far as possible without blocking: runs layers
    /// until either the token completes (`Done`) or an ensure-resident
    /// barrier's loads are still in flight (`Pending`). Never sleeps — a
    /// `Pending` cursor costs the caller nothing but this poll.
    pub fn decode_poll(
        &mut self,
        kv: &mut KvState,
        cur: &mut DecodeCursor,
    ) -> Result<DecodeProgress> {
        anyhow::ensure!(!cur.finished, "decode cursor already finished");
        loop {
            // resolve the outstanding barrier first
            let still_loading = match &cur.pending {
                Some(p) => !p.satisfied && !p.waits.all_ready(),
                None => false,
            };
            if still_loading {
                return Ok(DecodeProgress::Pending);
            }
            if let Some(p) = cur.pending.take() {
                cur.load_wait += p.t0.elapsed();
                let moe_out = self.layer_ffn(1, &p.hn, p.uses)?;
                for (xv, mv) in cur.x.iter_mut().zip(&moe_out) {
                    *xv += mv;
                }
                cur.layer += 1;
            }
            if cur.layer == self.cfg.n_layers as usize {
                cur.finished = true;
                kv.pos += 1;
                self.token_counter += 1;
                let logits = self.head(1, 1, &cur.x)?;
                return Ok(DecodeProgress::Done(logits));
            }

            let li = cur.layer;
            let li_u32 = li as u32;
            let e = self.cfg.n_experts as usize;
            cur.x = self.layer_attention(kv, li, 1, &cur.x, cur.pos)?;
            let (p_eff, probs, hn) = self.layer_gate(li, 1, true, &cur.x)?;
            let per_expert = self.layer_route(li_u32, 1, 1, &probs[..e], &cur.x);
            self.layer_plan_prefetch(li_u32, p_eff, &probs);
            self.layer_observe(li_u32, &probs[..e]);
            let (uses, waits) = self.layer_ensure_resident(li_u32, &per_expert);
            cur.pending = Some(PendingLayer {
                hn,
                uses,
                waits,
                t0: Instant::now(),
                satisfied: false,
            });
            // loop: an empty/already-complete wait set clears immediately
        }
    }

    /// Block until the cursor's outstanding loads complete (the batch-1
    /// path, and the scheduler's nothing-else-runnable fallback). The
    /// blocked time is *unhidden* load wait: it lands in
    /// [`Engine::load_wait`] and the loader's `wait_time`, exactly like the
    /// pre-scheduler blocking decode.
    pub fn decode_block(&mut self, cur: &mut DecodeCursor) {
        if let Some(p) = &mut cur.pending {
            if !p.satisfied {
                let waited = self.residency.wait(&p.waits);
                p.satisfied = true;
                self.load_wait += waited;
            }
        }
    }

    /// Abandon a suspended cursor (scheduler abort path): release the
    /// cache pins its barrier holds so the slots stay evictable. The
    /// in-flight loads themselves are left to complete harmlessly.
    pub fn decode_abort(&self, cur: DecodeCursor) {
        if let Some(p) = cur.pending {
            for (key, class, _gatew) in p.uses {
                let (_prec, pool) = self.class_target(class);
                self.residency.release(key, pool);
            }
        }
    }

    // ------------------------------------------------------------------
    // Per-layer building blocks (shared by prefill chunks and the cursor)
    // ------------------------------------------------------------------

    /// Embed `tokens` into an [s, d] activation buffer (pad rows use PAD).
    fn embed(&self, tokens: &[u32], s: usize) -> Vec<f32> {
        let d = self.cfg.d_model;
        let real = tokens.len();
        let mut x = vec![0.0f32; s * d];
        for (r, slot) in x.chunks_mut(d).enumerate() {
            let tok = if r < real { tokens[r] } else { crate::tokenizer::PAD } as usize;
            slot.copy_from_slice(&self.nonexpert_emb[tok * d..(tok + 1) * d]);
        }
        x
    }

    /// Attention for layer `li`; returns the new activations and writes the
    /// updated KV back into `kv`.
    fn layer_attention(
        &mut self,
        kv: &mut KvState,
        li: usize,
        s: usize,
        x: &[f32],
        pos: i32,
    ) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let x_lit = lit_f32(&[s, d], x)?;
        let kdims = [self.cfg.max_seq, self.cfg.n_kv_heads, self.cfg.head_dim()];
        let k_lit = lit_f32(&kdims, &kv.k[li])?;
        let v_lit = lit_f32(&kdims, &kv.v[li])?;
        let pos_lit = lit_i32(pos);
        let ll = &self.layers[li];
        let args: Vec<&Literal> = vec![
            &x_lit, &ll.attn[0], &ll.attn[1], &ll.attn[2], &ll.attn[3], &ll.attn[4],
            &k_lit, &v_lit, &pos_lit,
        ];
        let outs = self.rt.execute(&format!("attn_s{s}"), &args)?;
        anyhow::ensure!(outs.len() == 3, "attn outputs");
        let y = lit_to_f32(&outs[0])?;
        kv.k[li] = lit_to_f32(&outs[1])?;
        kv.v[li] = lit_to_f32(&outs[2])?;
        Ok(y)
    }

    /// Gating for layer `li`: stacked on decode, single on prefill.
    /// Returns (p_eff, probs [p_eff, s, e], normed hidden [s, d]).
    fn layer_gate(
        &mut self,
        li: usize,
        s: usize,
        decode: bool,
        x: &[f32],
    ) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        let d = self.cfg.d_model;
        let x_lit = lit_f32(&[s, d], x)?;
        let ll = &self.layers[li];
        if decode {
            let (p_eff, ref pn, ref wg) = ll.gate_stack;
            let args: Vec<&Literal> = vec![&x_lit, pn, wg];
            let outs = self.rt.execute(&format!("gate_p{p_eff}_s1"), &args)?;
            Ok((p_eff, lit_to_f32(&outs[0])?, lit_to_f32(&outs[1])?))
        } else {
            let (ref pn, ref wg) = ll.gate_single;
            let args: Vec<&Literal> = vec![&x_lit, pn, wg];
            let outs = self.rt.execute(&format!("gate_p1_s{s}"), &args)?;
            Ok((1usize, lit_to_f32(&outs[0])?, lit_to_f32(&outs[1])?))
        }
    }

    /// Route the chunk's tokens through the Expert Scorer, merging per-row
    /// decisions into the layer's per-expert execution set.
    fn layer_route(
        &mut self,
        li_u32: u32,
        s: usize,
        real: usize,
        layer_probs: &[f32],
        x: &[f32],
    ) -> PerExpert {
        let d = self.cfg.d_model;
        let e = self.cfg.n_experts as usize;
        if self.capture.hidden_states {
            // raw gating input (attention output, pre-norm): the
            // quantity whose cross-layer similarity Fig 7 measures
            self.capture.hiddens.push(HiddenObs {
                token: self.token_counter,
                layer: li_u32,
                hidden: x[..d].to_vec(),
            });
        }
        let mut per_expert: PerExpert = BTreeMap::new();
        for r in 0..real {
            let row = &layer_probs[r * e..(r + 1) * e];
            let decisions = scorer::decide(
                row,
                self.cfg.top_k,
                self.policy.t1,
                self.policy.t2,
                self.policy.dynamic_loading,
            );
            if self.capture.routing {
                self.capture.routes.push(RoutingObs {
                    token: self.token_counter + r as u64,
                    layer: li_u32,
                    experts: decisions.iter().map(|dd| dd.expert).collect(),
                    probs: row.to_vec(),
                });
            }
            for dd in decisions {
                let ent = per_expert
                    .entry(dd.expert)
                    .or_insert((Class::Skip, vec![0.0; s], dd.score));
                ent.0 = max_class(ent.0, dd.class);
                ent.1[r] = dd.gate_weight;
                ent.2 = ent.2.min(dd.score);
            }
        }
        per_expert
    }

    /// Predictor step (decode only): plan mixed-precision prefetches for
    /// subsequent layers from the stacked gate output, under the active
    /// sequence's generation scope so other sequences' queued prefetches
    /// survive this token.
    fn layer_plan_prefetch(&mut self, li_u32: u32, p_eff: usize, probs: &[f32]) {
        if p_eff <= 1 || self.policy.prefetch_depth == 0 {
            return;
        }
        let e = self.cfg.n_experts as usize;
        let stacked: Vec<Vec<f32>> =
            (0..p_eff).map(|j| probs[j * e..(j + 1) * e].to_vec()).collect();
        let scope = self.current_seq.unwrap_or(GLOBAL_SCOPE);
        self.residency.plan_prefetch(scope, li_u32, self.cfg.n_layers, &stacked);
    }

    /// Score the pending prediction of this layer + release pins
    /// (unconditional on decode: even layers with p_eff == 1 may have been
    /// predicted from an earlier layer).
    fn layer_observe(&mut self, li_u32: u32, layer_probs_first: &[f32]) {
        self.residency.observe(li_u32, layer_probs_first);
    }

    /// Ensure-resident barrier: hand the layer's routed experts to the
    /// residency facade, which probes/pins, submits (or joins) on-demand
    /// loads for misses, and returns the execution set plus the tickets to
    /// wait on. Does NOT wait — blocking vs suspension is the caller's
    /// policy.
    fn layer_ensure_resident(
        &self,
        li_u32: u32,
        per_expert: &PerExpert,
    ) -> (Vec<(ExpertKey, Class, Vec<f32>)>, TicketSet) {
        let demands: Vec<(ExpertKey, Class, Vec<f32>)> = per_expert
            .iter()
            .map(|(&expert, (class, gatew, _score))| {
                (ExpertKey::new(li_u32, expert), *class, gatew.clone())
            })
            .collect();
        self.residency.acquire(li_u32, demands, self.current_seq)
    }

    /// Execute the layer's resident experts and return the MoE output to
    /// add back into the residual stream.
    fn layer_ffn(
        &mut self,
        s: usize,
        hn: &[f32],
        uses: Vec<(ExpertKey, Class, Vec<f32>)>,
    ) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let x_norm_lit = lit_f32(&[s, d], hn)?;
        let mut moe_out = vec![0.0f32; s * d];
        let seq = self.current_seq;
        for (key, class, gatew) in uses {
            let (prec, pool) = self.class_target(class);
            let buf = self.residency.buffer(key, pool);
            let Some(buf) = buf else {
                // evicted between load and use under extreme pressure (or
                // the joined load was dropped as stale): execute directly
                // from next-level memory (bypass)
                let record = self.store.record(key, prec).to_vec();
                self.run_expert(&x_norm_lit, s, prec, &record, &gatew, &mut moe_out, key)?;
                self.residency.release(key, pool);
                continue;
            };
            let record = buf.lock().unwrap().clone();
            self.run_expert(&x_norm_lit, s, prec, &record, &gatew, &mut moe_out, key)?;
            self.residency.note_use(key, pool, seq);
            self.residency.release(key, pool);
        }
        Ok(moe_out)
    }

    /// LM head over the final activations; returns the last real row's
    /// logits.
    fn head(&mut self, s: usize, real: usize, x: &[f32]) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let x_lit = lit_f32(&[s, d], x)?;
        let args: Vec<&Literal> = vec![&x_lit, &self.final_norm_lit, &self.emb_lit];
        let outs = self.rt.execute(&format!("head_s{s}"), &args)?;
        let logits = lit_to_f32(&outs[0])?;
        let v = self.cfg.vocab;
        Ok(logits[(real - 1) * v..real * v].to_vec())
    }

    /// Run `tokens` through the model with chunk-size `s` artifacts,
    /// blocking at every ensure-resident barrier (prefill and the batch-1
    /// decode path). Padded rows (when tokens.len() < s) are masked out of
    /// routing.
    fn forward_chunk(
        &mut self,
        kv: &mut KvState,
        tokens: &[u32],
        s: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        let real = tokens.len();
        anyhow::ensure!(real <= s);
        let e = self.cfg.n_experts as usize;
        let decode = s == 1;

        let mut x = self.embed(tokens, s);
        let pos = kv.pos as i32;

        for li in 0..self.cfg.n_layers as usize {
            let li_u32 = li as u32;
            x = self.layer_attention(kv, li, s, &x, pos)?;
            let (p_eff, probs, hn) = self.layer_gate(li, s, decode, &x)?;
            let per_expert = self.layer_route(li_u32, s, real, &probs[..s * e], &x);
            if decode {
                self.layer_plan_prefetch(li_u32, p_eff, &probs);
                self.layer_observe(li_u32, &probs[..e]);
            }
            let (uses, waits) = self.layer_ensure_resident(li_u32, &per_expert);
            if !waits.is_empty() {
                let waited = self.residency.wait(&waits);
                self.load_wait += waited;
            }
            let moe_out = self.layer_ffn(s, &hn, uses)?;
            for (xv, mv) in x.iter_mut().zip(&moe_out) {
                *xv += mv;
            }
        }

        kv.pos += real;
        self.token_counter += real as u64;

        if !want_logits {
            return Ok(None);
        }
        Ok(Some(self.head(s, real, &x)?))
    }

    fn run_expert(
        &mut self,
        x_norm_lit: &Literal,
        s: usize,
        prec: Precision,
        record: &[u8],
        gatew: &[f32],
        moe_out: &mut [f32],
        key: ExpertKey,
    ) -> Result<()> {
        let mut args: Vec<Literal> = Vec::with_capacity(8);
        args.push(x_norm_lit.clone());
        args.extend(expert_literals(&self.cfg, prec, record)?);
        args.push(lit_f32(&[s], gatew)?);
        let name = format!("{}_{}_s{s}", self.ffn_prefix, prec.name());
        let outs = self
            .rt
            .execute(&name, &args)
            .with_context(|| format!("expert {key:?} via {name}"))?;
        let y = lit_to_f32(&outs[0])?;
        if self.capture.gate_stats {
            let d = self.cfg.d_model;
            for (r, w) in gatew.iter().enumerate() {
                if *w > 0.0 {
                    let row = &y[r * d..(r + 1) * d];
                    let norm =
                        row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
                    self.capture.gates.push(GateObs {
                        key,
                        token: self.token_counter + r as u64,
                        gate: *w,
                        out_norm: norm as f32,
                        score: 0.0,
                    });
                }
            }
        }
        for (o, yv) in moe_out.iter_mut().zip(&y) {
            *o += yv;
        }
        Ok(())
    }

    /// Map a scorer class to (precision, pool) under the active config.
    fn class_target(&self, class: Class) -> (Precision, Pool) {
        self.residency.class_target(class)
    }

    /// Compute-time spent inside PJRT (for Fig 3a-real).
    pub fn compute_time(&self) -> Duration {
        self.rt.compute_time.get()
    }
}

fn max_class(a: Class, b: Class) -> Class {
    use Class::*;
    match (a, b) {
        (Hi, _) | (_, Hi) => Hi,
        (Lo, _) | (_, Lo) => Lo,
        _ => Skip,
    }
}
