//! Per-sequence state: KV caches (host-resident, threaded through the
//! functional attention artifacts) and position.

use crate::config::ModelConfig;

/// One sequence's KV caches: per layer, [max_seq, n_kv_heads, head_dim].
#[derive(Debug, Clone)]
pub struct KvState {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// tokens already written to the cache
    pub pos: usize,
    pub max_seq: usize,
}

impl KvState {
    pub fn new(cfg: &ModelConfig) -> Self {
        let per_layer = cfg.max_seq * cfg.n_kv_heads * cfg.head_dim();
        Self {
            k: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
            pos: 0,
            max_seq: cfg.max_seq,
        }
    }

    /// Zero-capacity placeholder, used to move a live sequence's KV state
    /// into a batched decode cursor without reallocating (the cursor hands
    /// it back on completion or eviction). Never valid for compute.
    pub fn empty() -> Self {
        Self { k: Vec::new(), v: Vec::new(), pos: 0, max_seq: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.max_seq.saturating_sub(self.pos)
    }

    pub fn reset(&mut self) {
        for k in &mut self.k {
            k.fill(0.0);
        }
        for v in &mut self.v {
            v.fill(0.0);
        }
        self.pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 64,
            d_ff: 128,
            n_experts: 4,
            top_k: 2,
            n_heads: 4,
            n_kv_heads: 2,
            vocab: 260,
            max_seq: 16,
            quant_group: 32,
            expert_bytes: [0; 4],
        }
    }

    #[test]
    fn kv_dims() {
        let s = KvState::new(&cfg());
        assert_eq!(s.k.len(), 2);
        assert_eq!(s.k[0].len(), 16 * 2 * 16);
        assert_eq!(s.remaining(), 16);
    }

    #[test]
    fn reset_clears() {
        let mut s = KvState::new(&cfg());
        s.k[0][5] = 1.0;
        s.pos = 7;
        s.reset();
        assert_eq!(s.k[0][5], 0.0);
        assert_eq!(s.pos, 0);
    }
}
