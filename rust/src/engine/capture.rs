//! Instrumentation capture for the paper's analysis figures. Disabled by
//! default (zero cost on the hot path beyond a bool check); the figures
//! binary enables the channels it needs.

use crate::ExpertKey;

/// One expert activation observation (Fig 5a: ‖G‖ vs ‖G·E(x)‖).
#[derive(Debug, Clone, Copy)]
pub struct GateObs {
    pub key: ExpertKey,
    pub token: u64,
    /// gate weight (normalized top-k)
    pub gate: f32,
    /// L2 norm of the expert's weighted output
    pub out_norm: f32,
    /// Eq. 2 unimportance score
    pub score: f64,
}

/// Per-(token, layer) gate-input hidden state (Fig 7: cross-layer cosine).
#[derive(Debug, Clone)]
pub struct HiddenObs {
    pub token: u64,
    pub layer: u32,
    pub hidden: Vec<f32>,
}

/// Routing record: top-k experts chosen per (token, layer) (Fig 10).
#[derive(Debug, Clone)]
pub struct RoutingObs {
    pub token: u64,
    pub layer: u32,
    pub experts: Vec<u32>,
    pub probs: Vec<f32>,
}

#[derive(Debug, Default)]
pub struct Capture {
    pub gate_stats: bool,
    pub hidden_states: bool,
    pub routing: bool,
    pub gates: Vec<GateObs>,
    pub hiddens: Vec<HiddenObs>,
    pub routes: Vec<RoutingObs>,
}

impl Capture {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn all() -> Self {
        Self { gate_stats: true, hidden_states: true, routing: true, ..Self::default() }
    }

    pub fn clear(&mut self) {
        self.gates.clear();
        self.hiddens.clear();
        self.routes.clear();
    }
}
