//! TCP serving front-end. Line protocol (one request per line):
//!
//!   GEN <max_new_tokens> <temperature> <prompt…>\n
//!   STATS\n
//!
//! responses are single JSON lines. Two serving disciplines:
//!
//! * [`Server::serve`] — the paper's batch-1 FCFS protocol: a
//!   single-threaded accept loop, one request at a time on the caller's
//!   thread.
//! * [`Server::serve_concurrent`] — continuous serving: an acceptor thread
//!   plus one reader thread per connection (bounded by
//!   `--max-conn-threads`; over-capacity connects get a one-line
//!   `err_json` rejection instead of an unbounded thread spawn) feed the
//!   interleaved scheduler through an mpsc event channel; the engine stays
//!   on the caller's thread (PJRT state is not `Send`), and each
//!   completion is routed back to its connection through a per-request
//!   response channel. Requests the coordinator's bounded admission queue
//!   refuses are answered immediately with the typed rejection. While
//!   every live sequence is stalled on the expert-load link, the scheduler
//!   parks on the same channel and is woken by residency-ticket completion
//!   wakeups (`residency::Ticket::on_ready`) or by new connections — it
//!   never spins.
//!
//! tokio is not in the offline vendor set — std::net/std::thread/mpsc plus
//! the loader's own scheduler thread cover the concurrency needs
//! (DESIGN.md).

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{Coordinator, GenerationResult, Request, SchedulerMode};
use crate::util::json::{num, obj, s, Json};

/// Default per-connection read timeout (`--client-timeout-ms`).
pub const DEFAULT_CLIENT_TIMEOUT: Duration = Duration::from_secs(30);
/// Default cap on concurrent connection reader threads
/// (`--max-conn-threads`). One OS thread per live connection is fine at
/// this scale; an open-loop storm beyond it gets typed rejections instead
/// of a thread bomb.
pub const DEFAULT_MAX_CONN_THREADS: usize = 256;

pub struct Server {
    listener: TcpListener,
    next_id: u64,
    /// per-connection read timeout (both serving disciplines)
    client_timeout: Duration,
    /// bounded worker pool: max concurrent reader threads in
    /// [`Self::serve_concurrent`]; over-capacity connects are answered
    /// with an `err_json` rejection and closed by the acceptor
    max_conn_threads: usize,
}

/// A parsed protocol line.
enum Parsed {
    Gen(Request),
    Stats,
}

/// Commands flowing from connection threads to the scheduler thread.
enum Command {
    Gen { req: Request, resp: mpsc::Sender<Json> },
    Stats { resp: mpsc::Sender<Json> },
}

/// Everything that can wake the scheduler thread.
enum Event {
    Cmd(Command),
    /// a loader completion callback fired (some stalled sequence may run)
    Wake,
    /// a connection finished (max_conns accounting)
    ConnClosed,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:7077"; port 0 picks a free port).
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            next_id: 1,
            client_timeout: DEFAULT_CLIENT_TIMEOUT,
            max_conn_threads: DEFAULT_MAX_CONN_THREADS,
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Per-connection read timeout (`--client-timeout-ms`): tight-deadline
    /// overload tests set this to milliseconds so an idle client cannot
    /// hold a reader thread for the legacy hard-coded 30 s.
    pub fn set_client_timeout(&mut self, timeout: Duration) {
        self.client_timeout = timeout.max(Duration::from_millis(1));
    }

    /// Cap concurrent connection reader threads (`--max-conn-threads`,
    /// min 1). See [`DEFAULT_MAX_CONN_THREADS`].
    pub fn set_max_conn_threads(&mut self, n: usize) {
        self.max_conn_threads = n.max(1);
    }

    /// Serve forever (or until `max_conns` connections have been handled,
    /// for tests/benches — `None` = unbounded). Batch-1 FCFS: connections
    /// are handled one at a time on the caller's thread.
    pub fn serve(&mut self, coord: &mut Coordinator, max_conns: Option<usize>) -> Result<()> {
        let mut handled = 0usize;
        loop {
            let (stream, _peer) = self.listener.accept()?;
            if let Err(e) = self.handle(coord, stream) {
                eprintln!("[server] connection error: {e:#}");
            }
            handled += 1;
            if let Some(m) = max_conns {
                if handled >= m {
                    return Ok(());
                }
            }
        }
    }

    /// Serve with the interleaved scheduler: concurrent connections each
    /// get a reader thread; their requests decode round-robin on the
    /// caller's thread, overlapping one sequence's expert loads with the
    /// others' compute. Stops after `max_conns` connections have been
    /// accepted *and* fully served (`None` = forever).
    pub fn serve_concurrent(
        &mut self,
        coord: &mut Coordinator,
        max_conns: Option<usize>,
    ) -> Result<()> {
        coord.mode = SchedulerMode::Interleaved;
        let listener = self.listener.try_clone()?;
        let (tx, rx) = mpsc::channel::<Event>();
        let wake_tx = tx.clone();
        let ids = Arc::new(AtomicU64::new(self.next_id));
        let timeout = self.client_timeout;
        let thread_cap = self.max_conn_threads.max(1);

        let ids_acceptor = ids.clone();
        // live reader-thread count: only the acceptor increments (so the
        // check-then-increment below is race-free) and each reader
        // decrements as it exits
        let live_conns = Arc::new(AtomicUsize::new(0));
        let acceptor = std::thread::spawn(move || {
            let mut handled = 0usize;
            loop {
                let Ok((stream, _peer)) = listener.accept() else { break };
                if live_conns.load(Ordering::Acquire) >= thread_cap {
                    // bounded worker pool: answer and close instead of
                    // spawning an unbounded thread (or wedging the
                    // acceptor behind a full pool)
                    reject_conn(stream, thread_cap);
                    let _ = tx.send(Event::ConnClosed);
                } else {
                    live_conns.fetch_add(1, Ordering::AcqRel);
                    let conn_tx = tx.clone();
                    let conn_ids = ids_acceptor.clone();
                    let conn_live = live_conns.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, conn_tx, conn_ids, timeout) {
                            eprintln!("[server] connection error: {e:#}");
                        }
                        conn_live.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                handled += 1;
                if let Some(m) = max_conns {
                    if handled >= m {
                        break;
                    }
                }
            }
        });

        let mut responders: HashMap<u64, mpsc::Sender<Json>> = HashMap::new();
        // load tasks that already carry one of our wake callbacks: arming
        // is once per task, not once per park (waiters accumulate)
        let mut armed_ids: HashSet<u64> = HashSet::new();
        let mut closed = 0usize;
        loop {
            // ingest everything already queued, without blocking
            while let Ok(ev) = rx.try_recv() {
                handle_event(coord, ev, &mut responders, &mut closed);
            }
            let finished = max_conns.map(|m| closed >= m).unwrap_or(false);
            if finished && !coord.has_work() && responders.is_empty() {
                break;
            }
            if !coord.has_work() {
                // idle: park until the next connection event
                match rx.recv() {
                    Ok(ev) => handle_event(coord, ev, &mut responders, &mut closed),
                    Err(_) => break,
                }
                continue;
            }
            if coord.all_stalled() {
                // every live sequence waits on the link: nothing to
                // overlap. Park on the event channel — ticket completion
                // wakeups (or new connections) wake us. Parked time is
                // the unhidden share of the load wait. Only genuinely
                // in-flight tickets arm (`on_ready` refuses completed
                // ones): a barrier whose loads partially completed would
                // otherwise wake immediately and turn the park into a hot
                // spin.
                let tickets = coord.pending_tickets();
                let current: HashSet<u64> = tickets.iter().map(|t| t.task_id()).collect();
                armed_ids.retain(|id| current.contains(id));
                let mut armed = false;
                for ticket in tickets {
                    // a completed ticket must NOT count as armed — its
                    // wake already fired (and may be drained); the next
                    // step's poll clears its barrier without parking
                    if ticket.is_ready() {
                        continue;
                    }
                    // still-armed in-flight tickets from an earlier park
                    // keep their callback; parking on them is safe
                    if armed_ids.contains(&ticket.task_id()) {
                        armed = true;
                        continue;
                    }
                    let wtx = wake_tx.clone();
                    if ticket.on_ready(move || {
                        let _ = wtx.send(Event::Wake);
                    }) {
                        armed_ids.insert(ticket.task_id());
                        armed = true;
                    }
                }
                if armed {
                    let t0 = Instant::now();
                    match rx.recv() {
                        Ok(ev) => {
                            coord.note_unhidden_wait(t0.elapsed());
                            handle_event(coord, ev, &mut responders, &mut closed);
                        }
                        Err(_) => break,
                    }
                }
                // !armed: every awaited load already completed — the next
                // step's try_wait will clear the barriers without parking
            }
            // an engine error on one request must not tear down the whole
            // server (the FCFS path replies err_json per request too):
            // fail the affected requests individually and keep accepting
            match coord.step_nonblocking() {
                Ok(results) => {
                    for r in results {
                        if let Some(resp) = responders.remove(&r.id) {
                            let _ = resp.send(gen_json(&r));
                        }
                    }
                }
                Err(e) => {
                    eprintln!("[server] scheduler error: {e:#}");
                    let msg = format!("{e:#}");
                    for id in coord.abort_all() {
                        if let Some(resp) = responders.remove(&id) {
                            let _ = resp.send(err_json(&msg));
                        }
                    }
                }
            }
            // prefill errors fail only their own request: the scheduler
            // logged and recorded them and kept running — answer each on
            // its channel
            for (id, msg) in coord.take_failures() {
                if let Some(resp) = responders.remove(&id) {
                    let _ = resp.send(err_json(&msg));
                }
            }
        }
        self.next_id = ids.load(Ordering::Relaxed);
        if max_conns.is_some() {
            let _ = acceptor.join();
        }
        coord.sync_report();
        {
            let sch = coord.scheduler_stats();
            if sch.prefill_slices > 0 {
                eprintln!(
                    "[server] chunked prefill: {} slices ({} stall ms), chunks \
                     128/16/1 = {}/{}/{}, {} failures",
                    sch.prefill_slices,
                    (sch.prefill_stall.as_secs_f64() * 1e3).round(),
                    sch.prefill_chunks[0],
                    sch.prefill_chunks[1],
                    sch.prefill_chunks[2],
                    sch.prefill_failures,
                );
            }
        }
        if coord.max_batch > 1 {
            // batched-decode shutdown summary: did concurrency actually
            // become FLOP/load sharing? (occupancy > 1 says yes)
            let sch = coord.scheduler_stats();
            eprintln!(
                "[server] batched decode: {} steps, occupancy {:.2}, {} padded slots, \
                 {} evictions",
                sch.batch_steps,
                sch.batch_occupancy(),
                sch.padded_slots,
                sch.batch_evictions,
            );
        }
        Ok(())
    }

    fn handle(&mut self, coord: &mut Coordinator, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(self.client_timeout))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut out = stream;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Ok(()); // client closed
            }
            let line = line.trim_end();
            if line.is_empty() {
                continue;
            }
            let resp = self.dispatch(coord, line);
            out.write_all(resp.to_string().as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()?;
        }
    }

    fn dispatch(&mut self, coord: &mut Coordinator, line: &str) -> Json {
        let ids = AtomicU64::new(self.next_id);
        let parsed = parse_line(line, &ids);
        self.next_id = ids.into_inner();
        match parsed {
            Ok(Parsed::Gen(req)) => match coord.generate(&req) {
                Ok(r) => gen_json(&r),
                Err(e) => err_json(&format!("{e:#}")),
            },
            Ok(Parsed::Stats) => {
                coord.sync_report();
                coord.report.to_json()
            }
            Err(msg) => err_json(msg),
        }
    }
}

/// Parse one protocol line; GEN draws a fresh request id from `ids`.
fn parse_line(line: &str, ids: &AtomicU64) -> Result<Parsed, &'static str> {
    let mut parts = line.splitn(4, ' ');
    match parts.next() {
        Some("GEN") => {
            let max_new = parts.next().and_then(|v| v.parse::<usize>().ok());
            let temp = parts.next().and_then(|v| v.parse::<f32>().ok());
            let prompt = parts.next().unwrap_or("");
            match (max_new, temp) {
                (Some(max_new), Some(temp)) if !prompt.is_empty() => {
                    let id = ids.fetch_add(1, Ordering::Relaxed);
                    Ok(Parsed::Gen(Request {
                        id,
                        prompt: prompt.to_string(),
                        max_new_tokens: max_new,
                        temperature: temp,
                    }))
                }
                _ => Err("usage: GEN <max_new_tokens> <temperature> <prompt>"),
            }
        }
        Some("STATS") => Ok(Parsed::Stats),
        _ => Err("unknown command (GEN | STATS)"),
    }
}

/// Per-connection reader thread: parse lines, forward commands to the
/// scheduler, write each routed response back in order.
fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<Event>,
    ids: Arc<AtomicU64>,
    timeout: Duration,
) -> Result<()> {
    stream.set_read_timeout(Some(timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    let result: Result<()> = loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break Ok(()), // client closed
            Ok(_) => {}
            Err(e) => break Err(e.into()),
        }
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        let resp = match parse_line(trimmed, &ids) {
            Ok(parsed) => {
                let (rtx, rrx) = mpsc::channel::<Json>();
                let cmd = match parsed {
                    Parsed::Gen(req) => Command::Gen { req, resp: rtx },
                    Parsed::Stats => Command::Stats { resp: rtx },
                };
                if tx.send(Event::Cmd(cmd)).is_err() {
                    err_json("server shutting down")
                } else {
                    rrx.recv().unwrap_or_else(|_| err_json("server shutting down"))
                }
            }
            Err(msg) => err_json(msg),
        };
        if out.write_all(resp.to_string().as_bytes()).is_err() {
            break Ok(());
        }
        let _ = out.write_all(b"\n");
        let _ = out.flush();
    };
    // always account the close so max_conns bookkeeping terminates
    let _ = tx.send(Event::ConnClosed);
    result
}

fn handle_event(
    coord: &mut Coordinator,
    ev: Event,
    responders: &mut HashMap<u64, mpsc::Sender<Json>>,
    closed: &mut usize,
) {
    match ev {
        Event::Cmd(Command::Gen { req, resp }) => {
            // admission control: a full bounded queue answers the client's
            // channel with a typed rejection right now — the overload
            // ladder's last stage, after precision and prefetch shed
            let id = req.id;
            match coord.try_submit(req) {
                Ok(()) => {
                    responders.insert(id, resp);
                }
                Err(e) => {
                    let _ = resp.send(err_json(&e.to_string()));
                }
            }
        }
        Event::Cmd(Command::Stats { resp }) => {
            coord.sync_report();
            let _ = resp.send(coord.report.to_json());
        }
        Event::Wake => {}
        Event::ConnClosed => *closed += 1,
    }
}

fn gen_json(r: &GenerationResult) -> Json {
    obj(vec![
        ("id", num(r.id as f64)),
        ("text", s(&r.text)),
        ("tokens", num(r.tokens.len() as f64)),
        ("prefill_s", num(r.metrics.prefill_time.as_secs_f64())),
        ("decode_tps", num(r.metrics.decode_tps())),
    ])
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", s(msg))])
}

/// Answer an over-capacity connect with a one-line rejection and close.
/// Runs on the acceptor thread; the write is best-effort (a client that
/// already vanished loses nothing).
fn reject_conn(mut stream: TcpStream, cap: usize) {
    let msg = err_json(&format!(
        "server at connection capacity ({cap} reader threads); retry later"
    ));
    let _ = stream.write_all(msg.to_string().as_bytes());
    let _ = stream.write_all(b"\n");
    let _ = stream.flush();
}

/// Minimal client helper (examples/tests). Goes through the shared
/// timeout/retry transport: the old `TcpStream::connect` + blocking
/// `read_line` pair hung forever against an unresponsive (accepting but
/// never answering) or half-dead server — now the connect and every read
/// carry deadlines and transient failures get a bounded retry with
/// backoff.
pub fn client_request(addr: &str, line: &str) -> Result<Json> {
    let resp = crate::remote::transport::request_line(
        addr,
        line,
        &crate::remote::RetryPolicy::default(),
    )?;
    Json::parse(resp.trim_end()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn err_json_shape() {
        let j = err_json("boom");
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "boom");
    }

    #[test]
    fn parse_line_roundtrip() {
        let ids = AtomicU64::new(7);
        match parse_line("GEN 8 0.5 hello there world", &ids).unwrap() {
            Parsed::Gen(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.max_new_tokens, 8);
                assert!((r.temperature - 0.5).abs() < 1e-6);
                assert_eq!(r.prompt, "hello there world");
            }
            _ => panic!("expected GEN"),
        }
        assert!(matches!(parse_line("STATS", &ids), Ok(Parsed::Stats)));
        assert!(parse_line("GEN 8", &ids).is_err());
        assert!(parse_line("NOPE", &ids).is_err());
        // prompt keeps internal spaces past the 4th split
        assert_eq!(ids.load(Ordering::Relaxed), 8);
    }
}
