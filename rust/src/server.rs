//! TCP serving front-end. Line protocol (one request per line):
//!
//!   GEN <max_new_tokens> <temperature> <prompt…>\n
//!   STATS\n
//!
//! responses are single JSON lines. The accept loop is single-threaded
//! (batch-1 FCFS serving per the paper's evaluation protocol); connection
//! handling never blocks generation indefinitely thanks to read timeouts.
//! tokio is not in the offline vendor set — std::net + the loader's own
//! scheduler thread cover the paper's concurrency needs (DESIGN.md).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{Coordinator, Request};
use crate::util::json::{num, obj, s, Json};

pub struct Server {
    listener: TcpListener,
    next_id: u64,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:7077"; port 0 picks a free port).
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self { listener, next_id: 1 })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve forever (or until `max_conns` connections have been handled,
    /// for tests/benches — `None` = unbounded).
    pub fn serve(&mut self, coord: &mut Coordinator, max_conns: Option<usize>) -> Result<()> {
        let mut handled = 0usize;
        loop {
            let (stream, _peer) = self.listener.accept()?;
            if let Err(e) = self.handle(coord, stream) {
                eprintln!("[server] connection error: {e:#}");
            }
            handled += 1;
            if let Some(m) = max_conns {
                if handled >= m {
                    return Ok(());
                }
            }
        }
    }

    fn handle(&mut self, coord: &mut Coordinator, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut out = stream;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Ok(()); // client closed
            }
            let line = line.trim_end();
            if line.is_empty() {
                continue;
            }
            let resp = self.dispatch(coord, line);
            out.write_all(resp.to_string().as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()?;
        }
    }

    fn dispatch(&mut self, coord: &mut Coordinator, line: &str) -> Json {
        let mut parts = line.splitn(4, ' ');
        match parts.next() {
            Some("GEN") => {
                let max_new = parts.next().and_then(|v| v.parse::<usize>().ok());
                let temp = parts.next().and_then(|v| v.parse::<f32>().ok());
                let prompt = parts.next().unwrap_or("");
                match (max_new, temp) {
                    (Some(max_new), Some(temp)) if !prompt.is_empty() => {
                        let id = self.next_id;
                        self.next_id += 1;
                        let req = Request {
                            id,
                            prompt: prompt.to_string(),
                            max_new_tokens: max_new,
                            temperature: temp,
                        };
                        match coord.generate(&req) {
                            Ok(r) => obj(vec![
                                ("id", num(r.id as f64)),
                                ("text", s(&r.text)),
                                ("tokens", num(r.tokens.len() as f64)),
                                ("prefill_s", num(r.metrics.prefill_time.as_secs_f64())),
                                ("decode_tps", num(r.metrics.decode_tps())),
                            ]),
                            Err(e) => err_json(&format!("{e:#}")),
                        }
                    }
                    _ => err_json("usage: GEN <max_new_tokens> <temperature> <prompt>"),
                }
            }
            Some("STATS") => {
                coord.sync_report();
                coord.report.to_json()
            }
            _ => err_json("unknown command (GEN | STATS)"),
        }
    }
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", s(msg))])
}

/// Minimal client helper (examples/tests).
pub fn client_request(addr: &str, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Json::parse(resp.trim_end()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn err_json_shape() {
        let j = err_json("boom");
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "boom");
    }
}
