//! The Dynamic Expert Loader (§3.2, Fig 6): Expert Scorer → Task Queue →
//! Expert Scheduler.
//!
//! The scheduler runs on its own thread and moves expert records from the
//! `ExpertStore` ("next-level memory") into reserved cache slots through
//! the bandwidth-throttled link. Faithful to the paper's memcpy
//! observation, a transfer in flight is never preempted: an on-demand task
//! arriving behind a started prefetch waits for it — the misprediction
//! penalty of Fig 9. On-demand tasks do jump ahead of *queued* (not yet
//! started) prefetches, and stale prefetches are dropped by generation.

pub mod scorer;

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CacheManager, Pool};
use crate::memory::ThrottledCopier;
use crate::metrics::LoaderStats;
use crate::model::ExpertStore;
use crate::{ExpertKey, Precision};

/// Why a load was requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    OnDemand,
    Prefetch,
}

/// One entry in the Task Queue.
#[derive(Debug, Clone)]
pub struct LoadTask {
    pub id: u64,
    pub key: ExpertKey,
    pub precision: Precision,
    pub pool: Pool,
    pub kind: TaskKind,
    /// prefetch generation (stale generations are dropped)
    pub gen: u64,
    /// layer being executed when the task was issued (for Eq. 3's l_i)
    pub current_layer: u32,
}

/// Two-lane FIFO: on-demand tasks always dequeue before prefetches.
#[derive(Default)]
struct TaskQueue {
    ondemand: std::collections::VecDeque<LoadTask>,
    prefetch: std::collections::VecDeque<LoadTask>,
    closed: bool,
}

struct Shared {
    queue: Mutex<TaskQueue>,
    queue_cv: Condvar,
    done: Mutex<HashSet<u64>>,
    done_cv: Condvar,
    prefetch_gen: AtomicU64,
    next_id: AtomicU64,
    stop: AtomicBool,
}

/// Handle to the loader: issue tasks, wait for completions.
pub struct ExpertLoader {
    shared: Arc<Shared>,
    pub cache: Arc<Mutex<CacheManager>>,
    pub stats: Arc<Mutex<LoaderStats>>,
    handle: Option<JoinHandle<()>>,
}

impl ExpertLoader {
    pub fn start(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(TaskQueue::default()),
            queue_cv: Condvar::new(),
            done: Mutex::new(HashSet::new()),
            done_cv: Condvar::new(),
            prefetch_gen: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
        });
        let stats = Arc::new(Mutex::new(LoaderStats::default()));
        let worker = Worker {
            shared: shared.clone(),
            store,
            cache: cache.clone(),
            copier,
            stats: stats.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("hobbit-expert-scheduler".into())
            .spawn(move || worker.run())
            .expect("spawn scheduler");
        Self { shared, cache, stats, handle: Some(handle) }
    }

    /// Enqueue a load; returns the task id to wait on (None if the expert
    /// is already resident or incoming, or no slot could be reserved).
    pub fn submit(
        &self,
        key: ExpertKey,
        precision: Precision,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
    ) -> Option<u64> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains(key, pool) {
                return None;
            }
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let gen = self.shared.prefetch_gen.load(Ordering::Relaxed);
        let task = LoadTask { id, key, precision, pool, kind, gen, current_layer };
        let mut q = self.shared.queue.lock().unwrap();
        match kind {
            TaskKind::OnDemand => q.ondemand.push_back(task),
            TaskKind::Prefetch => q.prefetch.push_back(task),
        }
        drop(q);
        self.shared.queue_cv.notify_one();
        Some(id)
    }

    /// Invalidate all queued (unstarted) prefetches from earlier tokens.
    pub fn bump_prefetch_generation(&self) {
        self.shared.prefetch_gen.fetch_add(1, Ordering::Relaxed);
    }

    /// Block until every id in `ids` has completed. Returns wait time.
    pub fn wait(&self, ids: &[u64]) -> Duration {
        let t0 = Instant::now();
        let mut done = self.shared.done.lock().unwrap();
        loop {
            if ids.iter().all(|id| done.contains(id)) {
                for id in ids {
                    done.remove(id);
                }
                return t0.elapsed();
            }
            done = self.shared.done_cv.wait(done).unwrap();
        }
    }

    /// True when both task lanes are empty and nothing is mid-transfer
    /// (used by drains in tests/benches).
    pub fn is_idle(&self) -> bool {
        let q = self.shared.queue.lock().unwrap();
        q.ondemand.is_empty() && q.prefetch.is_empty()
    }
}

impl Drop for ExpertLoader {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.queue_cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Worker {
    shared: Arc<Shared>,
    store: Arc<ExpertStore>,
    cache: Arc<Mutex<CacheManager>>,
    copier: Arc<ThrottledCopier>,
    stats: Arc<Mutex<LoaderStats>>,
}

impl Worker {
    fn run(&self) {
        loop {
            let task = {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if self.shared.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    // on-demand lane first; prefetch lane drops stale gens
                    if let Some(t) = q.ondemand.pop_front() {
                        break t;
                    }
                    let cur_gen = self.shared.prefetch_gen.load(Ordering::Relaxed);
                    while let Some(t) = q.prefetch.front() {
                        if t.gen < cur_gen {
                            let stale = q.prefetch.pop_front().unwrap();
                            // report as done so no waiter hangs
                            self.mark_done(stale.id);
                        } else {
                            break;
                        }
                    }
                    if let Some(t) = q.prefetch.pop_front() {
                        break t;
                    }
                    if q.closed {
                        return;
                    }
                    q = self.shared.queue_cv.wait(q).unwrap();
                }
            };
            self.execute(task);
        }
    }

    fn execute(&self, task: LoadTask) {
        // reserve a destination slot
        let reservation = {
            let mut cache = self.cache.lock().unwrap();
            cache.reserve(task.key, task.pool, task.current_layer)
        };
        let Some(res) = reservation else {
            // already resident/incoming, or no evictable slot: done
            self.mark_done(task.id);
            return;
        };
        let record = self.store.record(task.key, task.precision);
        {
            // per-slot lock: the engine can read other slots meanwhile;
            // the transfer itself is non-preemptible (cudaMemcpy model)
            let mut buf = res.buffer.lock().unwrap();
            debug_assert_eq!(buf.len(), record.len(), "slot/record size");
            self.copier.transfer(record, &mut buf);
        }
        {
            let mut cache = self.cache.lock().unwrap();
            cache.commit(task.key, task.pool);
        }
        {
            let mut st = self.stats.lock().unwrap();
            let slot = crate::config::precision_slot(task.precision);
            match task.kind {
                TaskKind::OnDemand => st.ondemand_loads[slot] += 1,
                TaskKind::Prefetch => st.prefetch_loads[slot] += 1,
            }
            st.bytes_loaded += record.len() as u64;
        }
        self.mark_done(task.id);
    }

    fn mark_done(&self, id: u64) {
        let mut done = self.shared.done.lock().unwrap();
        done.insert(id);
        drop(done);
        self.shared.done_cv.notify_all();
    }
}
