//! The Dynamic Expert Loader (§3.2, Fig 6): Expert Scorer → Task Queue →
//! Expert Scheduler.
//!
//! The scheduler runs on its own thread and moves expert records from the
//! `ExpertStore` ("next-level memory") into reserved cache slots through
//! the bandwidth-throttled link. Faithful to the paper's memcpy
//! observation, a transfer in flight is never preempted: an on-demand task
//! arriving behind a started prefetch waits for it — the misprediction
//! penalty of Fig 9. On-demand tasks do jump ahead of *queued* (not yet
//! started) prefetches — [`ExpertLoader::promote_to_ondemand`] moves a
//! queued prefetch into the priority lane when an on-demand request joins
//! it — and stale prefetches are dropped by generation.
//!
//! Prefetch generations are **scoped**: each live sequence bumps its own
//! entry in the [`GenTable`] (scope = sequence id; scope 0 is the global
//! batch-1 stream), so one sequence's token advance no longer invalidates
//! other sequences' queued prefetches. A retired scope is marked
//! `u64::MAX`, which makes every queued prefetch of that sequence stale;
//! the worker garbage-collects retired entries when its prefetch lane
//! drains.
//!
//! Completion can be consumed three ways: blocking ([`ExpertLoader::wait`]),
//! polling ([`ExpertLoader::try_wait`]), or pushed ([`ExpertLoader::on_complete`]
//! per-task callbacks). The residency facade (`residency::ExpertResidency`)
//! is the intended client of the push path: it registers a *consuming*
//! callback per task so the done-set stays bounded without anyone calling
//! `wait`.

pub mod scorer;

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CacheManager, Pool};
use crate::memory::ThrottledCopier;
use crate::metrics::LoaderStats;
use crate::model::ExpertStore;
use crate::{ExpertKey, Precision};

/// Why a load was requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    OnDemand,
    Prefetch,
}

/// The global (batch-1) prefetch-generation scope; live sequences use
/// their sequence id.
pub const GLOBAL_SCOPE: u64 = 0;

/// Per-scope prefetch generation table, shared between the submit path,
/// the worker's staleness check, and sequence retirement (`u64::MAX`
/// marks a retired scope).
pub type GenTable = Arc<Mutex<HashMap<u64, u64>>>;

/// One entry in the Task Queue.
#[derive(Debug, Clone)]
pub struct LoadTask {
    pub id: u64,
    pub key: ExpertKey,
    pub precision: Precision,
    pub pool: Pool,
    pub kind: TaskKind,
    /// prefetch generation (stale generations are dropped)
    pub gen: u64,
    /// generation scope this task was issued under (sequence id; 0 = global)
    pub scope: u64,
    /// layer being executed when the task was issued (for Eq. 3's l_i)
    pub current_layer: u32,
}

/// Two-lane FIFO: on-demand tasks always dequeue before prefetches.
#[derive(Default)]
struct TaskQueue {
    ondemand: std::collections::VecDeque<LoadTask>,
    prefetch: std::collections::VecDeque<LoadTask>,
    closed: bool,
}

/// Completion callback: invoked once with the task id when the task
/// finishes (successfully, deduped, or dropped as stale). Callbacks must be
/// cheap and must not re-enter the loader's callback registration (they run
/// on the scheduler thread).
type Callback = Box<dyn FnOnce(u64) + Send + 'static>;

struct Shared {
    queue: Mutex<TaskQueue>,
    queue_cv: Condvar,
    done: Mutex<HashSet<u64>>,
    done_cv: Condvar,
    /// id -> (callback, consume-done-entry-after-firing)
    callbacks: Mutex<HashMap<u64, (Callback, bool)>>,
    gens: GenTable,
    next_id: AtomicU64,
    stop: AtomicBool,
    /// tasks popped from a lane but not yet completed (mid-transfer)
    in_flight: AtomicUsize,
}

impl Shared {
    /// Publish completion BEFORE draining the callback: `on_complete`
    /// re-checks `done` after inserting, so whichever side loses the race
    /// still finds (exactly one of) the entry to fire. The callbacks lock
    /// is NOT held while the callback runs.
    fn complete(&self, id: u64) {
        {
            let mut done = self.done.lock().unwrap();
            done.insert(id);
        }
        self.done_cv.notify_all();
        let cb = self.callbacks.lock().unwrap().remove(&id);
        if let Some((cb, consume)) = cb {
            cb(id);
            if consume {
                self.done.lock().unwrap().remove(&id);
            }
        }
    }
}

/// Handle to the loader: issue tasks, wait for completions.
pub struct ExpertLoader {
    shared: Arc<Shared>,
    pub cache: Arc<Mutex<CacheManager>>,
    pub stats: Arc<Mutex<LoaderStats>>,
    handle: Option<JoinHandle<()>>,
}

impl ExpertLoader {
    pub fn start(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(TaskQueue::default()),
            queue_cv: Condvar::new(),
            done: Mutex::new(HashSet::new()),
            done_cv: Condvar::new(),
            callbacks: Mutex::new(HashMap::new()),
            gens: Arc::new(Mutex::new(HashMap::new())),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
        });
        let stats = Arc::new(Mutex::new(LoaderStats::default()));
        let worker = Worker {
            shared: shared.clone(),
            store,
            cache: cache.clone(),
            copier,
            stats: stats.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("hobbit-expert-scheduler".into())
            .spawn(move || worker.run())
            .expect("spawn scheduler");
        Self { shared, cache, stats, handle: Some(handle) }
    }

    /// Enqueue a load in the global generation scope; returns the task id
    /// to wait on (None if the expert is already resident or incoming).
    pub fn submit(
        &self,
        key: ExpertKey,
        precision: Precision,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
    ) -> Option<u64> {
        self.submit_scoped(key, precision, pool, kind, current_layer, GLOBAL_SCOPE)
    }

    /// Enqueue a load under a specific prefetch-generation scope (the
    /// issuing sequence's id; [`GLOBAL_SCOPE`] for the batch-1 path).
    pub fn submit_scoped(
        &self,
        key: ExpertKey,
        precision: Precision,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
        scope: u64,
    ) -> Option<u64> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains(key, pool) {
                return None;
            }
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let gen = {
            let gens = self.shared.gens.lock().unwrap();
            gens.get(&scope).copied().unwrap_or(0)
        };
        let task = LoadTask { id, key, precision, pool, kind, gen, scope, current_layer };
        let mut q = self.shared.queue.lock().unwrap();
        match kind {
            TaskKind::OnDemand => q.ondemand.push_back(task),
            TaskKind::Prefetch => q.prefetch.push_back(task),
        }
        drop(q);
        self.shared.queue_cv.notify_one();
        Some(id)
    }

    /// Invalidate all queued (unstarted) prefetches of the global scope.
    pub fn bump_prefetch_generation(&self) {
        self.bump_prefetch_generation_for(GLOBAL_SCOPE);
    }

    /// Invalidate all queued (unstarted) prefetches issued under `scope`
    /// by earlier tokens of that sequence. Other scopes are unaffected.
    pub fn bump_prefetch_generation_for(&self, scope: u64) {
        let mut gens = self.shared.gens.lock().unwrap();
        let e = gens.entry(scope).or_insert(0);
        *e = e.saturating_add(1);
    }

    /// Shared handle to the per-scope generation table (sequence
    /// retirement marks its scope `u64::MAX` through this).
    pub fn gen_table(&self) -> GenTable {
        self.shared.gens.clone()
    }

    /// Re-stamp a *queued* prefetch task with `scope`'s current generation
    /// (a fresh prefetch request joined it). Without this, a re-planned
    /// prefetch that joins its own previous-token task — now stale after
    /// the planner's generation bump — would be silently dropped instead
    /// of loaded. Returns false when the task already started or
    /// completed (the join then resolves off the real transfer).
    pub fn refresh_prefetch(&self, id: u64, scope: u64) -> bool {
        let cur = {
            let gens = self.shared.gens.lock().unwrap();
            gens.get(&scope).copied().unwrap_or(0)
        };
        let mut q = self.shared.queue.lock().unwrap();
        if let Some(t) = q.prefetch.iter_mut().find(|t| t.id == id) {
            t.scope = scope;
            t.gen = cur;
            true
        } else {
            false
        }
    }

    /// Move a *queued* prefetch task into the on-demand lane (an on-demand
    /// request joined it). Returns false when the task already started or
    /// completed — a started transfer is non-preemptible (cudaMemcpy
    /// semantics), so the joiner simply waits it out.
    pub fn promote_to_ondemand(&self, id: u64) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if let Some(pos) = q.prefetch.iter().position(|t| t.id == id) {
            let mut t = q.prefetch.remove(pos).expect("position valid");
            t.kind = TaskKind::OnDemand;
            q.ondemand.push_back(t);
            drop(q);
            self.shared.queue_cv.notify_one();
            true
        } else {
            false
        }
    }

    /// Block until every id in `ids` has completed. Returns wait time.
    pub fn wait(&self, ids: &[u64]) -> Duration {
        let t0 = Instant::now();
        let mut done = self.shared.done.lock().unwrap();
        loop {
            if ids.iter().all(|id| done.contains(id)) {
                for id in ids {
                    done.remove(id);
                }
                return t0.elapsed();
            }
            done = self.shared.done_cv.wait(done).unwrap();
        }
    }

    /// Non-blocking completion poll: true when every id in `ids` has
    /// completed (the ids are then consumed, exactly like [`Self::wait`]).
    /// False leaves all ids pending so the caller can poll again.
    pub fn try_wait(&self, ids: &[u64]) -> bool {
        if ids.is_empty() {
            return true;
        }
        let mut done = self.shared.done.lock().unwrap();
        if ids.iter().all(|id| done.contains(id)) {
            for id in ids {
                done.remove(id);
            }
            true
        } else {
            false
        }
    }

    /// Non-consuming completion probe: true once `id` has completed and
    /// has not yet been consumed by `wait`/`try_wait`.
    pub fn is_done(&self, id: u64) -> bool {
        self.shared.done.lock().unwrap().contains(&id)
    }

    /// Register a completion callback for task `id`; it fires exactly once,
    /// on the scheduler thread when the task completes, or immediately on
    /// the caller thread if the task already completed. Register before the
    /// id is consumed by `wait`/`try_wait` — a consumed id never fires.
    /// Re-registering replaces (and drops) the previous callback.
    pub fn on_complete<F: FnOnce(u64) + Send + 'static>(&self, id: u64, cb: F) {
        self.register_callback(id, Box::new(cb), false);
    }

    /// Like [`Self::on_complete`], but the done-set entry is consumed when
    /// the callback fires, so completion state does not accumulate for ids
    /// nobody will `wait` on (the residency facade's contract).
    pub fn on_complete_consume<F: FnOnce(u64) + Send + 'static>(&self, id: u64, cb: F) {
        self.register_callback(id, Box::new(cb), true);
    }

    fn register_callback(&self, id: u64, cb: Callback, consume: bool) {
        self.shared.callbacks.lock().unwrap().insert(id, (cb, consume));
        // the worker publishes `done` before draining callbacks, so if the
        // task raced past us we can still claim (or find gone) our entry
        let already = self.shared.done.lock().unwrap().contains(&id);
        if already {
            let cb = self.shared.callbacks.lock().unwrap().remove(&id);
            if let Some((cb, consume)) = cb {
                cb(id);
                if consume {
                    self.shared.done.lock().unwrap().remove(&id);
                }
            }
        }
    }

    /// True when both task lanes are empty and nothing is mid-transfer
    /// (used by drains in tests/benches).
    pub fn is_idle(&self) -> bool {
        let q = self.shared.queue.lock().unwrap();
        q.ondemand.is_empty()
            && q.prefetch.is_empty()
            && self.shared.in_flight.load(Ordering::SeqCst) == 0
    }
}

impl Drop for ExpertLoader {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.queue_cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Worker {
    shared: Arc<Shared>,
    store: Arc<ExpertStore>,
    cache: Arc<Mutex<CacheManager>>,
    copier: Arc<ThrottledCopier>,
    stats: Arc<Mutex<LoaderStats>>,
}

impl Worker {
    fn run(&self) {
        loop {
            let task = {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if self.shared.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    // on-demand lane first; prefetch lane drops stale gens.
                    // `in_flight` is raised inside the queue critical
                    // section so `is_idle` never sees a popped-but-running
                    // task as idle.
                    if let Some(t) = q.ondemand.pop_front() {
                        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                        break t;
                    }
                    let mut stale: Vec<u64> = Vec::new();
                    {
                        let mut gens = self.shared.gens.lock().unwrap();
                        while let Some(t) = q.prefetch.front() {
                            let cur = gens.get(&t.scope).copied().unwrap_or(0);
                            if t.gen < cur {
                                let dropped = q.prefetch.pop_front().unwrap();
                                stale.push(dropped.id);
                            } else {
                                break;
                            }
                        }
                        // retired scopes (u64::MAX) are only referenced by
                        // queued prefetches; an empty lane proves none
                        // remain, so GC here — a busy on-demand lane must
                        // not starve the table (one entry per retired
                        // sequence otherwise accumulates forever)
                        if q.prefetch.is_empty() {
                            gens.retain(|_, g| *g != u64::MAX);
                        }
                    }
                    if !stale.is_empty() {
                        // report as done so no waiter hangs. Completion
                        // callbacks may take locks of their own (the
                        // residency wait-set), so fire them OUTSIDE the
                        // queue critical section.
                        drop(q);
                        for id in stale {
                            self.shared.complete(id);
                        }
                        q = self.shared.queue.lock().unwrap();
                        continue;
                    }
                    if let Some(t) = q.prefetch.pop_front() {
                        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                        break t;
                    }
                    if q.closed {
                        return;
                    }
                    q = self.shared.queue_cv.wait(q).unwrap();
                }
            };
            let id = task.id;
            self.execute(task);
            // transfer fully committed: drop in-flight before waking
            // waiters so a returned `wait` implies `is_idle` (absent new
            // submissions)
            self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.shared.complete(id);
        }
    }

    fn execute(&self, task: LoadTask) {
        // reserve a destination slot
        let reservation = {
            let mut cache = self.cache.lock().unwrap();
            cache.reserve(task.key, task.pool, task.current_layer)
        };
        let Some(res) = reservation else {
            // already resident/incoming, or no evictable slot: nothing to
            // copy (run() marks the task done)
            return;
        };
        let record = self.store.record(task.key, task.precision);
        {
            // per-slot lock: the engine can read other slots meanwhile;
            // the transfer itself is non-preemptible (cudaMemcpy model)
            let mut buf = res.buffer.lock().unwrap();
            debug_assert_eq!(buf.len(), record.len(), "slot/record size");
            self.copier.transfer(record, &mut buf);
        }
        {
            let mut cache = self.cache.lock().unwrap();
            cache.commit(task.key, task.pool);
        }
        {
            let mut st = self.stats.lock().unwrap();
            let slot = crate::config::precision_slot(task.precision);
            match task.kind {
                TaskKind::OnDemand => st.ondemand_loads[slot] += 1,
                TaskKind::Prefetch => st.prefetch_loads[slot] += 1,
            }
            st.bytes_loaded += record.len() as u64;
        }
    }
}
