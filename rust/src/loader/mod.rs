//! The Dynamic Expert Loader (§3.2, Fig 6): Expert Scorer → Task Queue →
//! Expert Scheduler — since the transfer pipeline, a **chunked,
//! multi-lane, bandwidth-arbitrated** scheduler.
//!
//! `IoConfig::lanes` worker threads move expert records from the
//! `ExpertStore` ("next-level memory") into reserved cache slots through
//! the shared link (`memory::LinkArbiter` splits `bytes_per_s` by
//! weighted fair share, so total bandwidth is conserved and on-demand
//! chunks outrank prefetch chunks 4:1). Each task executes as a sequence
//! of `IoConfig::chunk_bytes` chunks with a **preemption checkpoint**
//! between chunks:
//!
//! * a prefetch task *yields* mid-transfer when the on-demand lane is
//!   non-empty — partial progress is kept (the resume offset travels with
//!   the task, the slot stays `Loading`), and the task resumes from its
//!   offset once the on-demand work drains;
//! * [`promote_to_ondemand`](LoaderIo::promote_to_ondemand) now succeeds
//!   for *started* prefetches too: the running task's remaining chunks are
//!   re-prioritized to the on-demand weight at the next checkpoint.
//!
//! The paper modeled a started transfer as non-preemptible (§3.3, Fig 9),
//! so a mispredicted prefetch in flight delayed every on-demand miss
//! behind it by up to a full expert transfer; chunking turns that penalty
//! into O(one chunk). A *chunk* is still non-preemptible (one DMA call).
//!
//! Prefetch generations are **scoped**: each live sequence bumps its own
//! entry in the [`GenTable`] (scope = sequence id; scope 0 is the global
//! batch-1 stream), so one sequence's token advance no longer invalidates
//! other sequences' queued prefetches. A retired scope is marked
//! `u64::MAX`, which makes every queued prefetch of that sequence stale;
//! the workers garbage-collect retired entries when the prefetch lane
//! drains. Dropping a stale *preempted* prefetch aborts its reservation,
//! so a partially filled slot can never leak as `Loading` forever (and is
//! never committed).
//!
//! Completion carries a [`LoadOutcome`]: `Fulfilled` (bytes committed, or
//! already resident/incoming), `NoSlot` (every candidate slot pinned or
//! mid-load — nothing was copied, the expert is NOT resident; counted in
//! `LoaderStats::noslot_drops`), or `Stale` (dropped prefetch). It can be
//! consumed three ways: blocking ([`LoaderIo::wait`]), polling
//! ([`LoaderIo::try_wait`]), or pushed ([`LoaderIo::on_complete`] /
//! [`LoaderIo::on_complete_consume_outcome`] per-task callbacks). The
//! residency facade is the intended client of the push path: it registers
//! a *consuming* outcome callback per task so the done-set stays bounded,
//! and re-acquires on `NoSlot` instead of letting ticket waiters resume
//! believing the expert resident.

pub mod scorer;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CacheManager, CommitOutcome, Pool, UpgradeCommit};
use crate::config::IoConfig;
use crate::memory::{ThrottledCopier, ONDEMAND_WEIGHT, PREFETCH_WEIGHT};
use crate::metrics::LoaderStats;
use crate::model::ExpertStore;
use crate::remote::TieredStore;
use crate::{ExpertKey, Precision};

/// Why a load was requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    OnDemand,
    Prefetch,
}

/// How a load task completed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOutcome {
    /// bytes committed into the cache, or the expert was already
    /// resident/incoming when the task ran
    Fulfilled,
    /// every candidate slot was pinned or mid-load: nothing was copied and
    /// the expert is NOT resident — waiters must re-acquire or bypass
    NoSlot,
    /// dropped as a stale prefetch (generation bump / retired scope)
    Stale,
    /// the landed bytes failed their manifest checksum at commit: the slot
    /// was quarantined (scrubbed and freed, never `Ready`), the expert is
    /// NOT resident — waiters re-acquire so a clean copy is re-fetched
    Corrupt,
}

/// How many times an upgrade continuation whose staged record failed its
/// checksum is re-fetched before the upgrade is abandoned (the narrower
/// resident tier stays valid either way).
const MAX_INTEGRITY_HEALS: u32 = 2;

/// The global (batch-1) prefetch-generation scope; live sequences use
/// their sequence id.
pub const GLOBAL_SCOPE: u64 = 0;

/// Per-scope prefetch generation table, shared between the submit path,
/// the workers' staleness check, and sequence retirement (`u64::MAX`
/// marks a retired scope).
pub type GenTable = Arc<Mutex<HashMap<u64, u64>>>;

/// Partial progress of a preempted chunked transfer: the resume offset
/// travels with the task, and holding the slot buffer keeps the
/// reservation's destination stable while the task waits to resume (the
/// slot itself stays `Loading` — it is only committed once `offset`
/// reaches the record length).
#[derive(Debug, Clone)]
struct Resume {
    offset: usize,
    buffer: Arc<Mutex<Vec<u8>>>,
}

/// One entry in the Task Queue.
#[derive(Debug, Clone)]
pub struct LoadTask {
    pub id: u64,
    pub key: ExpertKey,
    pub precision: Precision,
    pub pool: Pool,
    pub kind: TaskKind,
    /// prefetch generation (stale generations are dropped)
    pub gen: u64,
    /// generation scope this task was issued under (sequence id; 0 = global)
    pub scope: u64,
    /// layer being executed when the task was issued (for Eq. 3's l_i)
    pub current_layer: u32,
    /// staged (progressive) load: once `precision` commits and the ticket
    /// resolves, stream this precision's record as a background
    /// continuation on the prefetch lane and upgrade the slot in place
    pub upgrade_to: Option<Precision>,
    /// this task IS an upgrade continuation: the slot is already `Ready`
    /// at a narrower tier, bytes stream into private staging memory and
    /// land via `CacheManager::commit_upgrade`. Exempt from prefetch
    /// staleness (dropping one only costs quality, but generations bump
    /// every token — upgrades would otherwise never run); nobody waits on
    /// it, so it completes without a done-set entry.
    upgrade: bool,
    /// integrity heal attempts spent on this upgrade continuation
    /// (bounded by [`MAX_INTEGRITY_HEALS`])
    heal: u32,
    /// pending transfer-flip fault (rng seed), drawn at transfer start and
    /// applied at commit so it survives preemption yields
    xfer_flip: Option<u64>,
    /// partial progress of a preempted transfer (None = not yet started)
    resume: Option<Resume>,
    /// submit instant (per-kind time-to-ready accounting). Reset when a
    /// prefetch is promoted, so `ondemand_ready` measures the joiner's
    /// wait — not the prefetch's whole speculative lifetime.
    submitted: Instant,
}

/// Per-running-task control block, guarded by the queue mutex so
/// [`LoaderIo::promote_to_ondemand`] and the executing worker's
/// checkpoint reads are atomic with queue membership.
#[derive(Default)]
struct RunCtl {
    /// an on-demand join asked for the remaining chunks at priority
    promote: bool,
}

/// Two-lane FIFO plus the running set: on-demand tasks always dequeue
/// before prefetches.
#[derive(Default)]
struct TaskQueue {
    ondemand: VecDeque<LoadTask>,
    prefetch: VecDeque<LoadTask>,
    /// tasks currently executing on a lane
    running: HashMap<u64, RunCtl>,
    closed: bool,
}

/// Completion callback: invoked once with the task id and outcome when
/// the task finishes (fulfilled, deduped, slotless, or dropped as stale).
/// Callbacks run on a lane thread with no loader lock held, so they may
/// submit follow-up tasks and register new callbacks — but must stay
/// cheap (they sit on a transfer lane's critical path).
type Callback = Box<dyn FnOnce(u64, LoadOutcome) + Send + 'static>;

struct Shared {
    queue: Mutex<TaskQueue>,
    queue_cv: Condvar,
    done: Mutex<HashMap<u64, LoadOutcome>>,
    done_cv: Condvar,
    /// id -> (callback, consume-done-entry-after-firing)
    callbacks: Mutex<HashMap<u64, (Callback, bool)>>,
    gens: GenTable,
    next_id: AtomicU64,
    stop: AtomicBool,
    /// tasks popped from a lane but not yet completed (mid-transfer)
    in_flight: AtomicUsize,
}

impl Shared {
    /// Publish completion BEFORE draining the callback: `on_complete`
    /// re-checks `done` after inserting, so whichever side loses the race
    /// still finds (exactly one of) the entry to fire. The callbacks lock
    /// is NOT held while the callback runs.
    fn complete(&self, id: u64, outcome: LoadOutcome) {
        {
            let mut done = self.done.lock().unwrap();
            done.insert(id, outcome);
        }
        self.done_cv.notify_all();
        let cb = self.callbacks.lock().unwrap().remove(&id);
        if let Some((cb, consume)) = cb {
            cb(id, outcome);
            if consume {
                self.done.lock().unwrap().remove(&id);
            }
        }
    }
}

/// Cloneable handle to the loader's submit/wait/callback surface. The
/// residency facade keeps one inside completion callbacks so a `NoSlot`
/// completion can re-acquire without owning the [`ExpertLoader`] (which
/// also owns the lane threads).
#[derive(Clone)]
pub struct LoaderIo {
    shared: Arc<Shared>,
    cache: Arc<Mutex<CacheManager>>,
    pub stats: Arc<Mutex<LoaderStats>>,
}

impl LoaderIo {
    /// Enqueue a load in the global generation scope; returns the task id
    /// to wait on (None if the expert is already resident or incoming).
    pub fn submit(
        &self,
        key: ExpertKey,
        precision: Precision,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
    ) -> Option<u64> {
        self.submit_scoped(key, precision, pool, kind, current_layer, GLOBAL_SCOPE)
    }

    /// Enqueue a load under a specific prefetch-generation scope (the
    /// issuing sequence's id; [`GLOBAL_SCOPE`] for the batch-1 path).
    pub fn submit_scoped(
        &self,
        key: ExpertKey,
        precision: Precision,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
        scope: u64,
    ) -> Option<u64> {
        self.submit_staged(key, precision, None, pool, kind, current_layer, scope)
    }

    /// Enqueue a *staged* (progressive) load: the `precision` record
    /// streams first and commits the slot usable at that tier; when
    /// `upgrade_to` is `Some`, the wider record then streams as a
    /// background continuation on the prefetch lane and upgrades the slot
    /// in place. `submit_scoped` is the `upgrade_to: None` special case.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_staged(
        &self,
        key: ExpertKey,
        precision: Precision,
        upgrade_to: Option<Precision>,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
        scope: u64,
    ) -> Option<u64> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains(key, pool) {
                return None;
            }
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let gen = {
            let gens = self.shared.gens.lock().unwrap();
            gens.get(&scope).copied().unwrap_or(0)
        };
        let task = LoadTask {
            id,
            key,
            precision,
            pool,
            kind,
            gen,
            scope,
            current_layer,
            upgrade_to,
            upgrade: false,
            heal: 0,
            xfer_flip: None,
            resume: None,
            submitted: Instant::now(),
        };
        let mut q = self.shared.queue.lock().unwrap();
        match kind {
            TaskKind::OnDemand => q.ondemand.push_back(task),
            TaskKind::Prefetch => q.prefetch.push_back(task),
        }
        drop(q);
        self.shared.queue_cv.notify_one();
        Some(id)
    }

    /// Invalidate all queued (unstarted) prefetches of the global scope.
    pub fn bump_prefetch_generation(&self) {
        self.bump_prefetch_generation_for(GLOBAL_SCOPE);
    }

    /// Invalidate all queued (unstarted) prefetches issued under `scope`
    /// by earlier tokens of that sequence. Other scopes are unaffected.
    pub fn bump_prefetch_generation_for(&self, scope: u64) {
        let mut gens = self.shared.gens.lock().unwrap();
        let e = gens.entry(scope).or_insert(0);
        *e = e.saturating_add(1);
    }

    /// Shared handle to the per-scope generation table (sequence
    /// retirement marks its scope `u64::MAX` through this).
    pub fn gen_table(&self) -> GenTable {
        self.shared.gens.clone()
    }

    /// Re-stamp a *queued* prefetch task with `scope`'s current generation
    /// (a fresh prefetch request joined it). Without this, a re-planned
    /// prefetch that joins its own previous-token task — now stale after
    /// the planner's generation bump — would be silently dropped instead
    /// of loaded. A preempted (partially transferred) task waiting in the
    /// lane is re-stamped the same way. Returns false when the task is
    /// currently executing or completed (the join then resolves off the
    /// real transfer — running tasks never re-check their generation).
    pub fn refresh_prefetch(&self, id: u64, scope: u64) -> bool {
        let cur = {
            let gens = self.shared.gens.lock().unwrap();
            gens.get(&scope).copied().unwrap_or(0)
        };
        let mut q = self.shared.queue.lock().unwrap();
        if let Some(t) = q.prefetch.iter_mut().find(|t| t.id == id) {
            t.scope = scope;
            t.gen = cur;
            true
        } else {
            false
        }
    }

    /// Re-prioritize a prefetch an on-demand request joined. A *queued*
    /// task (preempted-partial included) moves into the on-demand lane; a
    /// *started* task has its remaining chunks re-weighted to on-demand
    /// priority at the next chunk checkpoint — the paper's non-preemptible
    /// transfer (Fig 9) used to make this impossible, so the joiner ate
    /// the whole in-flight transfer. Returns false only when the task
    /// already completed.
    pub fn promote_to_ondemand(&self, id: u64) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if let Some(pos) = q.prefetch.iter().position(|t| t.id == id) {
            let mut t = q.prefetch.remove(pos).expect("position valid");
            t.kind = TaskKind::OnDemand;
            t.submitted = Instant::now();
            q.ondemand.push_back(t);
            drop(q);
            self.shared.queue_cv.notify_one();
            return true;
        }
        if q.ondemand.iter().any(|t| t.id == id) {
            return true; // already at priority
        }
        if let Some(ctl) = q.running.get_mut(&id) {
            ctl.promote = true;
            return true;
        }
        false
    }

    /// Block until every id in `ids` has completed. Returns wait time.
    pub fn wait(&self, ids: &[u64]) -> Duration {
        let t0 = Instant::now();
        let mut done = self.shared.done.lock().unwrap();
        loop {
            if ids.iter().all(|id| done.contains_key(id)) {
                for id in ids {
                    done.remove(id);
                }
                return t0.elapsed();
            }
            done = self.shared.done_cv.wait(done).unwrap();
        }
    }

    /// Non-blocking completion poll: true when every id in `ids` has
    /// completed (the ids are then consumed, exactly like [`Self::wait`]).
    /// False leaves all ids pending so the caller can poll again.
    pub fn try_wait(&self, ids: &[u64]) -> bool {
        if ids.is_empty() {
            return true;
        }
        let mut done = self.shared.done.lock().unwrap();
        if ids.iter().all(|id| done.contains_key(id)) {
            for id in ids {
                done.remove(id);
            }
            true
        } else {
            false
        }
    }

    /// Non-consuming completion probe: true once `id` has completed and
    /// has not yet been consumed by `wait`/`try_wait`.
    pub fn is_done(&self, id: u64) -> bool {
        self.shared.done.lock().unwrap().contains_key(&id)
    }

    /// Register a completion callback for task `id`; it fires exactly once,
    /// on a lane thread when the task completes, or immediately on the
    /// caller thread if the task already completed. Register before the
    /// id is consumed by `wait`/`try_wait` — a consumed id never fires.
    /// Re-registering replaces (and drops) the previous callback.
    pub fn on_complete<F: FnOnce(u64) + Send + 'static>(&self, id: u64, cb: F) {
        self.register_callback(id, Box::new(move |id: u64, _: LoadOutcome| cb(id)), false);
    }

    /// Like [`Self::on_complete`], but the done-set entry is consumed when
    /// the callback fires, so completion state does not accumulate for ids
    /// nobody will `wait` on (the residency facade's contract).
    pub fn on_complete_consume<F: FnOnce(u64) + Send + 'static>(&self, id: u64, cb: F) {
        self.register_callback(id, Box::new(move |id: u64, _: LoadOutcome| cb(id)), true);
    }

    /// Consuming completion callback that also receives the
    /// [`LoadOutcome`] — how the residency facade tells a fulfilled load
    /// from a `NoSlot` drop it must re-acquire.
    pub fn on_complete_consume_outcome<F: FnOnce(u64, LoadOutcome) + Send + 'static>(
        &self,
        id: u64,
        cb: F,
    ) {
        self.register_callback(id, Box::new(cb), true);
    }

    fn register_callback(&self, id: u64, cb: Callback, consume: bool) {
        self.shared.callbacks.lock().unwrap().insert(id, (cb, consume));
        // the worker publishes `done` before draining callbacks, so if the
        // task raced past us we can still claim (or find gone) our entry
        let already = self.shared.done.lock().unwrap().get(&id).copied();
        if let Some(outcome) = already {
            let cb = self.shared.callbacks.lock().unwrap().remove(&id);
            if let Some((cb, consume)) = cb {
                cb(id, outcome);
                if consume {
                    self.shared.done.lock().unwrap().remove(&id);
                }
            }
        }
    }

    /// True when both task lanes are empty and nothing is mid-transfer
    /// (used by drains in tests/benches).
    pub fn is_idle(&self) -> bool {
        let q = self.shared.queue.lock().unwrap();
        q.ondemand.is_empty()
            && q.prefetch.is_empty()
            && self.shared.in_flight.load(Ordering::SeqCst) == 0
    }
}

/// Handle to the loader: issue tasks, wait for completions. Owns the lane
/// threads and derefs to the cloneable [`LoaderIo`] surface, so every
/// submit/wait/callback method is reachable directly on the loader.
pub struct ExpertLoader {
    io: LoaderIo,
    pub cache: Arc<Mutex<CacheManager>>,
    pub stats: Arc<Mutex<LoaderStats>>,
    handles: Vec<JoinHandle<()>>,
}

impl std::ops::Deref for ExpertLoader {
    type Target = LoaderIo;

    fn deref(&self) -> &LoaderIo {
        &self.io
    }
}

impl ExpertLoader {
    /// Single-lane compat constructor (the pre-pipeline serialization:
    /// one worker, transfers FIFO). Chunking still applies within the
    /// lane. Engine construction passes an explicit [`IoConfig`] through
    /// [`Self::start_with`] instead.
    pub fn start(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
    ) -> Self {
        Self::start_with(store, cache, copier, IoConfig::single_lane())
    }

    /// Start the loader with `io.lanes` worker lanes executing tasks as
    /// `io.chunk_bytes`-sized chunks over the shared link. The store is
    /// treated as fully local (every expert resident in host DRAM).
    pub fn start_with(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
        io: IoConfig,
    ) -> Self {
        Self::start_tiered(Arc::new(TieredStore::local_only(store)), cache, copier, io)
    }

    /// Start the loader over a [`TieredStore`]: when the record is not in
    /// the local DRAM shard, the worker's fetch transparently walks
    /// staged-cache → peer (charged against the *network* link at the
    /// task's lane weight) → disk before the PCIe chunk loop begins.
    pub fn start_tiered(
        store: Arc<TieredStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
        io: IoConfig,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(TaskQueue::default()),
            queue_cv: Condvar::new(),
            done: Mutex::new(HashMap::new()),
            done_cv: Condvar::new(),
            callbacks: Mutex::new(HashMap::new()),
            gens: Arc::new(Mutex::new(HashMap::new())),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
        });
        let stats = Arc::new(Mutex::new(LoaderStats::default()));
        let lanes = io.lanes.max(1);
        let chunk_bytes = io.chunk_bytes.max(1);
        let faults = store.faults();
        let mut handles = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let worker = Worker {
                shared: shared.clone(),
                store: store.clone(),
                cache: cache.clone(),
                copier: copier.clone(),
                stats: stats.clone(),
                chunk_bytes,
                lanes,
                faults: faults.clone(),
            };
            let handle = std::thread::Builder::new()
                .name(format!("hobbit-io-lane-{lane}"))
                .spawn(move || worker.run())
                .expect("spawn io lane");
            handles.push(handle);
        }
        let io = LoaderIo { shared, cache: cache.clone(), stats: stats.clone() };
        Self { io, cache, stats, handles }
    }

    /// The cloneable submit/wait/callback surface (completion callbacks
    /// use this to re-acquire after a `NoSlot` drop).
    pub fn io(&self) -> LoaderIo {
        self.io.clone()
    }
}

impl Drop for ExpertLoader {
    fn drop(&mut self) {
        self.io.shared.stop.store(true, Ordering::Relaxed);
        {
            let mut q = self.io.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.io.shared.queue_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One transfer lane.
struct Worker {
    shared: Arc<Shared>,
    store: Arc<TieredStore>,
    cache: Arc<Mutex<CacheManager>>,
    copier: Arc<ThrottledCopier>,
    stats: Arc<Mutex<LoaderStats>>,
    chunk_bytes: usize,
    /// total lane count (preemption checkpoints only yield when every
    /// lane is busy — an idle lane will take the on-demand work itself)
    lanes: usize,
    /// deterministic fault injection for transfer/commit sites (pulled
    /// from the tiered store so one plan covers every tier); None in
    /// production
    faults: Option<Arc<crate::faults::FaultPlan>>,
}

/// What one `execute` call did with its task.
enum Step {
    Done(LoadOutcome),
    /// preemption checkpoint fired: partial progress kept, task goes back
    /// to the front of the prefetch lane
    Yielded(LoadTask),
}

impl Worker {
    fn run(&self) {
        loop {
            let task = {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if self.shared.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    // on-demand lane first; prefetch lane drops stale gens.
                    // `in_flight` is raised and the running entry inserted
                    // inside the queue critical section so `is_idle` never
                    // sees a popped-but-running task as idle and
                    // `promote_to_ondemand` always finds the task in
                    // exactly one place.
                    if let Some(t) = q.ondemand.pop_front() {
                        q.running.insert(t.id, RunCtl::default());
                        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                        break t;
                    }
                    let mut stale: Vec<LoadTask> = Vec::new();
                    {
                        let mut gens = self.shared.gens.lock().unwrap();
                        while let Some(t) = q.prefetch.front() {
                            let cur = gens.get(&t.scope).copied().unwrap_or(0);
                            // upgrade continuations are staleness-exempt:
                            // generations bump every token, but an upgrade
                            // targets an already-resident slot, not a
                            // prediction that can go stale
                            if !t.upgrade && t.gen < cur {
                                stale.push(q.prefetch.pop_front().unwrap());
                            } else {
                                break;
                            }
                        }
                        // retired scopes (u64::MAX) are only referenced by
                        // queued prefetches; an empty lane proves none
                        // remain, so GC here — a busy on-demand lane must
                        // not starve the table (one entry per retired
                        // sequence otherwise accumulates forever)
                        if q.prefetch.is_empty() {
                            gens.retain(|_, g| *g != u64::MAX);
                        }
                    }
                    if !stale.is_empty() {
                        // report as done so no waiter hangs. Completion
                        // callbacks may take locks of their own (the
                        // residency wait-set), so fire them OUTSIDE the
                        // queue critical section. A preempted task's
                        // partially filled slot is aborted, never left
                        // `Loading` (and never committed).
                        drop(q);
                        for t in stale {
                            if t.resume.is_some() {
                                self.cache.lock().unwrap().abort(t.key, t.pool);
                            }
                            self.shared.complete(t.id, LoadOutcome::Stale);
                        }
                        q = self.shared.queue.lock().unwrap();
                        continue;
                    }
                    if let Some(t) = q.prefetch.pop_front() {
                        q.running.insert(t.id, RunCtl::default());
                        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                        break t;
                    }
                    if q.closed {
                        return;
                    }
                    q = self.shared.queue_cv.wait(q).unwrap();
                }
            };
            let id = task.id;
            let is_upgrade = task.upgrade;
            match self.execute(task) {
                Step::Done(outcome) => {
                    {
                        let mut q = self.shared.queue.lock().unwrap();
                        q.running.remove(&id);
                    }
                    // transfer fully resolved: drop in-flight before waking
                    // waiters so a returned `wait` implies `is_idle`
                    // (absent new submissions)
                    self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                    if is_upgrade {
                        // nobody waits on an upgrade continuation — no
                        // done-set entry to leak; wake idle-drain pollers
                        self.shared.done_cv.notify_all();
                    } else {
                        self.shared.complete(id, outcome);
                    }
                }
                Step::Yielded(mut task) => {
                    // back to the FRONT of the prefetch lane: it resumes
                    // (from its offset) as soon as the on-demand work that
                    // preempted it drains. running-removal, requeue, and
                    // the in-flight drop share one critical section so the
                    // task is always findable and never counted idle. A
                    // promotion that raced in after the checkpoint read is
                    // honored here instead of lost.
                    let mut q = self.shared.queue.lock().unwrap();
                    let promoted =
                        q.running.remove(&id).map(|c| c.promote).unwrap_or(false);
                    if promoted {
                        task.kind = TaskKind::OnDemand;
                        task.submitted = Instant::now();
                        q.ondemand.push_back(task);
                    } else {
                        q.prefetch.push_front(task);
                    }
                    self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                    drop(q);
                    self.shared.queue_cv.notify_one();
                }
            }
        }
    }

    fn execute(&self, mut task: LoadTask) -> Step {
        // a resume is not a new transfer: the fault plan's transfer
        // counter only ticks on fresh starts
        let fresh_start = task.resume.is_none();
        // resolve the destination: a fresh reservation, the preempted
        // transfer's kept buffer + offset, or — for an upgrade
        // continuation — private staging memory (the slot stays readable
        // at its current tier the whole time)
        let (buffer, start_off) = match task.resume.take() {
            Some(r) => (r.buffer, r.offset),
            None if task.upgrade => {
                // early abort: the slot the upgrade targets may already be
                // gone (evicted) or refilled — don't burn link time on it
                let live = {
                    let cache = self.cache.lock().unwrap();
                    let pool = match task.pool {
                        Pool::Hi => &cache.hi,
                        Pool::Lo => &cache.lo,
                    };
                    pool.resident_tier(task.key).is_some()
                };
                if !live {
                    self.stats.lock().unwrap().upgrades_aborted += 1;
                    return Step::Done(LoadOutcome::Fulfilled);
                }
                let n = self.store.record_bytes(task.precision);
                (Arc::new(Mutex::new(vec![0u8; n])), 0)
            }
            None => {
                let reservation = {
                    let mut cache = self.cache.lock().unwrap();
                    cache.reserve(task.key, task.pool, task.current_layer)
                };
                match reservation {
                    Some(res) => (res.buffer, 0),
                    None => {
                        // distinguish "already resident/incoming" (nothing
                        // to copy) from "no evictable slot". The latter
                        // used to complete silently, so ticket waiters
                        // resumed believing the expert resident — now it
                        // completes as NoSlot and the residency facade
                        // re-acquires.
                        let present = {
                            let cache = self.cache.lock().unwrap();
                            cache.contains(task.key, task.pool)
                        };
                        if present {
                            return Step::Done(LoadOutcome::Fulfilled);
                        }
                        self.stats.lock().unwrap().noslot_drops += 1;
                        return Step::Done(LoadOutcome::NoSlot);
                    }
                }
            }
        };
        let weight = match task.kind {
            TaskKind::OnDemand => ONDEMAND_WEIGHT,
            TaskKind::Prefetch => PREFETCH_WEIGHT,
        };
        // Materialize the record from whichever tier holds it. A remote
        // fetch charges the network link (at this task's weight) before any
        // PCIe chunk moves; the result lands in the tiered store's staged
        // side-cache, so a preempted task's resume re-reads identical bytes
        // without touching the network again.
        let fetched = self.store.fetch(task.key, task.precision, weight);
        let record = fetched.as_slice();
        let xfer_fault = match (&self.faults, fresh_start) {
            (Some(plan), true) => plan.on_transfer(),
            _ => crate::faults::TransferFault::default(),
        };
        if let Some(stall) = xfer_fault.stall {
            // a wedged I/O lane: the bytes are fine but late — the
            // residency watchdog's prey. The stall occupies a real lane
            // grant, so link-pressure consumers see it too.
            self.copier.stall_lane(weight, stall);
        }
        if xfer_fault.flip.is_some() {
            // applied at commit time (below) so a preemption yield between
            // now and then cannot lose the fault
            task.xfer_flip = xfer_fault.flip;
        }
        let grant = self.copier.lane(weight);
        // DMA setup cost: once per transfer start and per preemption resume
        self.copier.charge_latency();
        let mut off = start_off;
        while off < record.len() {
            let n = self.chunk_bytes.min(record.len() - off);
            // copy the chunk under the slot lock, then charge the shared
            // link time WITHOUT it: cache readers of other requests never
            // block behind a modeled PCIe stall
            let t0 = Instant::now();
            {
                let mut buf = buffer.lock().unwrap();
                // a progressive floor record occupies a prefix of the
                // (native-precision-sized) slot; upgrades stage exactly
                // record.len()
                debug_assert!(buf.len() >= record.len(), "slot/record size");
                buf[off..off + n].copy_from_slice(&record[off..off + n]);
            }
            self.copier.charge_chunk(&grant, n, t0.elapsed());
            off += n;
            if off >= record.len() {
                break;
            }
            // ---- preemption checkpoint (between chunks) ----
            if task.kind == TaskKind::Prefetch {
                let mut q = self.shared.queue.lock().unwrap();
                let promoted = q
                    .running
                    .get_mut(&task.id)
                    .map(|c| std::mem::take(&mut c.promote))
                    .unwrap_or(false);
                if promoted {
                    drop(q);
                    // an on-demand join re-prioritizes the REMAINING
                    // chunks in place: switch kind and lane weight, keep
                    // copying (the clock restarts so time-to-ready
                    // measures the joiner's wait)
                    task.kind = TaskKind::OnDemand;
                    task.submitted = Instant::now();
                    grant.set_weight(ONDEMAND_WEIGHT);
                    self.stats.lock().unwrap().inflight_promotions += 1;
                    continue;
                }
                // yield only when EVERY lane is busy: with an idle lane
                // around, the waiting on-demand task is (about to be)
                // picked up there, and yielding would just re-pay the DMA
                // setup latency on resume for nothing — the weighted
                // arbiter already squeezes this lane's share
                if !q.ondemand.is_empty() && q.running.len() >= self.lanes {
                    drop(q);
                    self.stats.lock().unwrap().preemptions += 1;
                    task.resume = Some(Resume { offset: off, buffer });
                    return Step::Yielded(task);
                }
            }
        }
        drop(grant);
        if let Some(seed) = task.xfer_flip {
            // the pending transfer fault lands now, after every chunk (and
            // any preemption resume) has written its bytes — exactly what
            // a DMA engine corrupting one word in flight looks like to the
            // commit-time check
            let mut rng = crate::util::rng::Rng::new(seed);
            let mut buf = buffer.lock().unwrap();
            crate::faults::flip_bit(&mut buf[..record.len()], &mut rng);
        }
        if task.upgrade {
            if let Some(plan) = &self.faults {
                let mut staged = buffer.lock().unwrap();
                plan.on_upgrade_commit(&mut staged);
            }
            // land the fully staged record atomically — but only if it
            // still matches its manifest checksum; a torn staged record
            // must never overwrite a live, readable slot
            let expected = self.store.expected_checksum(task.key, task.precision);
            let outcome = {
                let staged = buffer.lock().unwrap();
                let mut cache = self.cache.lock().unwrap();
                cache.commit_upgrade_verified(
                    task.key,
                    task.pool,
                    Some(task.precision),
                    &staged,
                    expected,
                )
            };
            self.copier.note_transfer();
            let mut reheal = false;
            {
                let mut st = self.stats.lock().unwrap();
                match outcome {
                    UpgradeCommit::Committed => st.upgrades_committed += 1,
                    // the slot moved on (evicted/refilled): the narrower
                    // tier that is (or was) resident stays valid
                    UpgradeCommit::SlotMovedOn => st.upgrades_aborted += 1,
                    UpgradeCommit::Corrupt => {
                        st.integrity_failures += 1;
                        if task.heal < MAX_INTEGRITY_HEALS {
                            st.integrity_refetches += 1;
                            reheal = true;
                        } else {
                            st.upgrades_aborted += 1;
                        }
                    }
                }
                st.bytes_loaded += record.len() as u64;
            }
            if reheal {
                // bounded self-heal: re-stream the record from the store
                // (whose copy is verified) into fresh staging memory
                let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
                let cont = LoadTask {
                    id,
                    key: task.key,
                    precision: task.precision,
                    pool: task.pool,
                    kind: TaskKind::Prefetch,
                    gen: 0,
                    scope: task.scope,
                    current_layer: task.current_layer,
                    upgrade_to: None,
                    upgrade: true,
                    heal: task.heal + 1,
                    xfer_flip: None,
                    resume: None,
                    submitted: Instant::now(),
                };
                let mut q = self.shared.queue.lock().unwrap();
                q.prefetch.push_back(cont);
                drop(q);
                self.shared.queue_cv.notify_one();
            }
            return Step::Done(LoadOutcome::Fulfilled);
        }
        let expected = self
            .store
            .expected_checksum(task.key, task.precision)
            .map(|sum| (sum, record.len()));
        let commit = {
            let mut cache = self.cache.lock().unwrap();
            cache.commit_tier_verified(task.key, task.pool, Some(task.precision), expected)
        };
        self.copier.note_transfer();
        if commit == CommitOutcome::Corrupt {
            // quarantined: the slot was scrubbed and freed, the expert is
            // not resident. Waiters re-acquire (the residency facade's
            // bounded heal) and the re-fetch reads the store's clean copy.
            let mut st = self.stats.lock().unwrap();
            st.integrity_failures += 1;
            st.quarantined_slots += 1;
            st.bytes_loaded += record.len() as u64;
            return Step::Done(LoadOutcome::Corrupt);
        }
        {
            let mut st = self.stats.lock().unwrap();
            let slot = crate::config::precision_slot(task.precision);
            match task.kind {
                TaskKind::OnDemand => {
                    st.ondemand_loads[slot] += 1;
                    st.ondemand_ready += task.submitted.elapsed();
                }
                TaskKind::Prefetch => {
                    st.prefetch_loads[slot] += 1;
                    st.prefetch_ready += task.submitted.elapsed();
                }
            }
            st.bytes_loaded += record.len() as u64;
            if task.upgrade_to.is_some() {
                st.progressive_loads += 1;
            }
        }
        // the staged continuation: stream the wider record on the
        // prefetch lane (background weight) and upgrade the slot in place
        if let Some(up) = task.upgrade_to {
            let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
            let cont = LoadTask {
                id,
                key: task.key,
                precision: up,
                pool: task.pool,
                kind: TaskKind::Prefetch,
                gen: 0,
                scope: task.scope,
                current_layer: task.current_layer,
                upgrade_to: None,
                upgrade: true,
                heal: 0,
                xfer_flip: None,
                resume: None,
                submitted: Instant::now(),
            };
            let mut q = self.shared.queue.lock().unwrap();
            q.prefetch.push_back(cont);
            drop(q);
            self.shared.queue_cv.notify_one();
        }
        Step::Done(LoadOutcome::Fulfilled)
    }
}
