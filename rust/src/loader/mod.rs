//! The Dynamic Expert Loader (§3.2, Fig 6): Expert Scorer → Task Queue →
//! Expert Scheduler.
//!
//! The scheduler runs on its own thread and moves expert records from the
//! `ExpertStore` ("next-level memory") into reserved cache slots through
//! the bandwidth-throttled link. Faithful to the paper's memcpy
//! observation, a transfer in flight is never preempted: an on-demand task
//! arriving behind a started prefetch waits for it — the misprediction
//! penalty of Fig 9. On-demand tasks do jump ahead of *queued* (not yet
//! started) prefetches, and stale prefetches are dropped by generation.
//!
//! Completion can be consumed three ways: blocking ([`ExpertLoader::wait`]),
//! polling ([`ExpertLoader::try_wait`] — the interleaved scheduler's
//! non-blocking barrier), or pushed ([`ExpertLoader::on_complete`] per-task
//! callbacks, used by the serving front-end to wake its event loop).

pub mod scorer;

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CacheManager, Pool};
use crate::memory::ThrottledCopier;
use crate::metrics::LoaderStats;
use crate::model::ExpertStore;
use crate::{ExpertKey, Precision};

/// Why a load was requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    OnDemand,
    Prefetch,
}

/// One entry in the Task Queue.
#[derive(Debug, Clone)]
pub struct LoadTask {
    pub id: u64,
    pub key: ExpertKey,
    pub precision: Precision,
    pub pool: Pool,
    pub kind: TaskKind,
    /// prefetch generation (stale generations are dropped)
    pub gen: u64,
    /// layer being executed when the task was issued (for Eq. 3's l_i)
    pub current_layer: u32,
}

/// Two-lane FIFO: on-demand tasks always dequeue before prefetches.
#[derive(Default)]
struct TaskQueue {
    ondemand: std::collections::VecDeque<LoadTask>,
    prefetch: std::collections::VecDeque<LoadTask>,
    closed: bool,
}

/// Completion callback: invoked once with the task id when the task
/// finishes (successfully, deduped, or dropped as stale). Callbacks must be
/// cheap and must not call back into the loader (they can run on the
/// scheduler thread while it holds the queue lock).
type Callback = Box<dyn FnOnce(u64) + Send + 'static>;

struct Shared {
    queue: Mutex<TaskQueue>,
    queue_cv: Condvar,
    done: Mutex<HashSet<u64>>,
    done_cv: Condvar,
    callbacks: Mutex<HashMap<u64, Callback>>,
    prefetch_gen: AtomicU64,
    next_id: AtomicU64,
    stop: AtomicBool,
    /// tasks popped from a lane but not yet completed (mid-transfer)
    in_flight: AtomicUsize,
}

/// Handle to the loader: issue tasks, wait for completions.
pub struct ExpertLoader {
    shared: Arc<Shared>,
    pub cache: Arc<Mutex<CacheManager>>,
    pub stats: Arc<Mutex<LoaderStats>>,
    handle: Option<JoinHandle<()>>,
}

impl ExpertLoader {
    pub fn start(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(TaskQueue::default()),
            queue_cv: Condvar::new(),
            done: Mutex::new(HashSet::new()),
            done_cv: Condvar::new(),
            callbacks: Mutex::new(HashMap::new()),
            prefetch_gen: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
        });
        let stats = Arc::new(Mutex::new(LoaderStats::default()));
        let worker = Worker {
            shared: shared.clone(),
            store,
            cache: cache.clone(),
            copier,
            stats: stats.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("hobbit-expert-scheduler".into())
            .spawn(move || worker.run())
            .expect("spawn scheduler");
        Self { shared, cache, stats, handle: Some(handle) }
    }

    /// Enqueue a load; returns the task id to wait on (None if the expert
    /// is already resident or incoming, or no slot could be reserved).
    pub fn submit(
        &self,
        key: ExpertKey,
        precision: Precision,
        pool: Pool,
        kind: TaskKind,
        current_layer: u32,
    ) -> Option<u64> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains(key, pool) {
                return None;
            }
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let gen = self.shared.prefetch_gen.load(Ordering::Relaxed);
        let task = LoadTask { id, key, precision, pool, kind, gen, current_layer };
        let mut q = self.shared.queue.lock().unwrap();
        match kind {
            TaskKind::OnDemand => q.ondemand.push_back(task),
            TaskKind::Prefetch => q.prefetch.push_back(task),
        }
        drop(q);
        self.shared.queue_cv.notify_one();
        Some(id)
    }

    /// Invalidate all queued (unstarted) prefetches from earlier tokens.
    pub fn bump_prefetch_generation(&self) {
        self.shared.prefetch_gen.fetch_add(1, Ordering::Relaxed);
    }

    /// Block until every id in `ids` has completed. Returns wait time.
    pub fn wait(&self, ids: &[u64]) -> Duration {
        let t0 = Instant::now();
        let mut done = self.shared.done.lock().unwrap();
        loop {
            if ids.iter().all(|id| done.contains(id)) {
                for id in ids {
                    done.remove(id);
                }
                return t0.elapsed();
            }
            done = self.shared.done_cv.wait(done).unwrap();
        }
    }

    /// Non-blocking completion poll: true when every id in `ids` has
    /// completed (the ids are then consumed, exactly like [`Self::wait`]).
    /// False leaves all ids pending so the caller can poll again.
    pub fn try_wait(&self, ids: &[u64]) -> bool {
        if ids.is_empty() {
            return true;
        }
        let mut done = self.shared.done.lock().unwrap();
        if ids.iter().all(|id| done.contains(id)) {
            for id in ids {
                done.remove(id);
            }
            true
        } else {
            false
        }
    }

    /// Non-consuming completion probe: true once `id` has completed and
    /// has not yet been consumed by `wait`/`try_wait`.
    pub fn is_done(&self, id: u64) -> bool {
        self.shared.done.lock().unwrap().contains(&id)
    }

    /// Register a completion callback for task `id`; it fires exactly once,
    /// on the scheduler thread when the task completes, or immediately on
    /// the caller thread if the task already completed. Register before the
    /// id is consumed by `wait`/`try_wait` — a consumed id never fires.
    /// Re-registering replaces (and drops) the previous callback.
    pub fn on_complete<F: FnOnce(u64) + Send + 'static>(&self, id: u64, cb: F) {
        self.shared.callbacks.lock().unwrap().insert(id, Box::new(cb));
        // the worker publishes `done` before draining callbacks, so if the
        // task raced past us we can still claim (or find gone) our entry
        if self.shared.done.lock().unwrap().contains(&id) {
            if let Some(cb) = self.shared.callbacks.lock().unwrap().remove(&id) {
                cb(id);
            }
        }
    }

    /// True when both task lanes are empty and nothing is mid-transfer
    /// (used by drains in tests/benches).
    pub fn is_idle(&self) -> bool {
        let q = self.shared.queue.lock().unwrap();
        q.ondemand.is_empty()
            && q.prefetch.is_empty()
            && self.shared.in_flight.load(Ordering::SeqCst) == 0
    }
}

impl Drop for ExpertLoader {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.queue_cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Worker {
    shared: Arc<Shared>,
    store: Arc<ExpertStore>,
    cache: Arc<Mutex<CacheManager>>,
    copier: Arc<ThrottledCopier>,
    stats: Arc<Mutex<LoaderStats>>,
}

impl Worker {
    fn run(&self) {
        loop {
            let task = {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if self.shared.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    // on-demand lane first; prefetch lane drops stale gens.
                    // `in_flight` is raised inside the queue critical
                    // section so `is_idle` never sees a popped-but-running
                    // task as idle.
                    if let Some(t) = q.ondemand.pop_front() {
                        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                        break t;
                    }
                    let cur_gen = self.shared.prefetch_gen.load(Ordering::Relaxed);
                    while let Some(t) = q.prefetch.front() {
                        if t.gen < cur_gen {
                            let stale = q.prefetch.pop_front().unwrap();
                            // report as done so no waiter hangs
                            self.mark_done(stale.id);
                        } else {
                            break;
                        }
                    }
                    if let Some(t) = q.prefetch.pop_front() {
                        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                        break t;
                    }
                    if q.closed {
                        return;
                    }
                    q = self.shared.queue_cv.wait(q).unwrap();
                }
            };
            let id = task.id;
            self.execute(task);
            // transfer fully committed: drop in-flight before waking
            // waiters so a returned `wait` implies `is_idle` (absent new
            // submissions)
            self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.mark_done(id);
        }
    }

    fn execute(&self, task: LoadTask) {
        // reserve a destination slot
        let reservation = {
            let mut cache = self.cache.lock().unwrap();
            cache.reserve(task.key, task.pool, task.current_layer)
        };
        let Some(res) = reservation else {
            // already resident/incoming, or no evictable slot: nothing to
            // copy (run() marks the task done)
            return;
        };
        let record = self.store.record(task.key, task.precision);
        {
            // per-slot lock: the engine can read other slots meanwhile;
            // the transfer itself is non-preemptible (cudaMemcpy model)
            let mut buf = res.buffer.lock().unwrap();
            debug_assert_eq!(buf.len(), record.len(), "slot/record size");
            self.copier.transfer(record, &mut buf);
        }
        {
            let mut cache = self.cache.lock().unwrap();
            cache.commit(task.key, task.pool);
        }
        {
            let mut st = self.stats.lock().unwrap();
            let slot = crate::config::precision_slot(task.precision);
            match task.kind {
                TaskKind::OnDemand => st.ondemand_loads[slot] += 1,
                TaskKind::Prefetch => st.prefetch_loads[slot] += 1,
            }
            st.bytes_loaded += record.len() as u64;
        }
    }

    fn mark_done(&self, id: u64) {
        // publish completion BEFORE draining the callback: `on_complete`
        // re-checks `done` after inserting, so whichever side loses the
        // race still finds (exactly one of) the entry to fire
        let mut done = self.shared.done.lock().unwrap();
        done.insert(id);
        drop(done);
        self.shared.done_cv.notify_all();
        if let Some(cb) = self.shared.callbacks.lock().unwrap().remove(&id) {
            cb(id);
        }
    }
}
