//! The Expert Scorer (§3.2, Fig 6): token-level dynamic precision
//! decisions from gating outputs.
//!
//! Experts selected by the gate are ranked by normalized gate magnitude
//! ‖G(x)‖; the *unimportance degree* of expert e_i is the prefix sum of
//! the normalized magnitudes ranked above it (Eq. 2):
//!
//!   s_{e_0} = 0;   s_{e_i} = Σ_{j<i} ‖G(x)_{e_j}‖ (normalized)
//!
//! Thresholds split the ladder: s ≤ T1 → high precision; T1 < s ≤ T2 →
//! low precision; s > T2 → skip. e_0 (score 0) is always high precision.

use crate::tensor::topk;

/// Precision class chosen for one selected expert.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    Hi,
    Lo,
    Skip,
}

/// One gate-selected expert with its routing weight and precision class.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub expert: u32,
    /// renormalized top-k gate weight (feeds the expert FFN)
    pub gate_weight: f32,
    /// Eq. 2 unimportance score
    pub score: f64,
    pub class: Class,
}

/// Score the top-k experts of one token's gate distribution.
///
/// `probs` is the full softmax gate output for one token (length E);
/// when `dynamic` is false every selected expert is classed Hi (the
/// ablation baseline of Fig 16).
pub fn decide(probs: &[f32], top_k: usize, t1: f64, t2: f64, dynamic: bool) -> Vec<Decision> {
    let top = topk(probs, top_k);
    let sum: f32 = top.iter().map(|(_, v)| *v).sum();
    let denom = if sum > 0.0 { sum } else { 1.0 };
    let mut out = Vec::with_capacity(top_k);
    let mut prefix = 0.0f64;
    for (rank, (e, v)) in top.iter().enumerate() {
        let norm = (*v / denom) as f64;
        let score = if rank == 0 { 0.0 } else { prefix };
        let class = if !dynamic || score <= t1 {
            Class::Hi
        } else if score <= t2 {
            Class::Lo
        } else {
            Class::Skip
        };
        out.push(Decision {
            expert: *e as u32,
            gate_weight: *v / denom,
            score,
            class,
        });
        prefix += norm;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_always_high() {
        // dominant first expert -> second scores 0.9+ and is skipped
        let d = decide(&[0.95, 0.03, 0.02], 2, 0.6, 0.9, true);
        assert_eq!(d[0].class, Class::Hi);
        assert_eq!(d[0].score, 0.0);
        assert_eq!(d[1].class, Class::Skip);
        assert!(d[1].score > 0.9);
    }

    #[test]
    fn balanced_gate_keeps_both_high() {
        let d = decide(&[0.5, 0.5, 0.0], 2, 0.6, 0.9, true);
        assert_eq!(d[0].class, Class::Hi);
        assert_eq!(d[1].class, Class::Hi); // score 0.5 <= T1
    }

    #[test]
    fn moderate_dominance_gives_low_precision() {
        // g0 = 0.7, g1 = 0.3 normalized -> s_1 = 0.7 in (0.6, 0.9]
        let d = decide(&[0.7, 0.3], 2, 0.6, 0.9, true);
        assert_eq!(d[1].class, Class::Lo);
    }

    #[test]
    fn dynamic_off_forces_high() {
        let d = decide(&[0.95, 0.03, 0.02], 2, 0.6, 0.9, false);
        assert!(d.iter().all(|x| x.class == Class::Hi));
    }

    #[test]
    fn gate_weights_renormalized() {
        let d = decide(&[0.6, 0.2, 0.2], 2, 0.6, 0.9, true);
        let s: f32 = d.iter().map(|x| x.gate_weight).sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(d[0].gate_weight > d[1].gate_weight);
    }

    #[test]
    fn scores_monotone_in_rank() {
        let d = decide(&[0.4, 0.3, 0.2, 0.1], 4, 0.6, 0.9, true);
        for w in d.windows(2) {
            assert!(w[0].score <= w[1].score);
        }
        // last expert's score is 1 - its own normalized weight
        assert!((d[3].score - 0.9).abs() < 1e-6);
    }
}
