//! The comparator systems of §5 (Table 2), for both execution paths:
//!
//! * **sim** (paper scale): `SimSystem` configurations per testing group;
//! * **real** (tiny models via PJRT): `EngineOptions` ablation variants —
//!   HOBBIT minus one mechanism at a time, plus classic cache policies.

use crate::cache::Policy;
use crate::config::{HardwareConfig, PolicyConfig};
use crate::engine::EngineOptions;
use crate::sim::des::SimSystem;

pub const EQ3_WEIGHTS: [f64; 4] = [0.65, 0.05, 0.10, 0.20];

/// Table 2, row 2: GeForce RTX 4090, float16 group — HB, TF, DS, MO, MI.
pub fn group_rtx4090_f16() -> Vec<SimSystem> {
    vec![
        SimSystem::hobbit(EQ3_WEIGHTS),
        SimSystem::dense("Transformers", 16.0),
        SimSystem::dense("DeepSpeed", 16.0),
        SimSystem::moe_offloading(16.0),
        SimSystem::moe_infinity(16.0),
    ]
}

/// Table 2, row 1: Jetson AGX Orin, int8 group — HB, LL, MI.
pub fn group_orin_int8() -> Vec<SimSystem> {
    vec![
        SimSystem::hobbit_int8(EQ3_WEIGHTS),
        SimSystem::llama_cpp(8.0),
        SimSystem::moe_infinity(8.0),
    ]
}

/// Table 2, row 3: RTX 4090 + CPU, float16 group — HB(coop), LL, FD.
pub fn group_rtx4090_cpu() -> Vec<SimSystem> {
    vec![
        SimSystem::hobbit_coop(EQ3_WEIGHTS),
        SimSystem::llama_cpp(16.0),
        SimSystem::fiddler(16.0),
    ]
}

// ---------------------------------------------------------------------------
// Real-path (tiny model) ablation variants — Fig 16/17/18 on live PJRT.
// ---------------------------------------------------------------------------

/// Full HOBBIT.
pub fn real_hobbit(hw: HardwareConfig) -> EngineOptions {
    EngineOptions::new(hw, PolicyConfig::default())
}

/// Dynamic mixed-precision loading disabled (Fig 16 ablation).
pub fn real_no_dynamic(hw: HardwareConfig) -> EngineOptions {
    let policy = PolicyConfig { dynamic_loading: false, ..PolicyConfig::default() };
    EngineOptions::new(hw, policy)
}

/// Prefetching disabled (Fig 17b ablation).
pub fn real_no_prefetch(hw: HardwareConfig) -> EngineOptions {
    let policy = PolicyConfig { prefetch_depth: 0, ..PolicyConfig::default() };
    EngineOptions::new(hw, policy)
}

/// Classic cache policy instead of Eq. 3 (Fig 18 comparison).
pub fn real_with_policy(hw: HardwareConfig, policy: Policy) -> EngineOptions {
    let mut opts = EngineOptions::new(hw, PolicyConfig::default());
    opts.cache_policy = Some(policy);
    opts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_match_table2() {
        assert_eq!(group_rtx4090_f16().len(), 5);
        assert_eq!(group_orin_int8().len(), 3);
        assert_eq!(group_rtx4090_cpu().len(), 3);
        assert_eq!(group_orin_int8()[0].hi_bits, 8.0);
        assert_eq!(group_orin_int8()[0].lo_bits, 2.0);
    }

    #[test]
    fn weights_sum_to_one() {
        assert!((EQ3_WEIGHTS.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ablations_differ_from_full() {
        let hw = HardwareConfig::rtx4090_real();
        assert!(real_hobbit(hw.clone()).policy.dynamic_loading);
        assert!(!real_no_dynamic(hw.clone()).policy.dynamic_loading);
        assert_eq!(real_no_prefetch(hw).policy.prefetch_depth, 0);
    }
}
