//! Sequence-level multidimensional expert caching (§3.4, Fig 12).
//!
//! A mixed-precision expert cache with **two pools** (high-precision and
//! low-precision; the high pool is typically larger), per-sequence usage
//! records, and a pluggable replacement policy. The paper's contribution
//! is the *Multidimensional* policy of Eq. 3 — a weighted blend of
//!
//! * LRU   — recency `R_t / T`
//! * LFU   — sequence-level frequency `F_t / T`
//! * LHU   — **least high-precision frequently used** `H_t / T` (novel:
//!           a high-precision miss costs `B_h/B_l` times a low one)
//! * FLD   — farthest layer distance `1 - ((l_t - l_i + l_n) % l_n)/l_n`
//!
//! and the evaluation metric is the *miss penalty* (hi miss = 1, lo miss
//! = B_l/B_h), not the raw miss ratio.
//!
//! Pools hand out slot buffers guarded per-slot so the scheduler thread
//! can fill a reserved slot while the engine reads others; the pool map
//! itself is guarded by the caller (`loader::SharedCache` wraps the whole
//! manager in a mutex — pool sizes are tens of entries, scans are cheap).

pub mod policy;

pub use policy::Policy;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::metrics::CacheStats;
use crate::{ExpertKey, Precision};

/// Which pool an expert version lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pool {
    Hi,
    Lo,
}

/// Per-expert usage records (dense over layer*expert), reset per sequence
/// (§3.4: "at the start of each new sequence, the Policy Performer resets
/// the LRU, LFU and LHU records").
#[derive(Debug, Clone)]
pub struct Records {
    pub last_used: Vec<u64>,
    pub freq: Vec<u32>,
    pub hi_freq: Vec<u32>,
    /// model-level frequency: never reset (the Fig 18(b) comparison)
    pub model_freq: Vec<u64>,
    /// token counter T within the current sequence
    pub token: u64,
    experts_per_layer: u32,
}

impl Records {
    pub fn new(n_layers: u32, experts_per_layer: u32) -> Self {
        let n = (n_layers * experts_per_layer) as usize;
        Self {
            last_used: vec![0; n],
            freq: vec![0; n],
            hi_freq: vec![0; n],
            model_freq: vec![0; n],
            token: 0,
            experts_per_layer,
        }
    }

    pub fn idx(&self, key: ExpertKey) -> usize {
        key.index(self.experts_per_layer)
    }

    pub fn note_token(&mut self) {
        self.token += 1;
    }

    /// Record a use of `key`; `hi` marks high-precision use (LHU).
    pub fn note_use(&mut self, key: ExpertKey, hi: bool) {
        let i = self.idx(key);
        self.last_used[i] = self.token;
        self.freq[i] += 1;
        self.model_freq[i] += 1;
        if hi {
            self.hi_freq[i] += 1;
        }
    }

    pub fn reset_sequence(&mut self) {
        self.last_used.fill(0);
        self.freq.fill(0);
        self.hi_freq.fill(0);
        self.token = 0;
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum SlotState {
    Free,
    /// reserved by the loader; not evictable, not readable
    Loading(ExpertKey),
    Ready(ExpertKey),
    /// read-replica of a hot Ready primary: filled by a DRAM-to-DRAM copy
    /// (never via the link), not in `map`, never a pin target, first
    /// eviction victim
    Replica(ExpertKey),
}

/// The replica slots of one hot key, plus the rotation cursor that
/// spreads concurrent readers across primary + replicas.
#[derive(Debug, Clone, Default)]
struct ReplicaSet {
    slots: Vec<usize>,
    next: usize,
}

/// One precision pool.
pub struct CachePool {
    state: Vec<SlotState>,
    map: HashMap<ExpertKey, usize>,
    buffers: Vec<Arc<Mutex<Vec<u8>>>>,
    /// resident tier of each slot's bytes: `None` = the pool's native
    /// precision (the pre-progressive contract, and what `commit` sets);
    /// `Some(p)` = a progressive load left precision-`p` bytes in the slot
    /// (the record occupies a *prefix* of the slot buffer when `p` is
    /// narrower than the pool's native precision)
    tiers: Vec<Option<Precision>>,
    pinned: HashMap<ExpertKey, u32>, // pin count (predictions may stack)
    /// read-replicas of hot keys (slots in `state` as `Replica`, never in
    /// `map` — primaries alone are pinnable/evictable by policy)
    replicas: HashMap<ExpertKey, ReplicaSet>,
}

impl CachePool {
    pub fn new(capacity: usize, slot_bytes: usize) -> Self {
        Self {
            state: vec![SlotState::Free; capacity],
            map: HashMap::new(),
            buffers: (0..capacity)
                .map(|_| Arc::new(Mutex::new(vec![0u8; slot_bytes])))
                .collect(),
            tiers: vec![None; capacity],
            pinned: HashMap::new(),
            replicas: HashMap::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    pub fn contains_ready(&self, key: ExpertKey) -> bool {
        self.map
            .get(&key)
            .map(|&s| self.state[s] == SlotState::Ready(key))
            .unwrap_or(false)
    }

    pub fn is_loading(&self, key: ExpertKey) -> bool {
        self.map
            .get(&key)
            .map(|&s| self.state[s] == SlotState::Loading(key))
            .unwrap_or(false)
    }

    pub fn buffer(&self, key: ExpertKey) -> Option<Arc<Mutex<Vec<u8>>>> {
        let &slot = self.map.get(&key)?;
        if self.state[slot] == SlotState::Ready(key) {
            Some(self.buffers[slot].clone())
        } else {
            None
        }
    }

    /// Slot buffer plus the resident tier of its bytes (`None` tier = the
    /// pool's native precision). Readers that clone record bytes must read
    /// the tier and the bytes under ONE cache lock ([`CacheManager`]'s
    /// callers hold it) so an in-place upgrade can never tear a
    /// tier/bytes pair.
    pub fn buffer_tier(&self, key: ExpertKey) -> Option<(Arc<Mutex<Vec<u8>>>, Option<Precision>)> {
        let &slot = self.map.get(&key)?;
        if self.state[slot] == SlotState::Ready(key) {
            Some((self.buffers[slot].clone(), self.tiers[slot]))
        } else {
            None
        }
    }

    /// Resident tier of a ready expert (`None` tier = pool native).
    pub fn resident_tier(&self, key: ExpertKey) -> Option<Option<Precision>> {
        let &slot = self.map.get(&key)?;
        if self.state[slot] == SlotState::Ready(key) {
            Some(self.tiers[slot])
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Pin `key` against eviction (counts stack: predictions and barrier
    /// uses may overlap). Returns whether the key currently maps to a slot
    /// (ready or loading) — pinning ahead of a load is legal (the pin
    /// protects the slot once reserved), but a call site that believes the
    /// key is resident should `debug_assert!` the return so a mis-keyed
    /// pin cannot silently leave the real slot evictable.
    pub fn pin(&mut self, key: ExpertKey) -> bool {
        *self.pinned.entry(key).or_insert(0) += 1;
        self.map.contains_key(&key)
    }

    /// Release one pin of `key`. Returns whether a pin existed — false
    /// means the unpin was mis-keyed (or unbalanced) and silently changed
    /// nothing; call sites `debug_assert!` it.
    pub fn unpin(&mut self, key: ExpertKey) -> bool {
        if let Some(c) = self.pinned.get_mut(&key) {
            *c -= 1;
            if *c == 0 {
                self.pinned.remove(&key);
            }
            true
        } else {
            false
        }
    }

    pub fn pinned_contains(&self, key: ExpertKey) -> bool {
        self.pinned.contains_key(&key)
    }

    /// Total outstanding pin count across all keys (leak detection: a
    /// balanced pin/unpin history leaves this at zero).
    pub fn pinned_count(&self) -> u32 {
        self.pinned.values().sum()
    }

    pub fn ready_keys(&self) -> impl Iterator<Item = ExpertKey> + '_ {
        self.state.iter().filter_map(|s| match s {
            SlotState::Ready(k) => Some(*k),
            _ => None,
        })
    }

    /// Populate one read-replica of a hot Ready primary into a Free slot:
    /// a cheap DRAM-to-DRAM copy of bytes + tier, never a link fetch.
    /// Refuses (false) when the primary is not Ready or no slot is free —
    /// replicas only ever use otherwise-idle capacity.
    pub fn add_replica(&mut self, key: ExpertKey) -> bool {
        let Some(&pslot) = self.map.get(&key) else { return false };
        if self.state[pslot] != SlotState::Ready(key) {
            return false;
        }
        let Some(free) = self.state.iter().position(|s| *s == SlotState::Free) else {
            return false;
        };
        {
            let src = self.buffers[pslot].lock().unwrap();
            let mut dst = self.buffers[free].lock().unwrap();
            let n = src.len().min(dst.len());
            dst[..n].copy_from_slice(&src[..n]);
        }
        self.tiers[free] = self.tiers[pslot];
        self.state[free] = SlotState::Replica(key);
        self.replicas.entry(key).or_default().slots.push(free);
        true
    }

    /// Live replica count of one key / of the whole pool.
    pub fn replica_count(&self, key: ExpertKey) -> usize {
        self.replicas.get(&key).map(|r| r.slots.len()).unwrap_or(0)
    }

    pub fn total_replicas(&self) -> usize {
        self.replicas.values().map(|r| r.slots.len()).sum()
    }

    /// [`Self::buffer_tier`] that rotates reads across the primary and
    /// its replicas so concurrent snapshots never contend on one slot
    /// lock; the bool reports whether a replica served this read.
    pub fn buffer_tier_rotated(
        &mut self,
        key: ExpertKey,
    ) -> Option<(Arc<Mutex<Vec<u8>>>, Option<Precision>, bool)> {
        let &pslot = self.map.get(&key)?;
        if self.state[pslot] != SlotState::Ready(key) {
            return None;
        }
        if let Some(rs) = self.replicas.get_mut(&key) {
            if !rs.slots.is_empty() {
                let turn = rs.next % (rs.slots.len() + 1);
                rs.next = rs.next.wrapping_add(1);
                if turn > 0 {
                    let slot = rs.slots[turn - 1];
                    return Some((self.buffers[slot].clone(), self.tiers[slot], true));
                }
            }
        }
        Some((self.buffers[pslot].clone(), self.tiers[pslot], false))
    }

    /// Invalidate every replica of `key` (primary evicted, upgraded, or
    /// quarantined): their slots free atomically under the caller's cache
    /// lock, so a reader can never rotate onto stale-primary bytes.
    /// Returns how many slots were reclaimed.
    pub fn drop_replicas(&mut self, key: ExpertKey) -> usize {
        let Some(rs) = self.replicas.remove(&key) else { return 0 };
        for &s in &rs.slots {
            self.state[s] = SlotState::Free;
            self.tiers[s] = None;
        }
        rs.slots.len()
    }

    /// Reclaim one replica slot (lowest slot index — deterministic), the
    /// pool's first eviction victim class. Returns the freed slot.
    fn evict_one_replica(&mut self) -> Option<usize> {
        let slot = self.state.iter().position(|s| matches!(s, SlotState::Replica(_)))?;
        let SlotState::Replica(key) = self.state[slot] else { unreachable!() };
        if let Some(rs) = self.replicas.get_mut(&key) {
            rs.slots.retain(|&s| s != slot);
            if rs.slots.is_empty() {
                self.replicas.remove(&key);
            }
        }
        self.state[slot] = SlotState::Free;
        self.tiers[slot] = None;
        Some(slot)
    }
}

/// Result of a slot reservation.
pub struct Reservation {
    pub slot: usize,
    pub buffer: Arc<Mutex<Vec<u8>>>,
    pub evicted: Option<ExpertKey>,
}

/// Outcome of a checksum-verified commit ([`CacheManager::commit_tier_verified`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitOutcome {
    /// bytes verified (or no checksum supplied); slot is Ready
    Committed,
    /// bytes failed verification; slot was scrubbed and freed — the
    /// quarantine path: corrupt bytes are never served
    Corrupt,
}

/// Outcome of a checksum-verified in-place upgrade
/// ([`CacheManager::commit_upgrade_verified`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpgradeCommit {
    /// staged record verified and landed; slot now holds the wider tier
    Committed,
    /// slot was evicted or refilled since staging — benign abort, the
    /// resident narrower tier stays valid
    SlotMovedOn,
    /// staged record failed verification (torn upgrade); nothing was
    /// copied — the resident narrower tier stays valid
    Corrupt,
}

/// The Multidimensional Cache Manager (Fig 12).
///
/// Sequence records come in two flavours: `records` is the *merged* view
/// over every live sequence (the policy input — the pools are shared, so
/// eviction must see all live traffic), and `seq_records` tracks each live
/// sequence separately so that retiring one sequence removes exactly its
/// own LFU/LHU contributions instead of wiping every other sequence's
/// signals (the batch-1 `reset_sequence` behaviour, which corrupts
/// concurrent sequences).
pub struct CacheManager {
    pub hi: CachePool,
    pub lo: CachePool,
    pub records: Records,
    /// per-live-sequence records, keyed by scheduler sequence id
    seq_records: HashMap<u64, Records>,
    pub policy: Policy,
    pub stats: CacheStats,
    n_layers: u32,
    experts_per_layer: u32,
    /// miss-penalty ratio B_l/B_h of the active precision pair
    penalty_ratio: f64,
    /// hot-expert replica budget per pool (0 = replication off)
    max_replicas: usize,
}

impl CacheManager {
    pub fn new(
        n_layers: u32,
        experts_per_layer: u32,
        hi_capacity: usize,
        hi_slot_bytes: usize,
        lo_capacity: usize,
        lo_slot_bytes: usize,
        policy: Policy,
        penalty_ratio: f64,
    ) -> Self {
        Self {
            hi: CachePool::new(hi_capacity, hi_slot_bytes),
            lo: CachePool::new(lo_capacity, lo_slot_bytes),
            records: Records::new(n_layers, experts_per_layer),
            seq_records: HashMap::new(),
            policy,
            stats: CacheStats::default(),
            n_layers,
            experts_per_layer,
            penalty_ratio,
            max_replicas: 0,
        }
    }

    /// Set the per-pool hot-expert replica budget (0 disables replication
    /// — the default, so existing callers see unchanged behaviour).
    pub fn set_max_replicas(&mut self, n: usize) {
        self.max_replicas = n;
    }

    pub fn max_replicas(&self) -> usize {
        self.max_replicas
    }

    /// Populate one read-replica of a hot Ready primary, within budget.
    /// Replicas fill only Free slots (never evict, never fetch over the
    /// link), so they can't change hit/miss behaviour — only contention.
    pub fn add_replica(&mut self, key: ExpertKey, pool: Pool) -> bool {
        if self.max_replicas == 0 || self.pool(pool).total_replicas() >= self.max_replicas {
            return false;
        }
        let ok = self.pool_mut(pool).add_replica(key);
        if ok {
            self.stats.replicas_created += 1;
        }
        ok
    }

    /// Snapshot read source for a Ready `key`: rotates across primary +
    /// replicas ([`CachePool::buffer_tier_rotated`]) and counts replica-
    /// served reads. Callers clone (tier, bytes) under the one cache lock,
    /// exactly as with [`CachePool::buffer_tier`].
    pub fn read_buffer_tier(
        &mut self,
        key: ExpertKey,
        pool: Pool,
    ) -> Option<(Arc<Mutex<Vec<u8>>>, Option<Precision>)> {
        let (buf, tier, replica) = self.pool_mut(pool).buffer_tier_rotated(key)?;
        if replica {
            self.stats.replica_hits += 1;
        }
        Some((buf, tier))
    }

    fn pool(&self, p: Pool) -> &CachePool {
        match p {
            Pool::Hi => &self.hi,
            Pool::Lo => &self.lo,
        }
    }

    fn pool_mut(&mut self, p: Pool) -> &mut CachePool {
        match p {
            Pool::Hi => &mut self.hi,
            Pool::Lo => &mut self.lo,
        }
    }

    /// Probe without accounting (used by the predictor).
    pub fn contains(&self, key: ExpertKey, pool: Pool) -> bool {
        self.pool(pool).contains_ready(key) || self.pool(pool).is_loading(key)
    }

    /// Probe for an on-demand access, with hit/miss/penalty accounting.
    /// A hit in either requested precision counts; `pool` is the precision
    /// the loader *wants* for this access.
    pub fn access(&mut self, key: ExpertKey, pool: Pool) -> bool {
        let hit = self.pool(pool).contains_ready(key);
        match (pool, hit) {
            (Pool::Hi, true) => self.stats.hits_hi += 1,
            (Pool::Lo, true) => self.stats.hits_lo += 1,
            (Pool::Hi, false) => {
                self.stats.misses_hi += 1;
                self.stats.miss_penalty += 1.0;
            }
            (Pool::Lo, false) => {
                self.stats.misses_lo += 1;
                self.stats.miss_penalty += self.penalty_ratio;
            }
        }
        hit
    }

    /// Record a use (hit path or after load completes).
    pub fn note_use(&mut self, key: ExpertKey, pool: Pool) {
        self.note_use_for(key, pool, None);
    }

    /// Record a use attributed to a live sequence (interleaved serving).
    /// `None` updates only the merged view (the batch-1 path).
    pub fn note_use_for(&mut self, key: ExpertKey, pool: Pool, seq: Option<u64>) {
        self.records.note_use(key, pool == Pool::Hi);
        if let Some(s) = seq {
            if let Some(r) = self.seq_records.get_mut(&s) {
                r.note_use(key, pool == Pool::Hi);
            }
        }
    }

    /// Advance the token tick, attributed to a live sequence. The merged
    /// tick advances on every call — recency is global when the pools are
    /// shared — while the per-sequence tick advances only for `seq`.
    pub fn note_token_for(&mut self, seq: Option<u64>) {
        self.records.note_token();
        if let Some(s) = seq {
            if let Some(r) = self.seq_records.get_mut(&s) {
                r.note_token();
            }
        }
    }

    /// Register a new live sequence (interleaved serving). Unlike
    /// [`Self::reset_sequence`] this does NOT touch other sequences'
    /// records — starting sequence B must not erase sequence A's LRU/LFU/
    /// LHU signals while A is still decoding.
    pub fn begin_sequence_id(&mut self, seq: u64) {
        self.seq_records
            .insert(seq, Records::new(self.n_layers, self.experts_per_layer));
    }

    /// Retire a live sequence: subtract exactly its LFU/LHU contributions
    /// from the merged view (model-level frequency is never reset, recency
    /// is global). When the last live sequence retires, the merged records
    /// reset fully — equivalent to the paper's per-sequence reset.
    pub fn end_sequence_id(&mut self, seq: u64) {
        if let Some(r) = self.seq_records.remove(&seq) {
            for i in 0..r.freq.len() {
                self.records.freq[i] = self.records.freq[i].saturating_sub(r.freq[i]);
                self.records.hi_freq[i] =
                    self.records.hi_freq[i].saturating_sub(r.hi_freq[i]);
            }
        }
        if self.seq_records.is_empty() {
            self.records.reset_sequence();
        }
    }

    /// Number of live (registered) sequences.
    pub fn live_sequences(&self) -> usize {
        self.seq_records.len()
    }

    /// Per-sequence records of a live sequence, if registered.
    pub fn sequence_records(&self, seq: u64) -> Option<&Records> {
        self.seq_records.get(&seq)
    }

    /// Reserve a slot for `key` in `pool`, evicting the lowest-priority
    /// victim if full (Eq. 3). Returns None when every slot is pinned or
    /// mid-load — callers then bypass the cache.
    pub fn reserve(&mut self, key: ExpertKey, pool: Pool, current_layer: u32) -> Option<Reservation> {
        if self.pool(pool).contains_ready(key) || self.pool(pool).is_loading(key) {
            return None; // already present/incoming
        }
        let n_layers = self.n_layers;
        // find a free slot first; replicas are the next victim class —
        // reclaiming one costs nothing (the primary still serves reads) —
        // and only then does the policy pick a primary to evict
        let free = self.pool(pool).state.iter().position(|s| *s == SlotState::Free);
        let (slot, evicted) = if let Some(s) = free {
            (s, None)
        } else if let Some(s) = self.pool_mut(pool).evict_one_replica() {
            self.stats.replica_evictions += 1;
            (s, None)
        } else {
            let victim = self.choose_victim(pool, current_layer)?;
            let p = self.pool_mut(pool);
            let vslot = p.map[&victim];
            p.map.remove(&victim);
            let dropped = p.drop_replicas(victim);
            self.stats.evictions += 1;
            self.stats.replica_evictions += dropped as u64;
            (vslot, Some(victim))
        };
        let _ = n_layers;
        let p = self.pool_mut(pool);
        p.state[slot] = SlotState::Loading(key);
        p.tiers[slot] = None;
        p.map.insert(key, slot);
        Some(Reservation { slot, buffer: p.buffers[slot].clone(), evicted })
    }

    /// Mark a reserved slot as filled and readable at the pool's native
    /// precision (the pre-progressive contract).
    pub fn commit(&mut self, key: ExpertKey, pool: Pool) {
        self.commit_tier(key, pool, None);
    }

    /// Mark a reserved slot as filled and readable, recording the tier of
    /// the bytes it holds (`None` = pool native). A progressive lo-first
    /// load commits its floor precision here; the slot becomes usable
    /// immediately, at that tier.
    pub fn commit_tier(&mut self, key: ExpertKey, pool: Pool, tier: Option<Precision>) {
        let p = self.pool_mut(pool);
        if let Some(&slot) = p.map.get(&key) {
            debug_assert_eq!(p.state[slot], SlotState::Loading(key));
            p.state[slot] = SlotState::Ready(key);
            p.tiers[slot] = tier;
        }
    }

    /// [`Self::commit_tier`] with commit-time checksum verification: the
    /// tier-crossing boundary where a chunked (possibly preempted-and-
    /// resumed) transfer becomes servable. `expected` is the record's
    /// `(fnv1a64, byte length)`; verification reads the slot's first
    /// `len` bytes under the slot lock, after every chunk has landed — so
    /// a bit flipped in *any* chunk of the transfer is caught here. On
    /// mismatch the slot is quarantined: scrubbed, freed, never Ready —
    /// the caller re-acquires from a clean source. `None` skips
    /// verification (records with no known checksum, e.g. sim fills).
    pub fn commit_tier_verified(
        &mut self,
        key: ExpertKey,
        pool: Pool,
        tier: Option<Precision>,
        expected: Option<(u64, usize)>,
    ) -> CommitOutcome {
        if let Some((sum, len)) = expected {
            let p = self.pool_mut(pool);
            if let Some(&slot) = p.map.get(&key) {
                if p.state[slot] == SlotState::Loading(key) {
                    let mut buf = p.buffers[slot].lock().unwrap();
                    let n = len.min(buf.len());
                    if n != len || crate::util::checksum::fnv1a64(&buf[..n]) != sum {
                        buf.fill(0);
                        drop(buf);
                        p.state[slot] = SlotState::Free;
                        p.tiers[slot] = None;
                        p.map.remove(&key);
                        // quarantine invalidates replicas atomically too
                        let dropped = p.drop_replicas(key);
                        self.stats.replica_evictions += dropped as u64;
                        return CommitOutcome::Corrupt;
                    }
                }
            }
        }
        self.commit_tier(key, pool, tier);
        CommitOutcome::Committed
    }

    /// [`Self::commit_upgrade`] with checksum verification of the staged
    /// record *before* any byte touches the live slot — a torn upgrade
    /// must never replace valid narrow-tier bytes with corrupt wide-tier
    /// ones. The lo record already resident and the hi record staged here
    /// are verified independently (each against its own tier's checksum).
    pub fn commit_upgrade_verified(
        &mut self,
        key: ExpertKey,
        pool: Pool,
        tier: Option<Precision>,
        record: &[u8],
        expected: Option<u64>,
    ) -> UpgradeCommit {
        if let Some(sum) = expected {
            if crate::util::checksum::fnv1a64(record) != sum {
                return UpgradeCommit::Corrupt;
            }
        }
        if self.commit_upgrade(key, pool, tier, record) {
            UpgradeCommit::Committed
        } else {
            UpgradeCommit::SlotMovedOn
        }
    }

    /// Atomically upgrade a READY slot's bytes in place: copy the fully
    /// staged `record` (streamed into private memory off the critical
    /// path) into the slot buffer and flip the tier, all under the one
    /// cache lock the caller holds — readers clone (tier, bytes) under the
    /// same lock, so they observe either the old tier with the old bytes
    /// or the new tier with the new bytes, never a mix. Returns false —
    /// and changes nothing — when the slot is no longer `Ready(key)` (it
    /// was evicted or is being refilled): the upgrade aborts and whatever
    /// tier is resident stays valid. In-flight compute is never
    /// invalidated either way, because executors clone the record bytes
    /// out before computing.
    pub fn commit_upgrade(
        &mut self,
        key: ExpertKey,
        pool: Pool,
        tier: Option<Precision>,
        record: &[u8],
    ) -> bool {
        let p = self.pool_mut(pool);
        let Some(&slot) = p.map.get(&key) else { return false };
        if p.state[slot] != SlotState::Ready(key) {
            return false;
        }
        let mut buf = p.buffers[slot].lock().unwrap();
        debug_assert!(buf.len() >= record.len(), "upgrade record exceeds slot");
        buf[..record.len()].copy_from_slice(record);
        drop(buf);
        p.tiers[slot] = tier;
        // replicas hold the pre-upgrade tier: invalidate them under this
        // same lock so no reader rotates onto stale bytes
        let dropped = p.drop_replicas(key);
        self.stats.replica_evictions += dropped as u64;
        true
    }

    /// Abort a reservation (load failed / cancelled before starting).
    pub fn abort(&mut self, key: ExpertKey, pool: Pool) {
        let p = self.pool_mut(pool);
        if let Some(&slot) = p.map.get(&key) {
            if p.state[slot] == SlotState::Loading(key) {
                p.state[slot] = SlotState::Free;
                p.map.remove(&key);
                // a Loading key cannot have replicas (they require a Ready
                // primary, and re-reserving evicts the old primary first),
                // but drop defensively so an orphan can never be served
                let dropped = p.drop_replicas(key);
                self.stats.replica_evictions += dropped as u64;
            }
        }
    }

    fn choose_victim(&self, pool: Pool, current_layer: u32) -> Option<ExpertKey> {
        let p = self.pool(pool);
        let mut best: Option<(f64, ExpertKey)> = None;
        for key in p.ready_keys() {
            // pinned entries are eviction-proof: a pin marks an expert the
            // predictor promised (or the engine is reading) — evicting it
            // would silently invalidate the promise. With every slot
            // pinned, `reserve` returns None and callers bypass the cache.
            if p.pinned.contains_key(&key) {
                continue;
            }
            let prio = self.policy.priority(&self.records, key, current_layer, self.n_layers);
            if best.map(|(b, _)| prio < b).unwrap_or(true) {
                best = Some((prio, key));
            }
        }
        best.map(|(_, k)| k)
    }

    /// New sequence: reset seq-level records (§3.4, the batch-1 path).
    /// Also drops any registered live-sequence records — callers mixing the
    /// two APIs get a clean slate.
    pub fn reset_sequence(&mut self) {
        self.records.reset_sequence();
        self.seq_records.clear();
    }

    pub fn penalty_ratio(&self) -> f64 {
        self.penalty_ratio
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(hi: usize, lo: usize) -> CacheManager {
        CacheManager::new(4, 4, hi, 8, lo, 4, Policy::Lru, 0.25)
    }

    fn k(layer: u32, expert: u32) -> ExpertKey {
        ExpertKey::new(layer, expert)
    }

    #[test]
    fn insert_commit_lookup() {
        let mut m = mgr(2, 2);
        let r = m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        assert!(r.evicted.is_none());
        assert!(!m.hi.contains_ready(k(0, 0)));
        assert!(m.hi.is_loading(k(0, 0)));
        m.commit(k(0, 0), Pool::Hi);
        assert!(m.hi.contains_ready(k(0, 0)));
        assert!(m.hi.buffer(k(0, 0)).is_some());
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut m = mgr(2, 0);
        for e in 0..2 {
            m.reserve(k(0, e), Pool::Hi, 0).unwrap();
            m.commit(k(0, e), Pool::Hi);
        }
        m.records.note_token();
        m.note_use(k(0, 1), Pool::Hi); // expert 1 recently used
        let r = m.reserve(k(0, 2), Pool::Hi, 0).unwrap();
        assert_eq!(r.evicted, Some(k(0, 0)));
    }

    #[test]
    fn pinned_survive_eviction() {
        let mut m = mgr(2, 0);
        for e in 0..2 {
            m.reserve(k(0, e), Pool::Hi, 0).unwrap();
            m.commit(k(0, e), Pool::Hi);
        }
        m.hi.pin(k(0, 0));
        m.records.note_token();
        m.note_use(k(0, 0), Pool::Hi);
        m.note_use(k(0, 1), Pool::Hi);
        // expert 0 is pinned; victim must be 1 even under equal recency
        let r = m.reserve(k(0, 2), Pool::Hi, 0).unwrap();
        assert_eq!(r.evicted, Some(k(0, 1)));
    }

    #[test]
    fn access_accounts_penalty() {
        let mut m = mgr(1, 1);
        assert!(!m.access(k(0, 0), Pool::Hi));
        assert!(!m.access(k(0, 1), Pool::Lo));
        assert_eq!(m.stats.misses_hi, 1);
        assert_eq!(m.stats.misses_lo, 1);
        assert!((m.stats.miss_penalty - 1.25).abs() < 1e-12);
        m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        m.commit(k(0, 0), Pool::Hi);
        assert!(m.access(k(0, 0), Pool::Hi));
        assert_eq!(m.stats.hits_hi, 1);
    }

    #[test]
    fn reset_sequence_clears_records() {
        let mut m = mgr(1, 1);
        m.records.note_token();
        m.note_use(k(1, 2), Pool::Hi);
        assert_eq!(m.records.freq[m.records.idx(k(1, 2))], 1);
        m.reset_sequence();
        assert_eq!(m.records.freq[m.records.idx(k(1, 2))], 0);
        assert_eq!(m.records.token, 0);
        // model-level record survives (Fig 18b)
        assert_eq!(m.records.model_freq[m.records.idx(k(1, 2))], 1);
    }

    #[test]
    fn abort_frees_slot() {
        let mut m = mgr(1, 0);
        m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        m.abort(k(0, 0), Pool::Hi);
        assert!(!m.hi.is_loading(k(0, 0)));
        assert!(m.reserve(k(0, 1), Pool::Hi, 0).is_some());
    }

    #[test]
    fn double_reserve_returns_none() {
        let mut m = mgr(2, 0);
        assert!(m.reserve(k(0, 0), Pool::Hi, 0).is_some());
        assert!(m.reserve(k(0, 0), Pool::Hi, 0).is_none());
    }

    #[test]
    fn reserve_returns_none_when_every_slot_pinned() {
        // regression: choose_victim used to fall back to evicting pinned
        // experts, silently invalidating predictor pins
        let mut m = mgr(2, 0);
        for e in 0..2 {
            m.reserve(k(0, e), Pool::Hi, 0).unwrap();
            m.commit(k(0, e), Pool::Hi);
            m.hi.pin(k(0, e));
        }
        assert!(m.reserve(k(0, 2), Pool::Hi, 0).is_none(), "pinned slot evicted");
        assert!(m.hi.contains_ready(k(0, 0)) && m.hi.contains_ready(k(0, 1)));
        // releasing one pin makes that slot the only legal victim again
        m.hi.unpin(k(0, 0));
        let r = m.reserve(k(0, 2), Pool::Hi, 0).unwrap();
        assert_eq!(r.evicted, Some(k(0, 0)));
    }

    #[test]
    fn pinned_count_tracks_stacked_pins() {
        let mut m = mgr(2, 0);
        assert_eq!(m.hi.pinned_count(), 0);
        m.hi.pin(k(0, 0));
        m.hi.pin(k(0, 0));
        m.hi.pin(k(0, 1));
        assert_eq!(m.hi.pinned_count(), 3);
        m.hi.unpin(k(0, 0));
        assert_eq!(m.hi.pinned_count(), 2);
        m.hi.unpin(k(0, 0));
        m.hi.unpin(k(0, 1));
        assert_eq!(m.hi.pinned_count(), 0);
    }

    #[test]
    fn pin_unpin_report_slot_presence_and_balance() {
        let mut m = mgr(1, 1);
        // pinning ahead of the load is legal but reports no live slot yet
        assert!(!m.hi.pin(k(0, 0)));
        assert!(m.hi.unpin(k(0, 0)));
        m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        m.commit(k(0, 0), Pool::Hi);
        assert!(m.hi.pin(k(0, 0)), "pin of a resident key must see its slot");
        assert!(m.hi.unpin(k(0, 0)));
        // unbalanced unpin reports false instead of silently no-op'ing
        assert!(!m.hi.unpin(k(0, 0)));
        // mis-keyed pool: no pin there either
        assert!(!m.lo.unpin(k(0, 0)));
    }

    #[test]
    fn live_sequences_do_not_clobber_each_other() {
        // regression: with two live sequences, starting (or resetting for)
        // sequence B used to wipe sequence A's LRU/LFU/LHU records
        let mut m = mgr(4, 4);
        m.begin_sequence_id(1);
        m.note_token_for(Some(1));
        m.note_use_for(k(0, 0), Pool::Hi, Some(1));
        m.begin_sequence_id(2);
        m.note_token_for(Some(2));
        m.note_use_for(k(0, 1), Pool::Hi, Some(2));
        assert_eq!(m.live_sequences(), 2);
        // A's merged signals survive B's arrival and traffic
        let ia = m.records.idx(k(0, 0));
        let ib = m.records.idx(k(0, 1));
        assert_eq!(m.records.freq[ia], 1);
        assert_eq!(m.records.hi_freq[ia], 1);
        assert_eq!(m.records.freq[ib], 1);
        // per-sequence views are isolated
        assert_eq!(m.sequence_records(1).unwrap().freq[ia], 1);
        assert_eq!(m.sequence_records(1).unwrap().freq[ib], 0);
        assert_eq!(m.sequence_records(2).unwrap().freq[ib], 1);
        // retiring A subtracts exactly A's contributions
        m.end_sequence_id(1);
        assert_eq!(m.records.freq[ia], 0);
        assert_eq!(m.records.freq[ib], 1);
        // model-level frequency is never reset (Fig 18b)
        assert_eq!(m.records.model_freq[ia], 1);
        // last live sequence retiring resets the merged view entirely
        m.end_sequence_id(2);
        assert_eq!(m.records.freq[ib], 0);
        assert_eq!(m.records.token, 0);
        assert_eq!(m.records.model_freq[ib], 1);
    }

    #[test]
    fn tier_lifecycle_commit_upgrade_and_abort() {
        let mut m = mgr(1, 0);
        let r = m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        r.buffer.lock().unwrap().fill(0x11);
        m.commit_tier(k(0, 0), Pool::Hi, Some(Precision::Q8));
        assert_eq!(m.hi.resident_tier(k(0, 0)), Some(Some(Precision::Q8)));
        let (_, tier) = m.hi.buffer_tier(k(0, 0)).unwrap();
        assert_eq!(tier, Some(Precision::Q8));
        // in-place upgrade to the pool's native tier flips bytes + tier
        let hi_bytes = vec![0x22u8; 8];
        assert!(m.commit_upgrade(k(0, 0), Pool::Hi, None, &hi_bytes));
        assert_eq!(m.hi.resident_tier(k(0, 0)), Some(None));
        let (buf, _) = m.hi.buffer_tier(k(0, 0)).unwrap();
        assert_eq!(&buf.lock().unwrap()[..8], &hi_bytes[..]);
        // evicted slot: upgrade aborts, the new occupant is untouched
        let r = m.reserve(k(0, 1), Pool::Hi, 0).unwrap();
        assert_eq!(r.evicted, Some(k(0, 0)));
        assert!(!m.commit_upgrade(k(0, 0), Pool::Hi, None, &hi_bytes));
        // a slot mid-refill (Loading) also refuses the stale upgrade
        assert!(!m.commit_upgrade(k(0, 1), Pool::Hi, None, &hi_bytes));
        m.commit(k(0, 1), Pool::Hi);
        // reserve reset the tier for the new occupant
        assert_eq!(m.hi.resident_tier(k(0, 1)), Some(None));
    }

    #[test]
    fn verified_commit_quarantines_corrupt_slots() {
        use crate::util::checksum::fnv1a64;
        let mut m = mgr(1, 0);
        let good = [0x5au8; 8];
        let sum = fnv1a64(&good);
        // clean landing commits
        let r = m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        r.buffer.lock().unwrap().copy_from_slice(&good);
        let out = m.commit_tier_verified(k(0, 0), Pool::Hi, None, Some((sum, 8)));
        assert_eq!(out, CommitOutcome::Committed);
        assert!(m.hi.contains_ready(k(0, 0)));
        // corrupt landing: slot scrubbed, freed, never Ready
        let r = m.reserve(k(0, 1), Pool::Hi, 0).unwrap();
        assert_eq!(r.evicted, Some(k(0, 0)));
        let mut bad = good;
        bad[3] ^= 0x04; // one flipped bit
        r.buffer.lock().unwrap().copy_from_slice(&bad);
        let out = m.commit_tier_verified(k(0, 1), Pool::Hi, None, Some((sum, 8)));
        assert_eq!(out, CommitOutcome::Corrupt);
        assert!(!m.hi.contains_ready(k(0, 1)));
        assert!(!m.hi.is_loading(k(0, 1)));
        assert_eq!(&*r.buffer.lock().unwrap(), &[0u8; 8], "quarantined slot scrubbed");
        // the freed slot is immediately reusable
        assert!(m.reserve(k(0, 2), Pool::Hi, 0).is_some());
        // a record longer than its slot can never verify
        let mut m = mgr(1, 0);
        m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        let out = m.commit_tier_verified(k(0, 0), Pool::Hi, None, Some((sum, 9)));
        assert_eq!(out, CommitOutcome::Corrupt);
    }

    #[test]
    fn verified_upgrade_refuses_torn_records() {
        use crate::util::checksum::fnv1a64;
        let mut m = mgr(1, 0);
        let lo = [0x11u8; 4];
        let hi = [0x22u8; 8];
        let r = m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        r.buffer.lock().unwrap()[..4].copy_from_slice(&lo);
        m.commit_tier(k(0, 0), Pool::Hi, Some(Precision::Q8));
        // torn staged record: nothing copied, lo tier stays resident
        let mut torn = hi;
        torn[5] ^= 0x80;
        let out =
            m.commit_upgrade_verified(k(0, 0), Pool::Hi, None, &torn, Some(fnv1a64(&hi)));
        assert_eq!(out, UpgradeCommit::Corrupt);
        assert_eq!(m.hi.resident_tier(k(0, 0)), Some(Some(Precision::Q8)));
        assert_eq!(&r.buffer.lock().unwrap()[..4], &lo[..], "lo bytes untouched");
        // intact staged record lands
        let out = m.commit_upgrade_verified(k(0, 0), Pool::Hi, None, &hi, Some(fnv1a64(&hi)));
        assert_eq!(out, UpgradeCommit::Committed);
        assert_eq!(m.hi.resident_tier(k(0, 0)), Some(None));
        assert_eq!(&*r.buffer.lock().unwrap(), &hi[..]);
        // evicted slot reports the benign abort, not corruption
        let r2 = m.reserve(k(0, 1), Pool::Hi, 0).unwrap();
        assert_eq!(r2.evicted, Some(k(0, 0)));
        let out = m.commit_upgrade_verified(k(0, 0), Pool::Hi, None, &hi, Some(fnv1a64(&hi)));
        assert_eq!(out, UpgradeCommit::SlotMovedOn);
    }

    #[test]
    fn replicas_rotate_reads_and_evict_first() {
        let mut m = mgr(3, 0);
        m.set_max_replicas(2);
        let r = m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        r.buffer.lock().unwrap().fill(0x7f);
        m.commit(k(0, 0), Pool::Hi);
        assert!(m.add_replica(k(0, 0), Pool::Hi));
        assert_eq!(m.hi.replica_count(k(0, 0)), 1);
        assert_eq!(m.stats.replicas_created, 1);
        // rotation: primary first, then the replica (same bytes + tier)
        let _ = m.read_buffer_tier(k(0, 0), Pool::Hi).unwrap();
        assert_eq!(m.stats.replica_hits, 0);
        let (buf, tier) = m.read_buffer_tier(k(0, 0), Pool::Hi).unwrap();
        assert_eq!(tier, None);
        assert_eq!(&*buf.lock().unwrap(), &[0x7f; 8]);
        assert_eq!(m.stats.replica_hits, 1);
        // filling the pool reclaims the replica before any primary
        m.reserve(k(0, 1), Pool::Hi, 0).unwrap();
        m.commit(k(0, 1), Pool::Hi);
        let r = m.reserve(k(0, 2), Pool::Hi, 0).unwrap();
        assert!(r.evicted.is_none(), "replica reclaimed, no primary evicted");
        assert_eq!(m.hi.replica_count(k(0, 0)), 0);
        assert_eq!(m.stats.replica_evictions, 1);
        assert_eq!(m.stats.evictions, 0);
    }

    #[test]
    fn replica_budget_and_free_slot_requirement() {
        let mut m = mgr(2, 0);
        m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        m.commit(k(0, 0), Pool::Hi);
        // budget 0 (the default): replication is off
        assert!(!m.add_replica(k(0, 0), Pool::Hi));
        m.set_max_replicas(1);
        assert!(m.add_replica(k(0, 0), Pool::Hi));
        // per-pool budget reached
        assert!(!m.add_replica(k(0, 0), Pool::Hi));
        m.set_max_replicas(8);
        // no free slot left either: replicas never evict to make room
        assert!(!m.add_replica(k(0, 0), Pool::Hi));
        // a non-resident key can't be replicated
        assert!(!m.add_replica(k(0, 3), Pool::Hi));
    }

    #[test]
    fn upgrade_and_eviction_invalidate_replicas() {
        let mut m = mgr(3, 0);
        m.set_max_replicas(2);
        let r = m.reserve(k(0, 0), Pool::Hi, 0).unwrap();
        r.buffer.lock().unwrap()[..4].fill(0x11);
        m.commit_tier(k(0, 0), Pool::Hi, Some(Precision::Q8));
        assert!(m.add_replica(k(0, 0), Pool::Hi));
        // in-place upgrade of the primary drops its replicas atomically —
        // a rotated read must never see the pre-upgrade tier
        assert!(m.commit_upgrade(k(0, 0), Pool::Hi, None, &[0x22u8; 8]));
        assert_eq!(m.hi.replica_count(k(0, 0)), 0);
        assert_eq!(m.stats.replica_evictions, 1);
        let (buf, tier) = m.read_buffer_tier(k(0, 0), Pool::Hi).unwrap();
        assert_eq!(tier, None);
        assert_eq!(&*buf.lock().unwrap(), &[0x22u8; 8]);
        // reserve pressure reclaims the replica slot, never a primary,
        // and an evicted key's reads stop resolving entirely
        assert!(m.add_replica(k(0, 0), Pool::Hi));
        let r = m.reserve(k(0, 1), Pool::Hi, 0).unwrap();
        assert!(r.evicted.is_none(), "free slot first");
        m.commit(k(0, 1), Pool::Hi);
        let r = m.reserve(k(0, 2), Pool::Hi, 0).unwrap();
        assert!(r.evicted.is_none(), "replica slot reclaimed before any primary");
        assert_eq!(m.hi.replica_count(k(0, 0)), 0);
        assert!(m.read_buffer_tier(k(0, 3), Pool::Hi).is_none());
    }

    #[test]
    fn merged_tick_is_global_per_sequence_tick_is_local() {
        let mut m = mgr(2, 2);
        m.begin_sequence_id(7);
        m.begin_sequence_id(8);
        m.note_token_for(Some(7));
        m.note_token_for(Some(7));
        m.note_token_for(Some(8));
        assert_eq!(m.records.token, 3);
        assert_eq!(m.sequence_records(7).unwrap().token, 2);
        assert_eq!(m.sequence_records(8).unwrap().token, 1);
    }
}
