//! Replacement policies. `priority` returns "keep-worthiness": the victim
//! is the *lowest* priority Ready, unpinned entry. The Multidimensional
//! policy implements Eq. 3 exactly; the single-strategy policies exist as
//! the paper's comparison baselines (Fig 18) and as degenerate weight
//! settings of the blend.

use super::Records;
use crate::ExpertKey;

#[derive(Debug, Clone)]
pub enum Policy {
    /// uniform-random victim (the normalization baseline of Fig 18a)
    Random { seed: u64 },
    Lru,
    /// sequence-level LFU (records reset per sequence)
    LfuSeq,
    /// model-level LFU (never reset — the Fig 18b comparison)
    LfuModel,
    /// least high-precision frequently used (the paper's novel dimension)
    Lhu,
    /// farthest layer distance
    Fld,
    /// Eq. 3 weighted blend [w_lru, w_lfu, w_lhu, w_fld]
    Multidim { w: [f64; 4] },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Random { .. } => "random",
            Policy::Lru => "lru",
            Policy::LfuSeq => "lfu-seq",
            Policy::LfuModel => "lfu-model",
            Policy::Lhu => "lhu",
            Policy::Fld => "fld",
            Policy::Multidim { .. } => "multidim",
        }
    }

    pub fn from_name(s: &str, w: [f64; 4]) -> Option<Policy> {
        match s {
            "random" => Some(Policy::Random { seed: 0 }),
            "lru" => Some(Policy::Lru),
            "lfu" | "lfu-seq" => Some(Policy::LfuSeq),
            "lfu-model" => Some(Policy::LfuModel),
            "lhu" => Some(Policy::Lhu),
            "fld" => Some(Policy::Fld),
            "multidim" | "hobbit" => Some(Policy::Multidim { w }),
            _ => None,
        }
    }

    /// Keep-priority of `key` given the records and the layer currently
    /// being executed (`l_i` in Eq. 3). Higher = more worth keeping.
    pub fn priority(&self, rec: &Records, key: ExpertKey, current_layer: u32, n_layers: u32) -> f64 {
        let i = rec.idx(key);
        let t = rec.token.max(1) as f64;
        let lru = rec.last_used[i] as f64 / t;
        let lfu = rec.freq[i] as f64 / t;
        let lhu = rec.hi_freq[i] as f64 / t;
        let fld = fld_term(key.layer, current_layer, n_layers);
        match self {
            Policy::Random { seed } => {
                // stable pseudo-random priority per (key, token) so ties
                // break uniformly without carrying RNG state
                let mut h = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ rec.token;
                h ^= h >> 33;
                h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
                h ^= h >> 33;
                (h as f64) / (u64::MAX as f64)
            }
            Policy::Lru => lru,
            Policy::LfuSeq => lfu,
            Policy::LfuModel => {
                let total: u64 = rec.model_freq.iter().sum();
                rec.model_freq[i] as f64 / (total.max(1) as f64)
            }
            Policy::Lhu => lhu,
            Policy::Fld => fld,
            Policy::Multidim { w } => w[0] * lru + w[1] * lfu + w[2] * lhu + w[3] * fld,
        }
    }
}

/// `1 - ((l_t - l_i + l_n) % l_n) / l_n` — experts in layers just ahead of
/// the current layer score high; the layer just behind scores lowest.
pub fn fld_term(expert_layer: u32, current_layer: u32, n_layers: u32) -> f64 {
    let ln = n_layers as i64;
    let dist = ((expert_layer as i64 - current_layer as i64) + ln) % ln;
    1.0 - dist as f64 / ln as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fld_prefers_near_future_layers() {
        let n = 8;
        // current layer 3: layer 4 is next (dist 1), layer 2 is farthest ahead (dist 7)
        let next = fld_term(4, 3, n);
        let prev = fld_term(2, 3, n);
        let same = fld_term(3, 3, n);
        assert!(same > next && next > prev);
        assert!((same - 1.0).abs() < 1e-12);
    }

    #[test]
    fn multidim_reduces_to_components() {
        let mut rec = Records::new(2, 4);
        rec.note_token();
        rec.note_token();
        let k = ExpertKey::new(0, 1);
        rec.note_use(k, true);
        let full = Policy::Multidim { w: [1.0, 0.0, 0.0, 0.0] };
        assert!(
            (full.priority(&rec, k, 0, 2) - Policy::Lru.priority(&rec, k, 0, 2)).abs() < 1e-12
        );
        let fld = Policy::Multidim { w: [0.0, 0.0, 0.0, 1.0] };
        assert!(
            (fld.priority(&rec, k, 0, 2) - Policy::Fld.priority(&rec, k, 0, 2)).abs() < 1e-12
        );
    }

    #[test]
    fn lhu_distinguishes_from_lfu() {
        let mut rec = Records::new(1, 4);
        rec.note_token();
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        // a: used 3x, never in high precision; b: used 2x, always high
        for _ in 0..3 {
            rec.note_use(a, false);
        }
        for _ in 0..2 {
            rec.note_use(b, true);
        }
        assert!(Policy::LfuSeq.priority(&rec, a, 0, 1) > Policy::LfuSeq.priority(&rec, b, 0, 1));
        assert!(Policy::Lhu.priority(&rec, b, 0, 1) > Policy::Lhu.priority(&rec, a, 0, 1));
    }

    #[test]
    fn random_is_deterministic_per_token() {
        let rec = Records::new(1, 4);
        let p = Policy::Random { seed: 7 };
        let k = ExpertKey::new(0, 2);
        assert_eq!(p.priority(&rec, k, 0, 1), p.priority(&rec, k, 0, 1));
    }

    #[test]
    fn names_roundtrip() {
        for n in ["random", "lru", "lfu", "lfu-model", "lhu", "fld", "multidim"] {
            assert!(Policy::from_name(n, [0.25; 4]).is_some(), "{n}");
        }
        assert!(Policy::from_name("nope", [0.25; 4]).is_none());
    }
}
