//! # HOBBIT — mixed-precision expert offloading for fast MoE inference
//!
//! Reproduction of *"HOBBIT: A Mixed Precision Expert Offloading System for
//! Fast MoE Inference"* (cs.LG 2024) as a three-layer Rust + JAX + Pallas
//! stack: Python/JAX authors and AOT-compiles the model (L2) and its Pallas
//! kernels (L1) to HLO text at build time; this crate (L3) loads the
//! artifacts through the PJRT C API and owns everything the paper calls the
//! *system*: the dynamic expert loader, the adaptive expert predictor, the
//! multidimensional cache manager, the memory hierarchy, and the serving
//! coordinator. Python is never on the request path.
//!
//! Module map (see DESIGN.md for the paper-section correspondence):
//!
//! * [`runtime`] — PJRT client wrapper; loads `artifacts/*.hlo.txt`.
//! * [`model`] — model/weight manifests, expert storage at all precisions.
//! * [`quant`] — group quantization (byte-compatible with
//!   `python/compile/quantize.py`).
//! * [`memory`] — the two-tier memory hierarchy and bandwidth models.
//! * [`cache`] — the sequence-level multidimensional expert cache (§3.4).
//! * [`loader`] — the token-level dynamic expert loader (§3.2).
//! * [`predictor`] — the layer-level adaptive expert prefetcher (§3.3).
//! * [`residency`] — the session-scoped facade unifying loader + cache +
//!   predictor: typed load tickets, a cross-sequence shared wait-set with
//!   dedup accounting, RAII sequence sessions, and per-sequence prefetch
//!   generations. The only API through which the engine and coordinator
//!   make experts resident.
//! * [`engine`] — the per-layer inference engine; compute units run
//!   behind an executor seam (AOT PJRT artifacts, or pure-Rust reference
//!   kernels for artifact-free testing), with three decode shapes:
//!   blocking batch-1, the suspendable per-sequence cursor, and true
//!   batched decode (one padded {2,4,8}-wide step per group with a single
//!   merged residency acquire per layer).
//! * [`coordinator`] — request routing, sequence lifecycle, generation;
//!   two scheduler modes: the paper-faithful blocking batch-1 FCFS, and an
//!   interleaved continuous scheduler that suspends a sequence at its
//!   expert-load barrier and advances other sequences' decode meanwhile —
//!   or, with `--max-batch N`, gangs runnable sequences into one batched
//!   launch and evicts rows whose loads block.
//! * [`remote`] — the remote expert tier: expert shard servers speaking
//!   the `EXPERT` line protocol, a timeout/retry TCP transport, and the
//!   tiered store extending the hierarchy to HBM ← DRAM ← peer ← disk
//!   with network bandwidth as a second link class.
//! * [`server`] — TCP serving front-end: single-threaded FCFS accept loop
//!   (`serve`) or threaded accept + per-connection readers feeding the
//!   interleaved scheduler over a channel (`serve_concurrent`).
//! * [`workload`] — open-loop trace-driven traffic harness: bursty
//!   Poisson/diurnal arrivals with heavy-tailed log-normal lengths,
//!   replayed against the interleaved coordinator under admission control
//!   (the offered load the overload ladder degrades against).
//! * [`faults`] — seeded deterministic fault injection (`--fault-plan`):
//!   reproducible bit-flips, truncated peer streams, lane stalls, and torn
//!   upgrades at every tier boundary the integrity layer guards.
//! * [`sim`] — discrete-event simulator at paper scale (figures/benches).
//! * [`baselines`] — the six comparator systems of §5.
//! * [`trace`] — gating-trace capture, synthetic generation, replay.
//! * [`figures`] — regenerates every table/figure of the paper's §5.
//! * [`util`] — offline substrates: rng, json, stats, benchkit,
//!   property-testing (the vendored crate set has no serde/criterion/rand).

pub mod baselines;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod faults;
pub mod figures;
pub mod loader;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod predictor;
pub mod quant;
pub mod remote;
pub mod residency;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tensor;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod workload;

/// Expert identity: (layer, expert index) — the unit of offloading.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertKey {
    pub layer: u32,
    pub expert: u32,
}

impl ExpertKey {
    pub fn new(layer: u32, expert: u32) -> Self {
        Self { layer, expert }
    }
    /// Dense index into per-model tables.
    pub fn index(&self, experts_per_layer: u32) -> usize {
        (self.layer * experts_per_layer + self.expert) as usize
    }
}

/// Expert precision classes. `F32` plays the paper's "fp16" role; `Q8` the
/// "int4" role (4.0x fewer bytes); `Q2` the "int2" role relative to `Q8`.
/// See DESIGN.md §Hardware-Adaptation for the mapping rationale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    F32,
    Q8,
    Q4,
    Q2,
}

impl Precision {
    pub const ALL: [Precision; 4] =
        [Precision::F32, Precision::Q8, Precision::Q4, Precision::Q2];

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Q8 => "q8",
            Precision::Q4 => "q4",
            Precision::Q2 => "q2",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(Precision::F32),
            "q8" => Some(Precision::Q8),
            "q4" => Some(Precision::Q4),
            "q2" => Some(Precision::Q2),
            _ => None,
        }
    }

    /// Bits per weight (scales excluded) — drives the `B_l/B_h` penalty
    /// ratio of §3.4.
    pub fn bits(&self) -> u32 {
        match self {
            Precision::F32 => 32,
            Precision::Q8 => 8,
            Precision::Q4 => 4,
            Precision::Q2 => 2,
        }
    }

    /// How many weights one packed byte carries (f32 is stored as 4 bytes
    /// each, so `pack` is only meaningful for quantized formats).
    pub fn pack(&self) -> usize {
        match self {
            Precision::F32 => 1,
            Precision::Q8 => 1,
            Precision::Q4 => 2,
            Precision::Q2 => 4,
        }
    }
}
