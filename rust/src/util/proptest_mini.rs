//! Minimal property-testing harness (proptest is not in the offline vendor
//! set). Runs a property over N seeded random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically with
//! `check_seeded`.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // HOBBIT_PROPTEST_CASES can crank this up for soak runs
        let cases = std::env::var("HOBBIT_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        Self { cases, seed: 0x4855_4242_4954 } // "HUBBIT"
    }
}

/// Run `prop` over `cfg.cases` generated cases. `prop` receives a fresh RNG
/// per case and returns `Err(reason)` to fail. Panics with the failing
/// case's seed on failure.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_cfg(name, Config::default(), prop)
}

pub fn check_cfg<F>(name: &str, cfg: Config, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(reason) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay seed {case_seed:#x}): {reason}"
            );
        }
    }
}

/// Replay a single failing case.
pub fn check_seeded<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(reason) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {reason}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("below is in range", |rng| {
            let n = 1 + rng.below(100);
            let x = rng.below(n);
            prop_assert!(x < n, "{x} >= {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check_cfg(
            "always fails",
            Config { cases: 1, seed: 1 },
            |_rng| Err("nope".into()),
        );
    }
}
