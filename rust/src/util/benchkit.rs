//! Tiny benchmark harness (criterion is not in the offline vendor set).
//! Used by `benches/*.rs` (harness = false) and by the figures binary.
//! Warms up, then runs timed iterations until both a minimum iteration
//! count and a minimum wall-time are reached; reports mean/p50/p99.

use std::time::Instant;

use super::stats::{summarize, Summary};

pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time_s: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, min_iters: 10, max_iters: 10_000, min_time_s: 0.5 }
    }
}

/// One benchmark result line.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn print(&self) {
        let s = &self.summary;
        println!(
            "{:<48} {:>10} {:>10} {:>10} {:>6}",
            self.name,
            fmt_t(s.mean),
            fmt_t(s.p50),
            fmt_t(s.p99),
            s.n
        );
    }
}

pub fn fmt_t(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{:.3} s", seconds)
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

pub fn header() {
    println!(
        "{:<48} {:>10} {:>10} {:>10} {:>6}",
        "benchmark", "mean", "p50", "p99", "iters"
    );
    println!("{}", "-".repeat(88));
}

/// Time `f` under the default config and print a table row.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_cfg(name, BenchConfig::default(), f)
}

pub fn bench_cfg<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < cfg.min_iters
        || (t0.elapsed().as_secs_f64() < cfg.min_time_s && samples.len() < cfg.max_iters)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult { name: name.to_string(), summary: summarize(&samples) };
    r.print();
    r
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches don't depend on unstable features).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench_cfg(
            "noop",
            BenchConfig { warmup_iters: 1, min_iters: 5, max_iters: 5, min_time_s: 0.0 },
            || {
                n = black_box(n + 1);
            },
        );
        assert_eq!(r.summary.n, 5);
        assert!(n >= 6);
    }
}
