//! Offline substrates: the vendored crate set contains only the `xla`
//! dependency closure (no rand / serde / criterion / proptest), so the
//! small pieces of those we need are implemented here.

pub mod benchkit;
pub mod checksum;
pub mod json;
pub mod proptest_mini;
pub mod rng;
pub mod stats;
