//! Small statistics helpers shared by metrics, benches and figures.

/// Summary of a sample: mean / stddev / percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: percentile_sorted(&sorted, 0.50),
        p95: percentile_sorted(&sorted, 0.95),
        p99: percentile_sorted(&sorted, 0.99),
        max: sorted[n - 1],
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return f64::NAN;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Cosine similarity of two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        dot += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_orthogonal() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[2.0, 2.0]) - 1.0).abs() < 1e-12);
    }
}
