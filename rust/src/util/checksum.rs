//! Record checksums: 64-bit FNV-1a, the integrity layer's one hash.
//!
//! FNV-1a is not cryptographic — the threat model is bit rot and torn
//! transfers, not an adversary — but it is byte-order stable, allocation
//! free, fast enough to run on every tier-crossing commit, and trivially
//! reimplemented by the Python export step (`python/compile/gen_weights.py`
//! writes the same values into `manifest.json`). All record checksums in
//! the system (manifest, shard-protocol frame field, commit verification)
//! are this function over the raw record bytes.

/// FNV-1a offset basis (64-bit).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a prime (64-bit).
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// 64-bit FNV-1a over `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Render a checksum the way manifests store it (16 lowercase hex digits,
/// zero padded — u64 does not survive a round-trip through JSON's f64
/// numbers, strings do).
pub fn to_hex(sum: u64) -> String {
    format!("{sum:016x}")
}

/// Parse a manifest-format checksum; `None` on anything but 16 hex digits.
pub fn from_hex(s: &str) -> Option<u64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn single_bit_flip_changes_sum() {
        let mut rec = vec![0u8; 4096];
        for (i, b) in rec.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let clean = fnv1a64(&rec);
        rec[1234] ^= 0x10;
        assert_ne!(clean, fnv1a64(&rec));
    }

    #[test]
    fn hex_round_trips() {
        for sum in [0u64, 1, 0xcbf2_9ce4_8422_2325, u64::MAX] {
            assert_eq!(from_hex(&to_hex(sum)), Some(sum));
        }
        assert_eq!(from_hex("xyz"), None);
        assert_eq!(from_hex("00ff"), None, "short strings rejected");
        assert_eq!(from_hex("00000000000000000"), None, "long strings rejected");
    }
}
