//! Minimal JSON: a recursive-descent parser and a writer. Covers the full
//! grammar we exchange with the Python build step (objects, arrays,
//! numbers, strings with escapes, bools, null). No serde in the offline
//! vendor set — see DESIGN.md substitutions.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `j.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- writer --------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for writer-side code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| "bad utf8")?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\"y", "d": null}, "e": true}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path(&["b", "c"]).unwrap().as_str().unwrap(), "x\"y");
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().as_f64().unwrap(), -300.0);
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"artifacts": {"attn_s1": {"file": "attn_s1.hlo.txt",
            "inputs": [{"shape": [1, 256], "dtype": "float32"}], "outputs": 3}}}"#;
        let j = Json::parse(src).unwrap();
        let a = j.path(&["artifacts", "attn_s1"]).unwrap();
        assert_eq!(a.get("outputs").unwrap().as_usize().unwrap(), 3);
        let shape = a.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
