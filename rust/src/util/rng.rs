//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core, plus the
//! handful of distributions the workload generators need.

/// xoshiro256** — fast, high-quality, reproducible across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Dirichlet sample via normalized Gamma(alpha, 1) draws
    /// (Marsaglia-Tsang for alpha >= 1, boost trick below 1).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for &a in &[0.2, 1.0, 5.0] {
            let d = r.dirichlet(a, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
