//! Configuration system: model configs (parsed from the artifact
//! manifest), hardware profiles (bandwidths / cache budgets), and policy
//! knobs (the paper's T1/T2 thresholds, cache weights, prefetch depth).
//! Everything is JSON-loadable so experiments are reproducible from files;
//! presets mirror the paper's three testbeds (Table 2).

use std::sync::Arc;
use std::time::Duration;

use crate::faults::FaultPlan;
use crate::remote::transport::RetryPolicy;
use crate::remote::ShardSpec;
use crate::util::json::Json;
use crate::Precision;

/// Model architecture (mirror of python/compile/configs.py, parsed from
/// artifacts/<model>/manifest.json).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: u32,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_experts: u32,
    pub top_k: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub quant_group: usize,
    /// On-wire expert bytes per precision (incl. scales), from the manifest.
    pub expert_bytes: [usize; 4],
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn total_experts(&self) -> usize {
        (self.n_layers * self.n_experts) as usize
    }

    pub fn bytes_for(&self, p: Precision) -> usize {
        self.expert_bytes[precision_slot(p)]
    }

    pub fn from_manifest(j: &Json) -> Result<Self, String> {
        let m = j.get("model").ok_or("manifest missing 'model'")?;
        let g = |k: &str| -> Result<f64, String> {
            m.get(k).and_then(Json::as_f64).ok_or_else(|| format!("model missing '{k}'"))
        };
        let eb = m.get("expert_bytes").ok_or("model missing expert_bytes")?;
        let mut expert_bytes = [0usize; 4];
        for p in Precision::ALL {
            expert_bytes[precision_slot(p)] = eb
                .get(p.name())
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("expert_bytes missing {}", p.name()))?;
        }
        Ok(Self {
            name: m.get("name").and_then(Json::as_str).ok_or("model missing name")?.to_string(),
            n_layers: g("n_layers")? as u32,
            d_model: g("d_model")? as usize,
            d_ff: g("d_ff")? as usize,
            n_experts: g("n_experts")? as u32,
            top_k: g("top_k")? as usize,
            n_heads: g("n_heads")? as usize,
            n_kv_heads: g("n_kv_heads")? as usize,
            vocab: g("vocab")? as usize,
            max_seq: g("max_seq")? as usize,
            quant_group: g("quant_group")? as usize,
            expert_bytes,
        })
    }

    /// Inverse of [`Self::from_manifest`]: render the shape as a
    /// manifest document (`{"model": {...}}`). A shard server started on
    /// a bare weights directory reads the model shape back from this.
    pub fn to_manifest_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut eb = BTreeMap::new();
        for p in Precision::ALL {
            eb.insert(
                p.name().to_string(),
                Json::Num(self.expert_bytes[precision_slot(p)] as f64),
            );
        }
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("n_layers".to_string(), Json::Num(self.n_layers as f64));
        m.insert("d_model".to_string(), Json::Num(self.d_model as f64));
        m.insert("d_ff".to_string(), Json::Num(self.d_ff as f64));
        m.insert("n_experts".to_string(), Json::Num(self.n_experts as f64));
        m.insert("top_k".to_string(), Json::Num(self.top_k as f64));
        m.insert("n_heads".to_string(), Json::Num(self.n_heads as f64));
        m.insert("n_kv_heads".to_string(), Json::Num(self.n_kv_heads as f64));
        m.insert("vocab".to_string(), Json::Num(self.vocab as f64));
        m.insert("max_seq".to_string(), Json::Num(self.max_seq as f64));
        m.insert("quant_group".to_string(), Json::Num(self.quant_group as f64));
        m.insert("expert_bytes".to_string(), Json::Obj(eb));
        let mut root = BTreeMap::new();
        root.insert("model".to_string(), Json::Obj(m));
        Json::Obj(root)
    }
}

pub fn precision_slot(p: Precision) -> usize {
    match p {
        Precision::F32 => 0,
        Precision::Q8 => 1,
        Precision::Q4 => 2,
        Precision::Q2 => 3,
    }
}

/// The two-tier memory hierarchy of Fig 2: expert transfers from
/// next-level memory into the expert cache, plus a compute-speed knob for
/// the simulator's baselines.
#[derive(Debug, Clone)]
pub struct HardwareConfig {
    pub name: String,
    /// bandwidth of the expert-loading link (bytes/s): PCIe for the 4090
    /// profile, SSD-bound unified memory for the Orin profile. For the
    /// *real* path this throttles the actual memcpy; the sim uses it
    /// directly.
    pub load_bw: f64,
    /// per-transfer fixed latency (s) — DMA setup / syscall cost.
    pub load_latency: f64,
    /// number of experts (high-precision units) fitting the GPU cache.
    pub hi_cache_experts: usize,
    /// number of low-precision experts fitting the low cache pool.
    pub lo_cache_experts: usize,
    /// whether the CPU-assist compute mode is available (Fig 13/15).
    pub cpu_assist: bool,
    /// CPU expert-FFN time per token (s) for the cooperative mode model.
    pub cpu_expert_time: f64,
}

impl HardwareConfig {
    /// RTX-4090-class profile, scaled for the tiny models on the real path:
    /// bandwidth chosen so expert-loading dominates like Fig 3(a) (~85%).
    pub fn rtx4090_real() -> Self {
        Self {
            name: "rtx4090-real".into(),
            load_bw: 1.5e9, // scaled: tiny experts at 1.5 GB/s ~ 45B experts at 32 GB/s
            load_latency: 30e-6,
            hi_cache_experts: 20,
            lo_cache_experts: 24,
            cpu_assist: false,
            cpu_expert_time: 5e-3,
        }
    }

    /// Jetson-Orin-class profile: SSD-bound loading, smaller cache.
    pub fn orin_real() -> Self {
        Self {
            name: "orin-real".into(),
            load_bw: 0.25e9,
            load_latency: 80e-6,
            hi_cache_experts: 12,
            lo_cache_experts: 16,
            cpu_assist: false,
            cpu_expert_time: 12e-3,
        }
    }

    /// 4090 + CPU cooperative profile (Fig 15).
    pub fn rtx4090_cpu_real() -> Self {
        Self { cpu_assist: true, name: "rtx4090+cpu-real".into(), ..Self::rtx4090_real() }
    }

    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "rtx4090" | "rtx4090-real" => Some(Self::rtx4090_real()),
            "orin" | "orin-real" => Some(Self::orin_real()),
            "rtx4090+cpu" | "rtx4090-cpu" => Some(Self::rtx4090_cpu_real()),
            _ => None,
        }
    }
}

/// Transfer-pipeline knobs: how the expert loader drives the link.
///
/// The loader executes each transfer as a sequence of `chunk_bytes`-sized
/// chunks with a preemption checkpoint between chunks (a prefetch yields
/// to pending on-demand work there), across `lanes` parallel lanes that
/// split the link's `bytes_per_s` by weighted fair share (total bandwidth
/// is conserved — see `memory::LinkArbiter`). `hobbit serve/generate`
/// expose these as `--io-lanes` / `--io-chunk-bytes`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoConfig {
    /// parallel transfer lanes sharing the link (>= 1)
    pub lanes: usize,
    /// preemption granularity: bytes copied between checkpoints (>= 1)
    pub chunk_bytes: usize,
    /// wedged-ticket watchdog: a residency wait blocked longer than this
    /// (milliseconds) on a still-unfinished load re-submits the fetch and
    /// counts a `watchdog_recovery` — a stalled I/O lane degrades latency,
    /// never availability. 0 disables the watchdog.
    pub watchdog_ms: u64,
}

impl Default for IoConfig {
    /// The chunked pipeline: 2 lanes, 256 KiB chunks — an on-demand miss
    /// behind a mispredicted in-flight prefetch waits at most one chunk
    /// instead of the whole expert (Fig 9's penalty, removed). The
    /// watchdog bound is far above any healthy transfer time for the
    /// scaled link models, so it only fires on genuinely wedged lanes.
    fn default() -> Self {
        Self { lanes: 2, chunk_bytes: 256 * 1024, watchdog_ms: 5000 }
    }
}

impl IoConfig {
    /// One lane: transfers serialize exactly like the pre-pipeline loader
    /// (chunking still bounds how long the lane is non-preemptible).
    /// The compat default of `ExpertLoader::start`/`ExpertResidency::new`.
    pub fn single_lane() -> Self {
        Self { lanes: 1, ..Self::default() }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.lanes == 0 {
            return Err("io lanes must be >= 1".into());
        }
        if self.chunk_bytes == 0 {
            return Err("io chunk bytes must be >= 1".into());
        }
        Ok(())
    }
}

/// One remote peer: its address and the expert shard it serves.
#[derive(Debug, Clone)]
pub struct PeerSpec {
    pub addr: String,
    pub shard: ShardSpec,
}

/// The remote expert tier (`--peers` / `--shard` / `--net-gbps`): which
/// experts live locally, which peers own the rest, and the model of the
/// network link class they are fetched over. Validated at startup —
/// overlapping or incomplete shard assignments are a config error, never
/// a runtime miss.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// flat expert indices served from local DRAM
    pub local_shard: ShardSpec,
    /// peer shard servers; together with `local_shard` they must exactly
    /// partition the flat expert space
    pub peers: Vec<PeerSpec>,
    /// network link bandwidth (bytes/s) — its own `LinkArbiter` budget,
    /// independent of the PCIe link
    pub net_bw: f64,
    /// network per-transfer latency (s): connect + request overhead model
    pub net_latency: f64,
    /// bound of the staged peer->DRAM side-cache, in records
    pub staged_capacity: usize,
    /// network streaming granularity (client read chunks)
    pub chunk_bytes: usize,
    /// connect/read timeouts and retry budget per remote fetch
    pub retry: RetryPolicy,
    /// circuit-breaker cooldown after a peer exhausts its retries
    pub cooldown: Duration,
    /// deterministic fault injection for the remote/disk tiers
    /// (`--fault-plan`); None in production
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        Self {
            local_shard: ShardSpec::all(),
            peers: Vec::new(),
            net_bw: crate::memory::LinkModel::from_gbps(1.0, 0.0).bytes_per_s,
            net_latency: 200e-6,
            staged_capacity: 32,
            chunk_bytes: 64 * 1024,
            retry: RetryPolicy::default(),
            cooldown: Duration::from_secs(2),
            faults: None,
        }
    }
}

impl RemoteConfig {
    /// Build from the CLI surface. `peers` is `addr=spec;addr=spec` (`;`
    /// separates peers because shard specs use `,` internally), `shard`
    /// is the local [`ShardSpec`], `net_gbps` the network budget in
    /// gigabits/s. Returns `None` when neither sharding flag is given
    /// (single-node mode).
    pub fn from_flags(
        peers: Option<&str>,
        shard: Option<&str>,
        net_gbps: Option<f64>,
    ) -> Result<Option<Self>, String> {
        if peers.is_none() && shard.is_none() {
            return Ok(None);
        }
        let mut rc = Self::default();
        if let Some(s) = shard {
            rc.local_shard = ShardSpec::parse(s)?;
        }
        if let Some(ps) = peers {
            for ent in ps.split(';').filter(|e| !e.trim().is_empty()) {
                let (addr, spec) = ent
                    .split_once('=')
                    .ok_or_else(|| format!("peer '{ent}' must be host:port=shard-spec"))?;
                let addr = addr.trim().to_string();
                if !addr.contains(':') {
                    return Err(format!("peer address '{addr}' must be host:port"));
                }
                rc.peers.push(PeerSpec { addr, shard: ShardSpec::parse(spec)? });
            }
            if shard.is_none() {
                return Err("--peers requires --shard (the local shard)".into());
            }
        }
        if let Some(g) = net_gbps {
            if g <= 0.0 {
                return Err("--net-gbps must be > 0".into());
            }
            rc.net_bw = crate::memory::LinkModel::from_gbps(g, 0.0).bytes_per_s;
        }
        Ok(Some(rc))
    }

    /// The startup gate: local + peer shards must exactly partition the
    /// `total_experts`-sized flat index space, and the link model must be
    /// sane.
    pub fn validate(&self, total_experts: usize) -> Result<(), String> {
        if self.net_bw <= 0.0 {
            return Err("network bandwidth must be > 0".into());
        }
        if self.chunk_bytes == 0 {
            return Err("network chunk bytes must be >= 1".into());
        }
        let shards: Vec<&ShardSpec> = self.peers.iter().map(|p| &p.shard).collect();
        ShardSpec::validate_partition(&self.local_shard, &shards, total_experts)
    }
}

/// Overload-control plane for the interleaved scheduler (`serve
/// --interleaved`): a bounded admission queue plus the degradation ladder
/// that sheds expert *precision* before it sheds *requests* (MoBiLE's
/// little-expert fallback, lifted to the serving layer).
///
/// Ladder stages as the admission queue fills toward `queue_limit`:
///   1. fill >= `precision_frac` (or the oldest queued request is at SLO
///      risk) — force the progressive-streaming floor to the low tier, so
///      every hi-pool miss becomes usable after the low-bits prefix;
///   2. fill >= `prefetch_frac` — drop speculative prefetch planning, the
///      link belongs entirely to on-demand misses;
///   3. fill == `queue_limit` — reject new submissions with a typed
///      error (the only stage that refuses work).
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// bounded admission queue depth; `None` = unbounded (the legacy
    /// closed-loop behavior — no rejection, ladder stages keyed off an
    /// effectively-infinite queue never fire)
    pub queue_limit: Option<usize>,
    /// TTFT SLO: drives goodput accounting and the ladder's SLO-risk
    /// signal; `None` = every completion counts toward goodput
    pub slo_ttft: Option<Duration>,
    /// queue fill fraction at which precision shedding engages (stage 1)
    pub precision_frac: f64,
    /// queue fill fraction at which prefetch shedding engages (stage 2)
    pub prefetch_frac: f64,
    /// master switch for stages 1–2 (`--no-ladder`); admission bounding
    /// (stage 3) stays — availability is non-negotiable, accuracy is not
    pub ladder: bool,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            queue_limit: None,
            slo_ttft: None,
            precision_frac: 0.25,
            prefetch_frac: 0.75,
            ladder: true,
        }
    }
}

impl OverloadConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.queue_limit == Some(0) {
            return Err("admission queue limit must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.precision_frac)
            || !(0.0..=1.0).contains(&self.prefetch_frac)
        {
            return Err("ladder fractions must be in [0,1]".into());
        }
        if self.precision_frac > self.prefetch_frac {
            return Err("precision shed must engage at or before prefetch shed".into());
        }
        if self.slo_ttft == Some(Duration::ZERO) {
            return Err("TTFT SLO must be > 0".into());
        }
        Ok(())
    }
}

/// Typed `--max-batch` configuration error: the requested batch width is
/// covered by no execution path, and the message says which knob would
/// cover it. Replaces the silent hole where widths above the padded
/// ceiling were rejected with a generic bound even though grouped
/// execution (the default) runs them ragged at their exact row count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BatchWidthError {
    /// zero is not a batch
    Zero,
    /// wider than even the grouped ragged ceiling
    TooWide { requested: usize, ceiling: usize },
    /// grouped execution is off and no compiled padded launch width
    /// covers the request; `grouped_ceiling` is what dropping
    /// `--no-grouped` would buy
    NoPaddedWidth { requested: usize, ceiling: usize, grouped_ceiling: usize },
}

impl std::fmt::Display for BatchWidthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchWidthError::Zero => write!(f, "--max-batch must be >= 1"),
            BatchWidthError::TooWide { requested, ceiling } => write!(
                f,
                "--max-batch {requested} exceeds the grouped execution \
                 ceiling of {ceiling}"
            ),
            BatchWidthError::NoPaddedWidth { requested, ceiling, grouped_ceiling } => write!(
                f,
                "--max-batch {requested} has no compiled padded launch \
                 width under --no-grouped (max {ceiling}); drop \
                 --no-grouped for ragged widths up to {grouped_ceiling}"
            ),
        }
    }
}

impl std::error::Error for BatchWidthError {}

/// Startup gate for `--max-batch`: every width in `1..=ceiling` of the
/// selected execution path is accepted, everything else gets a
/// [`BatchWidthError`] naming the knob that would cover it.
pub fn validate_max_batch(max_batch: usize, grouped: bool) -> Result<(), BatchWidthError> {
    use crate::runtime::{MAX_DECODE_BATCH, MAX_GROUPED_BATCH};
    if max_batch == 0 {
        return Err(BatchWidthError::Zero);
    }
    if max_batch > MAX_GROUPED_BATCH {
        return Err(BatchWidthError::TooWide {
            requested: max_batch,
            ceiling: MAX_GROUPED_BATCH,
        });
    }
    if !grouped && max_batch > MAX_DECODE_BATCH {
        return Err(BatchWidthError::NoPaddedWidth {
            requested: max_batch,
            ceiling: MAX_DECODE_BATCH,
            grouped_ceiling: MAX_GROUPED_BATCH,
        });
    }
    Ok(())
}

/// HOBBIT policy knobs (paper defaults in parentheses).
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// dynamic-loading importance thresholds (T1 = 0.6, T2 = 0.9, §3.2).
    pub t1: f64,
    pub t2: f64,
    /// enable the token-level dynamic (mixed-precision) loading at all.
    pub dynamic_loading: bool,
    /// prefetch depth p (0 disables prefetching; paper recommends 1..3).
    pub prefetch_depth: usize,
    /// multidimensional cache weights (Eq. 3), summing to 1.
    pub w_lru: f64,
    pub w_lfu: f64,
    pub w_lhu: f64,
    pub w_fld: f64,
    /// high-precision format and its low-precision replacement.
    pub hi_precision: Precision,
    pub lo_precision: Precision,
    /// progressive low-bits-first streaming: a criticality-class cache
    /// miss may stream its `lo_precision` record first (usable as soon as
    /// it lands) and upgrade to `hi_precision` as a background
    /// continuation. The per-acquire floor decision weighs criticality,
    /// TTFT-deadline slack, and link pressure. Off = the pre-progressive
    /// behavior (every hi-pool miss streams the full hi record).
    pub progressive: bool,
    /// freeze the per-acquire precision choice: every hi-pool fetch
    /// streams exactly this precision, no staging, no upgrades
    /// (`--pin-precision`; pinning `hi_precision` reproduces the
    /// non-progressive byte stream bit-for-bit). Lo-pool fetches always
    /// use `lo_precision` — their slots are sized for it.
    pub pin_precision: Option<Precision>,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            t1: 0.6,
            t2: 0.9,
            dynamic_loading: true,
            prefetch_depth: 2,
            // calibrated on the synthetic trace set (see EXPERIMENTS.md Fig 18)
            w_lru: 0.65,
            w_lfu: 0.05,
            w_lhu: 0.10,
            w_fld: 0.20,
            hi_precision: Precision::F32,
            lo_precision: Precision::Q8,
            progressive: false,
            pin_precision: None,
        }
    }
}

impl PolicyConfig {
    /// The paper's int8-served configuration (Orin group of Table 2).
    pub fn int8_group() -> Self {
        Self { hi_precision: Precision::Q8, lo_precision: Precision::Q2, ..Self::default() }
    }

    /// Penalty ratio B_l/B_h of §3.4 for a given model.
    pub fn penalty_ratio(&self, model: &ModelConfig) -> f64 {
        model.bytes_for(self.lo_precision) as f64 / model.bytes_for(self.hi_precision) as f64
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.t1) || !(0.0..=1.0).contains(&self.t2) {
            return Err("T1/T2 must be in [0,1]".into());
        }
        if self.t1 > self.t2 {
            return Err("T1 must be <= T2".into());
        }
        let sum = self.w_lru + self.w_lfu + self.w_lhu + self.w_fld;
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("cache weights must sum to 1 (got {sum})"));
        }
        if self.hi_precision.bits() <= self.lo_precision.bits() {
            return Err("hi precision must be wider than lo".into());
        }
        if self.prefetch_depth > 4 {
            return Err("prefetch depth > 4 has no compiled gate artifact".into());
        }
        if let Some(p) = self.pin_precision {
            // the pinned record must fit the hi pool's native-sized slots
            if p.bits() > self.hi_precision.bits() {
                return Err("pin precision wider than hi precision".into());
            }
            if self.progressive {
                return Err("pin-precision freezes the choice; drop --progressive".into());
            }
        }
        Ok(())
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut cfg = Self::default();
        let g = |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        cfg.t1 = g("t1", cfg.t1);
        cfg.t2 = g("t2", cfg.t2);
        cfg.prefetch_depth = g("prefetch_depth", cfg.prefetch_depth as f64) as usize;
        cfg.w_lru = g("w_lru", cfg.w_lru);
        cfg.w_lfu = g("w_lfu", cfg.w_lfu);
        cfg.w_lhu = g("w_lhu", cfg.w_lhu);
        cfg.w_fld = g("w_fld", cfg.w_fld);
        if let Some(b) = j.get("dynamic_loading").and_then(Json::as_bool) {
            cfg.dynamic_loading = b;
        }
        if let Some(p) = j.get("hi_precision").and_then(Json::as_str) {
            cfg.hi_precision = Precision::from_name(p).ok_or("bad hi_precision")?;
        }
        if let Some(p) = j.get("lo_precision").and_then(Json::as_str) {
            cfg.lo_precision = Precision::from_name(p).ok_or("bad lo_precision")?;
        }
        if let Some(b) = j.get("progressive").and_then(Json::as_bool) {
            cfg.progressive = b;
        }
        if let Some(p) = j.get("pin_precision").and_then(Json::as_str) {
            cfg.pin_precision = Some(Precision::from_name(p).ok_or("bad pin_precision")?);
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_default_valid() {
        PolicyConfig::default().validate().unwrap();
        PolicyConfig::int8_group().validate().unwrap();
    }

    #[test]
    fn overload_default_valid_and_bounds_checked() {
        OverloadConfig::default().validate().unwrap();
        let mut o = OverloadConfig::default();
        o.queue_limit = Some(0);
        assert!(o.validate().is_err(), "zero queue limit must fail");
        let mut o = OverloadConfig::default();
        o.precision_frac = 0.9;
        o.prefetch_frac = 0.5;
        assert!(o.validate().is_err(), "inverted ladder order must fail");
        let mut o = OverloadConfig::default();
        o.prefetch_frac = 1.5;
        assert!(o.validate().is_err(), "fraction > 1 must fail");
        let mut o = OverloadConfig::default();
        o.slo_ttft = Some(Duration::ZERO);
        assert!(o.validate().is_err(), "zero SLO must fail");
        let mut o = OverloadConfig::default();
        o.queue_limit = Some(64);
        o.slo_ttft = Some(Duration::from_millis(500));
        o.ladder = false;
        o.validate().unwrap();
    }

    #[test]
    fn max_batch_validation_is_exec_mode_aware() {
        use crate::runtime::{MAX_DECODE_BATCH, MAX_GROUPED_BATCH};
        // grouped (default): any width up to the ragged ceiling
        validate_max_batch(1, true).unwrap();
        validate_max_batch(MAX_DECODE_BATCH + 1, true).unwrap();
        validate_max_batch(MAX_GROUPED_BATCH, true).unwrap();
        assert_eq!(
            validate_max_batch(MAX_GROUPED_BATCH + 1, true),
            Err(BatchWidthError::TooWide {
                requested: MAX_GROUPED_BATCH + 1,
                ceiling: MAX_GROUPED_BATCH
            })
        );
        // legacy padded path: capped at the largest compiled width, and
        // the error names the knob that would cover the request
        validate_max_batch(MAX_DECODE_BATCH, false).unwrap();
        let err = validate_max_batch(MAX_DECODE_BATCH + 1, false).unwrap_err();
        assert_eq!(
            err,
            BatchWidthError::NoPaddedWidth {
                requested: MAX_DECODE_BATCH + 1,
                ceiling: MAX_DECODE_BATCH,
                grouped_ceiling: MAX_GROUPED_BATCH
            }
        );
        assert!(err.to_string().contains("--no-grouped"), "{err}");
        // zero rejected on both paths
        assert_eq!(validate_max_batch(0, true), Err(BatchWidthError::Zero));
        assert_eq!(validate_max_batch(0, false), Err(BatchWidthError::Zero));
    }

    #[test]
    fn policy_rejects_bad_weights() {
        let mut p = PolicyConfig::default();
        p.w_lru = 0.9;
        assert!(p.validate().is_err());
        let mut p = PolicyConfig::default();
        p.t1 = 0.95;
        assert!(p.validate().is_err(), "t1 > t2 must fail");
    }

    #[test]
    fn policy_from_json_overrides() {
        let j = Json::parse(r#"{"t1": 0.5, "t2": 0.8, "prefetch_depth": 3}"#).unwrap();
        let p = PolicyConfig::from_json(&j).unwrap();
        assert_eq!(p.t1, 0.5);
        assert_eq!(p.prefetch_depth, 3);
        assert_eq!(p.w_lru, PolicyConfig::default().w_lru);
    }

    #[test]
    fn policy_precision_mode_validation() {
        let mut p = PolicyConfig::default();
        p.pin_precision = Some(Precision::F32);
        p.validate().unwrap();
        p.pin_precision = Some(Precision::Q4);
        p.validate().unwrap();
        p.progressive = true;
        assert!(p.validate().is_err(), "pin + progressive must conflict");
        p.pin_precision = None;
        p.validate().unwrap();
        // pin wider than the hi pool's slots cannot fit
        let mut p = PolicyConfig::int8_group();
        p.pin_precision = Some(Precision::F32);
        assert!(p.validate().is_err(), "pin wider than hi must fail");
        let j = Json::parse(r#"{"progressive": true}"#).unwrap();
        assert!(PolicyConfig::from_json(&j).unwrap().progressive);
        let j = Json::parse(r#"{"pin_precision": "q8"}"#).unwrap();
        assert_eq!(
            PolicyConfig::from_json(&j).unwrap().pin_precision,
            Some(Precision::Q8)
        );
    }

    #[test]
    fn io_config_defaults_and_validation() {
        let io = IoConfig::default();
        assert_eq!(io.lanes, 2);
        assert_eq!(io.chunk_bytes, 256 * 1024);
        assert!(io.watchdog_ms > 0, "watchdog on by default");
        io.validate().unwrap();
        assert_eq!(IoConfig::single_lane().lanes, 1);
        assert!(IoConfig { lanes: 0, chunk_bytes: 1, ..IoConfig::default() }
            .validate()
            .is_err());
        assert!(IoConfig { lanes: 1, chunk_bytes: 0, ..IoConfig::default() }
            .validate()
            .is_err());
        // watchdog_ms 0 is the explicit off switch, always valid
        IoConfig { watchdog_ms: 0, ..IoConfig::default() }.validate().unwrap();
    }

    #[test]
    fn hardware_presets() {
        assert!(HardwareConfig::preset("rtx4090").is_some());
        assert!(HardwareConfig::preset("orin").is_some());
        assert!(HardwareConfig::preset("rtx4090+cpu").unwrap().cpu_assist);
        assert!(HardwareConfig::preset("nope").is_none());
    }

    #[test]
    fn manifest_json_round_trips() {
        let cfg = crate::model::synth::tiny_model_config("manifest-rt");
        let j = Json::parse(&cfg.to_manifest_json().to_string()).unwrap();
        let back = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.n_layers, cfg.n_layers);
        assert_eq!(back.expert_bytes, cfg.expert_bytes);
        assert_eq!(back.top_k, cfg.top_k);
        assert_eq!(back.vocab, cfg.vocab);
    }

    #[test]
    fn remote_config_flag_parsing_and_validation() {
        assert!(RemoteConfig::from_flags(None, None, None).unwrap().is_none());
        let rc = RemoteConfig::from_flags(
            Some("127.0.0.1:7001=0-5;127.0.0.1:7002=6-11"),
            Some("none"),
            Some(10.0),
        )
        .unwrap()
        .unwrap();
        assert_eq!(rc.peers.len(), 2);
        assert!(rc.local_shard.is_none());
        assert!((rc.net_bw - 10.0e9 / 8.0).abs() < 1.0);
        rc.validate(12).unwrap();
        // incomplete partition rejected at startup
        let rc = RemoteConfig::from_flags(Some("127.0.0.1:7001=0-5"), Some("none"), None)
            .unwrap()
            .unwrap();
        let err = rc.validate(12).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
        // overlapping partition rejected
        let rc = RemoteConfig::from_flags(Some("127.0.0.1:7001=0-11"), Some("0-3"), None)
            .unwrap()
            .unwrap();
        assert!(rc.validate(12).unwrap_err().contains("overlap"));
        // malformed flags
        assert!(RemoteConfig::from_flags(Some("noport=0-5"), Some("none"), None).is_err());
        assert!(RemoteConfig::from_flags(Some("127.0.0.1:7001=0-5"), None, None).is_err());
        assert!(RemoteConfig::from_flags(None, Some("all"), Some(-1.0)).is_err());
        // --shard all alone is the single-node degenerate case
        RemoteConfig::from_flags(None, Some("all"), None)
            .unwrap()
            .unwrap()
            .validate(12)
            .unwrap();
    }

    #[test]
    fn model_config_from_manifest_json() {
        let src = r#"{"model": {"name": "m", "n_layers": 8, "d_model": 256,
            "d_ff": 512, "n_experts": 8, "top_k": 2, "n_heads": 8,
            "n_kv_heads": 4, "vocab": 260, "max_seq": 512, "quant_group": 64,
            "rope_theta": 10000.0, "norm_eps": 1e-5,
            "expert_bytes": {"f32": 1572864, "q8": 417792, "q4": 221184, "q2": 122880}}}"#;
        let j = Json::parse(src).unwrap();
        let m = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(m.n_layers, 8);
        assert_eq!(m.bytes_for(Precision::F32), 1572864);
        assert_eq!(m.head_dim(), 32);
        assert_eq!(m.total_experts(), 64);
    }
}
