//! Live-engine figures (tiny models through PJRT): Fig 3(b) quality of
//! skip-vs-quantize, Fig 5 gate statistics, Fig 7 cross-layer similarity
//! and prediction accuracy, Fig 17(a) stacked vs sequential gating cost,
//! Table 3 mixed-precision accuracy. These require `make artifacts`.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::config::{HardwareConfig, PolicyConfig};
use crate::engine::{Capture, Engine, EngineOptions};
use crate::loader::scorer;
use crate::runtime::{lit_f32, lit_to_f32};
use crate::tensor::{kl_from_logits, topk};
use crate::util::stats::{cosine, pearson};
use crate::Precision;

use super::{section, Row};

/// Engine with an effectively-infinite cache and relaxed link (quality
/// experiments measure numerics, not timing).
fn quality_engine(
    artifacts: &Path,
    model: &str,
    policy: PolicyConfig,
    capture: Capture,
) -> Result<Engine> {
    let hw = HardwareConfig {
        name: "quality".into(),
        load_bw: 64e9,
        load_latency: 0.0,
        hi_cache_experts: 256,
        lo_cache_experts: 256,
        cpu_assist: false,
        cpu_expert_time: 0.0,
    };
    let mut opts = EngineOptions::new(hw, policy);
    opts.capture = capture;
    Engine::new(artifacts, model, opts)
}

/// Teacher-forced logits over a fixed token stream.
fn eval_logits(engine: &mut Engine, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
    let mut kv = engine.new_sequence();
    let mut out = Vec::with_capacity(tokens.len());
    let mut logits = engine.prefill(&mut kv, &tokens[..1])?;
    out.push(logits.clone());
    for &t in &tokens[1..] {
        logits = engine.decode_step(&mut kv, t)?;
        out.push(logits.clone());
    }
    Ok(out)
}

fn eval_tokens(n: usize) -> Vec<u32> {
    // deterministic pseudo-text bytes (BOS + printable range)
    let mut v = vec![crate::tokenizer::BOS];
    let mut s = 0x9E3779B97F4A7C15u64;
    while v.len() < n {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        v.push(32 + (s >> 33) as u32 % 90);
    }
    v
}

/// Mean KL + top-1 + top-5 agreement of `b` against baseline `a`.
/// (top-5 is the robust metric for the random-init tiny models, whose
/// near-uniform logits make top-1 flip on tiny perturbations.)
fn divergence(a: &[Vec<f32>], b: &[Vec<f32>]) -> (f64, f64, f64) {
    let mut kl = 0.0;
    let mut agree = 0.0;
    let mut agree5 = 0.0;
    for (x, y) in a.iter().zip(b) {
        kl += kl_from_logits(x, y);
        let ax = topk(x, 1)[0].0;
        agree += (ax == topk(y, 1)[0].0) as u32 as f64;
        agree5 += topk(y, 5).iter().any(|(i, _)| *i == ax) as u32 as f64;
    }
    let n = a.len() as f64;
    (kl / n, agree / n, agree5 / n)
}

/// Fig 3(b): replacing unimportant experts with low-precision versions
/// preserves quality far better than skipping them, and the gap grows
/// with the ratio. Ratio is controlled through the T1/T2 thresholds as
/// score quantiles (the same mechanism HOBBIT uses online).
pub fn fig3b(artifacts: &Path, model: &str) -> Result<Vec<Row>> {
    section("Fig 3(b) — quality impact: expert skip vs low-precision replace");
    let tokens = eval_tokens(40);
    // baseline: everything high precision
    let mut base_policy = PolicyConfig::default();
    base_policy.dynamic_loading = false;
    let mut eng = quality_engine(artifacts, model, base_policy, Capture::none())?;
    let base = eval_logits(&mut eng, &tokens)?;
    drop(eng);

    // score distribution from a routing capture to place quantiles
    let mut cap_policy = PolicyConfig::default();
    cap_policy.dynamic_loading = false;
    let mut cap = Capture::none();
    cap.routing = true;
    let mut eng = quality_engine(artifacts, model, cap_policy, cap)?;
    let _ = eval_logits(&mut eng, &tokens)?;
    let mut scores: Vec<f64> = Vec::new();
    for r in &eng.capture.routes {
        for d in scorer::decide(&r.probs, eng.cfg.top_k, 2.0, 2.0, true) {
            scores.push(d.score);
        }
    }
    drop(eng);
    scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let quantile = |q: f64| scores[((scores.len() - 1) as f64 * q) as usize];

    let mut rows = Vec::new();
    for ratio in [0.05, 0.10, 0.20, 0.30] {
        let t = quantile(1.0 - ratio);
        // replace curve: bottom `ratio` of selections -> low precision
        let mut p = PolicyConfig::default();
        p.t1 = t;
        p.t2 = 1.0; // never skip
        let mut eng = quality_engine(artifacts, model, p, Capture::none())?;
        let quant = eval_logits(&mut eng, &tokens)?;
        drop(eng);
        // skip curve: bottom `ratio` of selections -> skipped
        let mut p = PolicyConfig::default();
        p.t1 = t;
        p.t2 = t;
        let mut eng = quality_engine(artifacts, model, p, Capture::none())?;
        let skip = eval_logits(&mut eng, &tokens)?;
        drop(eng);
        let (kl_q, ag_q, ag5_q) = divergence(&base, &quant);
        let (kl_s, ag_s, ag5_s) = divergence(&base, &skip);
        rows.push(
            Row::new(format!("ratio {:.0}%", ratio * 100.0))
                .push("replace_kl", kl_q)
                .push("skip_kl", kl_s)
                .push("replace_top1", ag_q)
                .push("skip_top1", ag_s)
                .push("replace_top5", ag5_q)
                .push("skip_top5", ag5_s),
        );
    }
    super::print_rows(&rows);
    Ok(rows)
}

/// Fig 5(a): Pearson correlation of ‖G(x)‖ with ‖G(x)·E(x)‖;
/// Fig 5(b): unimportance-score distribution and the T1/T2 split.
pub fn fig5(artifacts: &Path, model: &str) -> Result<Vec<Row>> {
    section("Fig 5 — gating statistics");
    let mut cap = Capture::none();
    cap.gate_stats = true;
    cap.routing = true;
    let mut policy = PolicyConfig::default();
    policy.dynamic_loading = false; // observe every selected expert in hi
    let mut eng = quality_engine(artifacts, model, policy, cap)?;
    let _ = eval_logits(&mut eng, &eval_tokens(48))?;

    let gates: Vec<f64> = eng.capture.gates.iter().map(|g| g.gate as f64).collect();
    let outs: Vec<f64> = eng.capture.gates.iter().map(|g| g.out_norm as f64).collect();
    let corr = pearson(&gates, &outs);

    // score distribution + the paper's T1=0.6/T2=0.9 split
    let (mut hi, mut lo, mut skip, mut total) = (0u64, 0u64, 0u64, 0u64);
    for r in &eng.capture.routes {
        for d in scorer::decide(&r.probs, eng.cfg.top_k, 0.6, 0.9, true) {
            total += 1;
            match d.class {
                scorer::Class::Hi => hi += 1,
                scorer::Class::Lo => lo += 1,
                scorer::Class::Skip => skip += 1,
            }
        }
    }
    let rows = vec![
        Row::new("(a) corr(|G|, |G E(x)|)").push("pearson", corr),
        Row::new("(b) split @ T1=0.6 T2=0.9")
            .push("hi%", 100.0 * hi as f64 / total as f64)
            .push("lo%", 100.0 * lo as f64 / total as f64)
            .push("skip%", 100.0 * skip as f64 / total as f64),
    ];
    super::print_rows(&rows);
    Ok(rows)
}

/// Fig 7: cosine similarity of gating inputs across layer offsets, and
/// top-1 prediction accuracy when the current input drives the next
/// layers' gates (the basis of the Stacking Computer).
pub fn fig7(artifacts: &Path, model: &str) -> Result<Vec<Row>> {
    section("Fig 7 — cross-layer similarity and prediction accuracy");
    let mut cap = Capture::none();
    cap.hidden_states = true;
    cap.routing = true;
    let mut eng = quality_engine(artifacts, model, PolicyConfig::default(), cap)?;
    let _ = eval_logits(&mut eng, &eval_tokens(40))?;

    let d = eng.cfg.d_model;
    let e = eng.cfg.n_experts as usize;
    let n_layers = eng.cfg.n_layers;
    let eps = 1e-5f32;
    let mut rows = Vec::new();
    for offset in 1..=3u32 {
        let mut sims = Vec::new();
        let mut hits = 0u64;
        let mut total = 0u64;
        for h in &eng.capture.hiddens {
            if h.layer + offset >= n_layers {
                continue;
            }
            // cosine vs the same token's hidden at layer + offset
            if let Some(h2) = eng
                .capture
                .hiddens
                .iter()
                .find(|x| x.token == h.token && x.layer == h.layer + offset)
            {
                sims.push(cosine(&h.hidden, &h2.hidden));
            }
            // offline prediction: norm with the target layer's weights,
            // multiply by its gate matrix, top-k, compare with realized
            let target = h.layer + offset;
            let (_, pn) = eng.nonexpert.get(&format!("post_norm.{target}"))?;
            let (_, wg) = eng.nonexpert.get(&format!("wg.{target}"))?;
            let ms: f32 =
                h.hidden.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let rinv = 1.0 / (ms + eps).sqrt();
            let mut logits = vec![0.0f32; e];
            for (i, lg) in logits.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for r in 0..d {
                    acc += h.hidden[r] * rinv * pn[r] * wg[r * e + i];
                }
                *lg = acc;
            }
            let predicted: Vec<usize> =
                topk(&logits, eng.cfg.top_k).iter().map(|x| x.0).collect();
            if let Some(actual) = eng
                .capture
                .routes
                .iter()
                .find(|r| r.token == h.token && r.layer == target)
            {
                let actual_top = topk(&actual.probs, 1)[0].0;
                total += 1;
                if predicted.contains(&actual_top) {
                    hits += 1;
                }
            }
        }
        let mean_sim = sims.iter().sum::<f64>() / sims.len().max(1) as f64;
        rows.push(
            Row::new(format!("next {offset}"))
                .push("cosine", mean_sim)
                .push("top1_pred_acc", hits as f64 / total.max(1) as f64),
        );
    }
    super::print_rows(&rows);
    Ok(rows)
}

/// Fig 17(a): the Stacking Computer's cost is ~flat in p; sequential
/// gating grows linearly. Timed on the live PJRT executables.
pub fn fig17a(artifacts: &Path, model: &str) -> Result<Vec<Row>> {
    section("Fig 17(a) — stacked vs sequential gating cost (PJRT wall time)");
    let mut rt = crate::runtime::Runtime::open(&artifacts.join(model))?;
    let manifest_model = rt.manifest.model_json();
    let cfg = crate::config::ModelConfig::from_manifest(&manifest_model)
        .map_err(anyhow::Error::msg)?;
    let d = cfg.d_model;
    let e = cfg.n_experts as usize;
    let mut rows = Vec::new();
    for p in 1..=4usize {
        for kind in ["gate", "gate_seq"] {
            let name = format!("{kind}_p{p}_s1");
            rt.ensure(&name)?;
            let x = lit_f32(&[1, d], &vec![0.1; d])?;
            let pn = lit_f32(&[p, d], &vec![1.0; p * d])?;
            let wg = lit_f32(&[p, d, e], &vec![0.01; p * d * e])?;
            // warmup + timed loop (p50 of per-call samples; single-core
            // CPU timings are noisy, the median is the honest statistic)
            for _ in 0..10 {
                let _ = rt.execute(&name, &[&x, &pn, &wg])?;
            }
            let mut samples = Vec::with_capacity(200);
            for _ in 0..200 {
                let t0 = Instant::now();
                let out = rt.execute(&name, &[&x, &pn, &wg])?;
                let _ = lit_to_f32(&out[0])?;
                samples.push(t0.elapsed().as_secs_f64());
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let us = samples[samples.len() / 2] * 1e6;
            rows.push(Row::new(format!("{kind} p={p}")).push("p50_us", us));
        }
    }
    super::print_rows(&rows);
    Ok(rows)
}

/// Table 3: model quality with mixed-precision experts — top-1 agreement
/// and KL against the group's high-precision baseline, for both precision
/// groups (f32-served + q8 replacements; q8-served + q2 replacements).
pub fn table3(artifacts: &Path, model: &str) -> Result<Vec<Row>> {
    section("Table 3 — quality with mixed-precision experts");
    let tokens = eval_tokens(40);
    let mut rows = Vec::new();
    for (group, hi, lo) in [
        ("f32 group", Precision::F32, Precision::Q8),
        ("q8 group", Precision::Q8, Precision::Q2),
    ] {
        let mut base_p = PolicyConfig::default();
        base_p.hi_precision = hi;
        base_p.lo_precision = lo;
        base_p.dynamic_loading = false;
        let mut eng = quality_engine(artifacts, model, base_p.clone(), Capture::none())?;
        let base = eval_logits(&mut eng, &tokens)?;
        drop(eng);
        let mut mixed_p = base_p;
        mixed_p.dynamic_loading = true;
        let mut eng = quality_engine(artifacts, model, mixed_p, Capture::none())?;
        let mixed = eval_logits(&mut eng, &tokens)?;
        drop(eng);
        let (kl, agree, agree5) = divergence(&base, &mixed);
        rows.push(
            Row::new(format!("{model} {group} (+{})", lo.name()))
                .push("top1_agreement", agree)
                .push("top5_agreement", agree5)
                .push("mean_kl", kl),
        );
    }
    super::print_rows(&rows);
    Ok(rows)
}
