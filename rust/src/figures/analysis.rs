//! Trace/cache analysis figures: Fig 10 (expert-usage statistics), Fig 11
//! (LFU vs LHU), Fig 18 (cache-policy comparison).

use crate::cache::Policy;
use crate::trace::replay::{replay, ReplayConfig};
use crate::trace::{self, generate, TraceGenConfig};

use super::{section, Row};

fn mixtral_traces(seed: u64) -> trace::TraceSet {
    generate(&TraceGenConfig { seed, ..TraceGenConfig::mixtral_like() }, 4, 96)
}

/// Fig 10: (a) probability of expert reuse between consecutive tokens vs
/// the theoretical uniform values; (b) sequence-level selection skew.
pub fn fig10() -> Vec<Row> {
    section("Fig 10 — expert usage statistics (Mixtral-like traces)");
    let ts = mixtral_traces(41);
    let k = 2;
    let e = 8.0;
    let top1: f64 =
        ts.seqs.iter().map(|s| trace::top1_reuse_prob(s, k)).sum::<f64>() / ts.seqs.len() as f64;
    let any: f64 =
        ts.seqs.iter().map(|s| trace::any_reuse_prob(s, k)).sum::<f64>() / ts.seqs.len() as f64;
    // theoretical: top-1 reused with prob k/E; any-of-k ~ 1-((E-k)/E)^k
    let th_top1 = k as f64 / e;
    let th_any = 1.0 - ((e - k as f64) / e) * ((e - 1.0 - k as f64) / (e - 1.0));
    let mut rows = vec![
        Row::new("top1 reuse").push("measured", top1).push("theoretical", th_top1),
        Row::new("any-of-topk reuse").push("measured", any).push("theoretical", th_any),
    ];
    // (b) per-sequence preference divergence: mean L1 distance between two
    // sequences' per-layer selection frequencies
    let f0 = trace::selection_frequency(&ts.seqs[0], k);
    let f1 = trace::selection_frequency(&ts.seqs[1], k);
    let mut l1 = 0.0;
    for (r0, r1) in f0.iter().zip(&f1) {
        for (a, b) in r0.iter().zip(r1) {
            l1 += (a - b).abs();
        }
    }
    l1 /= f0.len() as f64;
    rows.push(Row::new("seq-level preference L1 gap").push("per-layer", l1));
    super::print_rows(&rows);
    rows
}

/// Fig 11: LFU vs LHU on mixed-precision usage — per-expert miss counts
/// for one layer and the total penalty gap.
pub fn fig11() -> Vec<Row> {
    section("Fig 11 — LFU vs LHU (mixed-precision cache, one layer)");
    let ts = mixtral_traces(43);
    let cfg = ReplayConfig { hi_capacity: 12, lo_capacity: 16, ..Default::default() };
    let lfu = replay(&ts, Policy::LfuSeq, &cfg);
    let lhu = replay(&ts, Policy::Lhu, &cfg);
    let mut rows = Vec::new();
    // per-expert misses of layer 0 (the paper shows one layer)
    for e in 0..8usize {
        rows.push(
            Row::new(format!("layer0/expert{e}"))
                .push("lfu_hi_miss", lfu.per_expert_misses[e][0] as f64)
                .push("lfu_lo_miss", lfu.per_expert_misses[e][1] as f64)
                .push("lhu_hi_miss", lhu.per_expert_misses[e][0] as f64)
                .push("lhu_lo_miss", lhu.per_expert_misses[e][1] as f64),
        );
    }
    rows.push(
        Row::new("total miss penalty")
            .push("lfu", lfu.penalty)
            .push("lhu", lhu.penalty)
            .push("lhu_vs_lfu_%", 100.0 * (lfu.penalty - lhu.penalty) / lfu.penalty),
    );
    super::print_rows(&rows);
    rows
}

/// The four evaluation setups of Fig 18(a): (model, cache sizes).
fn fig18_setups() -> Vec<(String, TraceGenConfig, ReplayConfig)> {
    vec![
        (
            "mixtral/4090".into(),
            TraceGenConfig::mixtral_like(),
            ReplayConfig { hi_capacity: 43, lo_capacity: 55, ..Default::default() },
        ),
        (
            "mixtral/orin".into(),
            TraceGenConfig::mixtral_like(),
            ReplayConfig { hi_capacity: 16, lo_capacity: 24, ..Default::default() },
        ),
        (
            "phi/4090".into(),
            TraceGenConfig::phi_like(),
            ReplayConfig { hi_capacity: 90, lo_capacity: 110, ..Default::default() },
        ),
        (
            "phi/orin".into(),
            TraceGenConfig::phi_like(),
            ReplayConfig { hi_capacity: 34, lo_capacity: 50, ..Default::default() },
        ),
    ]
}

/// Fig 18(a): cache miss penalty by policy, normalized against Random.
pub fn fig18a(weights: [f64; 4]) -> Vec<Row> {
    section("Fig 18(a) — cache policy miss penalty (normalized vs random)");
    let mut rows = Vec::new();
    for (name, mut gen, cfg) in fig18_setups() {
        gen.seed = 47;
        let ts = generate(&gen, 5, 96);
        let base = replay(&ts, Policy::Random { seed: 3 }, &cfg).penalty;
        let mut row = Row::new(name);
        for (pname, p) in [
            ("lru", Policy::Lru),
            ("lfu", Policy::LfuSeq),
            ("lhu", Policy::Lhu),
            ("fld", Policy::Fld),
            ("ours", Policy::Multidim { w: weights }),
        ] {
            let r = replay(&ts, p, &cfg);
            row = row.push(pname, r.penalty / base);
        }
        row.print();
        rows.push(row);
    }
    rows
}

/// Fig 18(b): model-level vs sequence-level records (LFU is the policy
/// the level matters for).
pub fn fig18b() -> Vec<Row> {
    section("Fig 18(b) — model-level vs sequence-level policies (hit ratio)");
    let ts = mixtral_traces(53);
    let cfg = ReplayConfig { hi_capacity: 20, lo_capacity: 28, ..Default::default() };
    let mut rows = Vec::new();
    for (name, p) in [
        ("lfu", None),
        ("lru", Some(Policy::Lru)),
        ("fld", Some(Policy::Fld)),
    ] {
        let (model_lvl, seq_lvl) = match p {
            None => (
                replay(&ts, Policy::LfuModel, &cfg),
                replay(&ts, Policy::LfuSeq, &cfg),
            ),
            Some(p) => (
                replay(&ts, p.clone(), &ReplayConfig { seq_level: false, ..cfg.clone() }),
                replay(&ts, p, &cfg),
            ),
        };
        rows.push(
            Row::new(name)
                .push("model_level_hit", model_lvl.hit_ratio())
                .push("seq_level_hit", seq_lvl.hit_ratio()),
        );
    }
    super::print_rows(&rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::EQ3_WEIGHTS;

    #[test]
    fn fig10_reuse_beats_theory() {
        let rows = fig10();
        assert!(rows[0].get("measured").unwrap() > rows[0].get("theoretical").unwrap());
        assert!(rows[1].get("measured").unwrap() > rows[0].get("measured").unwrap());
    }

    #[test]
    fn fig18a_ours_best_on_average() {
        let rows = fig18a(EQ3_WEIGHTS);
        let mean = |k: &str| {
            rows.iter().map(|r| r.get(k).unwrap()).sum::<f64>() / rows.len() as f64
        };
        let ours = mean("ours");
        assert!(ours < 1.0, "ours {ours} must beat random");
        assert!(ours <= mean("lru") + 1e-9, "ours {ours} vs lru {}", mean("lru"));
        assert!(ours <= mean("lfu") + 0.01, "ours {ours} vs lfu {}", mean("lfu"));
    }

    #[test]
    fn fig18b_seq_level_helps_lfu() {
        let rows = fig18b();
        let lfu = &rows[0];
        assert!(
            lfu.get("seq_level_hit").unwrap() >= lfu.get("model_level_hit").unwrap() - 0.01,
            "sequence-level LFU should not lose to model-level"
        );
    }
}
