//! Sim-scale figures: Fig 3a (time breakdown), Fig 9 (preload timelines),
//! Fig 14 (end-to-end vs SOTA), Fig 15 (CPU cooperative), Fig 16 (dynamic
//! loading ablation), Fig 17b (prefetch ablation).

use crate::baselines::{self, EQ3_WEIGHTS};
use crate::sim::des::{simulate_decode, SimSystem};
use crate::sim::params::{SimHardware, SimModel};
use crate::trace::{generate, TraceGenConfig, TraceSet};

use super::{section, Row};

/// The paper's four [input, output] length groups (§5.1 Metrics).
pub const LEN_GROUPS: [(usize, u32); 4] = [(16, 32), (16, 128), (128, 32), (128, 128)];

fn traces_for(model: &SimModel, n_seqs: usize, tokens: u32, seed: u64) -> TraceSet {
    let mut cfg = if model.n_experts == 16 {
        TraceGenConfig::phi_like()
    } else {
        TraceGenConfig::mixtral_like()
    };
    cfg.n_layers = model.n_layers;
    cfg.seed = seed;
    generate(&cfg, n_seqs, tokens)
}

/// Fig 3(a): expert loading dominates inference cost (RTX 4090 ~85%,
/// Jetson Orin ~95%) — measured on a naive on-demand offloading system.
pub fn fig3a() -> Vec<Row> {
    section("Fig 3(a) — decode time breakdown (naive on-demand offloading)");
    let mut rows = Vec::new();
    // the motivation measurement runs the base fp16 model on both devices
    for (hw, bits) in [(SimHardware::rtx4090(), 16.0), (SimHardware::orin(), 16.0)] {
        let model = SimModel::mixtral_8x7b();
        let mut sys = SimSystem::moe_offloading(bits);
        sys.prefetch_depth = 0; // pure on-demand (the paper's measurement)
        sys.name = "on-demand".into();
        let traces = traces_for(&model, 2, 32, 11);
        let (_, d) = simulate_decode(&sys, &hw, &model, &traces, 16, 1);
        let load_pct = 100.0 * d.load_fraction();
        rows.push(
            Row::new(format!("{} / Mixtral-8x7B", hw.name))
                .push("load%", load_pct)
                .push("compute%", 100.0 - load_pct),
        );
    }
    super::print_rows(&rows);
    rows
}

/// Fig 9: preload timelines — decode speed under prediction-accuracy and
/// mixed-precision conditions. Reproduces the ordering: (b) high-acc
/// prefetch ≥ (a) no prefetch ≥ (c) low-acc prefetch, and mixed precision
/// (d)/(e) softens the low-acc penalty.
pub fn fig9() -> Vec<Row> {
    section("Fig 9 — prefetch benefit/penalty vs prediction accuracy");
    let hw = SimHardware::rtx4090();
    let model = SimModel::mixtral_8x7b();
    let traces = traces_for(&model, 2, 32, 13);
    let mut rows = Vec::new();
    let cases: [(&str, usize, f64, bool); 5] = [
        ("(a) no prefetch, fp16", 0, 0.0, false),
        ("(b) prefetch acc=0.95, fp16", 1, 0.95, false),
        ("(c) prefetch acc=0.40, fp16", 1, 0.40, false),
        ("(d) prefetch acc=0.95, mixed", 1, 0.95, true),
        ("(e) prefetch acc=0.40, mixed", 1, 0.40, true),
    ];
    for (name, depth, acc, mixed) in cases {
        let mut sys = SimSystem::hobbit(EQ3_WEIGHTS);
        sys.name = name.into();
        sys.prefetch_depth = depth;
        sys.pred_acc = [acc; 4];
        sys.dynamic = mixed;
        if !mixed {
            sys.lo_cache_frac = 0.0;
        }
        let (_, d) = simulate_decode(&sys, &hw, &model, &traces, 16, 2);
        rows.push(Row::new(name).push("tok/s", d.tps()).push("load_wait_s", d.load_wait_time));
    }
    super::print_rows(&rows);
    rows
}

/// Fig 14: end-to-end decode speed + prefill latency, HOBBIT vs SOTA, on
/// the first two testing groups of Table 2 (Orin-int8, 4090-fp16), both
/// models, the paper's four length groups.
pub fn fig14() -> Vec<Row> {
    section("Fig 14 — end-to-end vs SOTA (sim @ paper scale)");
    let mut rows = Vec::new();
    for (group_name, hw, systems) in [
        ("orin-int8", SimHardware::orin(), baselines::group_orin_int8()),
        ("4090-f16", SimHardware::rtx4090(), baselines::group_rtx4090_f16()),
    ] {
        for model in [SimModel::mixtral_8x7b(), SimModel::phi_moe()] {
            for (inp, out) in LEN_GROUPS {
                let traces = traces_for(&model, 2, out, 17 + inp as u64);
                for sys in &systems {
                    let (p, d) = simulate_decode(sys, &hw, &model, &traces, inp, 3);
                    rows.push(
                        Row::new(format!(
                            "{group_name}/{}/[{inp},{out}]/{}",
                            model.name, sys.name
                        ))
                        .push("decode_tps", d.tps())
                        .push("prefill_s", p.latency),
                    );
                }
            }
        }
    }
    super::print_rows(&rows);
    // summary speedups (the paper's headline numbers)
    summarize_speedups(&rows, "4090-f16", "HOBBIT", &["MoE-Offloading", "MoE-Infinity"]);
    summarize_speedups(&rows, "orin-int8", "HOBBIT", &["Llama.cpp", "MoE-Infinity"]);
    rows
}

fn summarize_speedups(rows: &[Row], group: &str, ours: &str, baselines: &[&str]) {
    for b in baselines {
        let mut ratios = Vec::new();
        for r in rows.iter().filter(|r| r.label.starts_with(group) && r.label.ends_with(ours)) {
            let prefix = r.label.rsplit_once('/').unwrap().0;
            if let Some(br) = rows.iter().find(|x| x.label == format!("{prefix}/{b}")) {
                let (a, bb) = (r.get("decode_tps").unwrap(), br.get("decode_tps").unwrap());
                if bb > 0.0 {
                    ratios.push(a / bb);
                }
            }
        }
        if !ratios.is_empty() {
            let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
            println!("  -> {group}: HOBBIT vs {b}: mean decode speedup {mean:.2}x");
        }
    }
}

/// Fig 15: RTX 4090 + CPU cooperative computing group.
pub fn fig15() -> Vec<Row> {
    section("Fig 15 — CPU-GPU cooperative mode (4090 + CPU)");
    let hw = SimHardware::rtx4090();
    let mut rows = Vec::new();
    for model in [SimModel::mixtral_8x7b(), SimModel::phi_moe()] {
        for (inp, out) in LEN_GROUPS {
            let traces = traces_for(&model, 2, out, 29 + inp as u64);
            for sys in baselines::group_rtx4090_cpu() {
                let (p, d) = simulate_decode(&sys, &hw, &model, &traces, inp, 5);
                rows.push(
                    Row::new(format!("{}/[{inp},{out}]/{}", model.name, sys.name))
                        .push("decode_tps", d.tps())
                        .push("prefill_s", p.latency),
                );
            }
        }
    }
    super::print_rows(&rows);
    rows
}

/// Fig 16: dynamic expert loading ablation — speedup of HOBBIT over
/// HOBBIT-without-mixed-precision across all setups.
pub fn fig16() -> Vec<Row> {
    section("Fig 16 — dynamic (mixed-precision) expert loading speedup");
    let mut rows = Vec::new();
    let setups: [(&str, SimHardware, f64, f64); 3] = [
        ("orin", SimHardware::orin(), 8.0, 2.0),
        ("4090", SimHardware::rtx4090(), 16.0, 4.0),
        ("4090+cpu", SimHardware::rtx4090(), 16.0, 4.0),
    ];
    for (name, hw, hi_bits, lo_bits) in setups {
        for model in [SimModel::mixtral_8x7b(), SimModel::phi_moe()] {
            let traces = traces_for(&model, 2, 64, 31);
            let mut on = SimSystem::hobbit(EQ3_WEIGHTS);
            on.hi_bits = hi_bits;
            on.lo_bits = lo_bits;
            let mut off = on.clone();
            off.dynamic = false;
            off.lo_cache_frac = 0.0;
            if name == "4090+cpu" {
                on.miss_mode = crate::sim::des::MissMode::Cooperative;
                off.miss_mode = crate::sim::des::MissMode::Cooperative;
            }
            let don = simulate_decode(&on, &hw, &model, &traces, 16, 7).1;
            let doff = simulate_decode(&off, &hw, &model, &traces, 16, 7).1;
            rows.push(
                Row::new(format!("{name}/{}", model.name))
                    .push("speedup", don.tps() / doff.tps().max(1e-9)),
            );
        }
    }
    super::print_rows(&rows);
    rows
}

/// Fig 17(b): prefetch depth sweep, with and without dynamic loading.
pub fn fig17b() -> Vec<Row> {
    section("Fig 17(b) — adaptive prefetching ablation (depth 0-4)");
    let hw = SimHardware::rtx4090();
    let mut rows = Vec::new();
    for model in [SimModel::mixtral_8x7b(), SimModel::phi_moe()] {
        let traces = traces_for(&model, 2, 48, 37);
        for dynamic in [false, true] {
            for depth in 0..=4usize {
                let mut sys = SimSystem::hobbit(EQ3_WEIGHTS);
                sys.dynamic = dynamic;
                if !dynamic {
                    sys.lo_cache_frac = 0.0;
                }
                sys.prefetch_depth = depth;
                let d = simulate_decode(&sys, &hw, &model, &traces, 16, 9).1;
                rows.push(
                    Row::new(format!(
                        "{}/{}/p={depth}",
                        model.name,
                        if dynamic { "f16+i4" } else { "f16" }
                    ))
                    .push("tok/s", d.tps()),
                );
            }
        }
    }
    super::print_rows(&rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_loading_dominates() {
        let rows = fig3a();
        for r in &rows {
            assert!(r.get("load%").unwrap() > 60.0, "{}", r.label);
        }
        // Orin is more load-bound than the 4090
        assert!(rows[1].get("load%").unwrap() > rows[0].get("load%").unwrap());
    }

    #[test]
    fn fig9_ordering() {
        let rows = fig9();
        let tps = |i: usize| rows[i].get("tok/s").unwrap();
        // high-acc prefetch beats no prefetch; mixed softens low-acc penalty
        assert!(tps(1) >= tps(0) * 0.98, "(b) {} vs (a) {}", tps(1), tps(0));
        assert!(tps(3) >= tps(4), "(d) should beat (e)");
        assert!(tps(4) >= tps(2), "(e) mixed should soften the (c) penalty");
    }

    #[test]
    fn fig16_speedups_in_paper_band() {
        // paper: 1.19x - 1.57x
        for r in fig16() {
            let s = r.get("speedup").unwrap();
            assert!(s > 1.0, "{}: speedup {s}", r.label);
            assert!(s < 3.0, "{}: speedup {s} implausible", r.label);
        }
    }
}
