//! Regenerates every table and figure of the paper's evaluation (§5) plus
//! the motivation/analysis figures (§2-3). Each `figNN` function prints
//! the same rows/series the paper reports and returns them for tests.
//! Absolute numbers come from this testbed's simulator/engine; the *shape*
//! (who wins, by what factor, where crossovers fall) is the reproduction
//! target — see EXPERIMENTS.md for paper-vs-measured.
//!
//! * sim-scale figures (paper-size models over modeled PCIe/SSD links):
//!   Fig 3a, 9, 14, 15, 16, 17b — `endtoend` module
//! * trace/cache figures: Fig 10, 11, 18 — `analysis` module
//! * live-engine figures (tiny models through PJRT): Fig 3b, 5, 7, 17a,
//!   Table 3 — `real` module (requires built artifacts)

pub mod analysis;
pub mod endtoend;
pub mod real;

/// Pretty section header shared by all figure printers.
pub fn section(title: &str) {
    println!();
    println!("==== {title} ====");
}

/// A printed row: label + named values (also returned for tests).
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub values: Vec<(String, f64)>,
}

impl Row {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), values: Vec::new() }
    }

    pub fn push(mut self, k: &str, v: f64) -> Self {
        self.values.push((k.to_string(), v));
        self
    }

    pub fn get(&self, k: &str) -> Option<f64> {
        self.values.iter().find(|(n, _)| n == k).map(|(_, v)| *v)
    }

    pub fn print(&self) {
        print!("{:<36}", self.label);
        for (k, v) in &self.values {
            let vstr = if v.abs() >= 1000.0 {
                format!("{v:.0}")
            } else if v.abs() >= 10.0 {
                format!("{v:.1}")
            } else {
                format!("{v:.3}")
            };
            print!(" {k}={vstr:<10}");
        }
        println!();
    }
}

pub fn print_rows(rows: &[Row]) {
    for r in rows {
        r.print();
    }
}
