//! Byte-level tokenizer: 256 byte tokens + BOS/EOS/PAD/UNK specials.
//! The tiny models are trained on nothing (random init), so a byte
//! vocabulary keeps the serving path end-to-end real without shipping a
//! BPE table (DESIGN.md substitutions).

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const PAD: u32 = 258;
pub const UNK: u32 = 259;
pub const VOCAB: usize = 260;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Self
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB
    }

    /// Encode text to token ids, prepending BOS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(text.bytes().map(|b| b as u32));
        out
    }

    /// Decode token ids back to text, skipping specials; invalid UTF-8 is
    /// replaced.
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> =
            tokens.iter().filter(|&&t| t < 256).map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, t: u32) -> bool {
        t >= 256
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tk = Tokenizer::new();
        let toks = tk.encode("hello, MoE!");
        assert_eq!(toks[0], BOS);
        assert_eq!(toks.len(), 12);
        assert_eq!(tk.decode(&toks), "hello, MoE!");
    }

    #[test]
    fn roundtrip_utf8() {
        let tk = Tokenizer::new();
        let s = "héllo ☕";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let tk = Tokenizer::new();
        assert_eq!(tk.decode(&[BOS, b'a' as u32, EOS, PAD, UNK]), "a");
        assert!(tk.is_special(EOS));
        assert!(!tk.is_special(65));
    }

    #[test]
    fn all_tokens_below_vocab() {
        let tk = Tokenizer::new();
        for t in tk.encode("any text at all \u{1F600}") {
            assert!((t as usize) < VOCAB);
        }
    }
}
