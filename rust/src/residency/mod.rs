//! Expert residency: one session-scoped facade over the loader, the cache
//! manager, and the predictor.
//!
//! HOBBIT's three techniques — token-level dynamic loading (§3.2),
//! layer-level prefetching (§3.3), sequence-level caching (§3.4) — are one
//! hierarchy in the paper, and [`ExpertResidency`] is that hierarchy's
//! single entry point: the engine and coordinator never touch
//! `ExpertLoader::submit`/`wait` or `CacheManager::reserve`/`commit`
//! directly. The facade adds the cross-sequence machinery the raw parts
//! cannot express:
//!
//! * **Typed tickets** — [`Ticket`] replaces the raw `u64` task-id lists
//!   threaded through the decode cursor: a ticket knows its expert, pool,
//!   precision, and kind, is cheap to clone, and supports polling
//!   ([`Ticket::is_ready`]), blocking ([`TicketSet::block`] via
//!   [`ExpertResidency::wait`]), and push wakeups ([`Ticket::on_ready`]).
//! * **Shared wait-set** — two sequences missing on the same expert share
//!   one load task: the second request *joins* the first's ticket instead
//!   of silently bouncing off the loader's dedup (`dedup_hits`/
//!   `dedup_total` in `LoaderStats` count exactly these joins). An
//!   on-demand join of a prefetch promotes it to the priority lane —
//!   *queued* tasks move lanes, and since the chunked pipeline a *started*
//!   transfer's remaining chunks are re-prioritized too (Fig 9's
//!   non-preemptible penalty, removed).
//! * **RAII sessions** — [`SequenceSession`] scopes a live sequence's
//!   cache records and prefetch generation: dropping the session retires
//!   its records and marks its generation scope stale, so nothing leaks
//!   when a request completes, errors, or is aborted.
//! * **Scoped prefetch generations** — each session bumps its own
//!   generation, so one sequence's token advance no longer cancels other
//!   sequences' queued prefetches (the old global bump did).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cache::{CacheManager, Pool};
use crate::config::IoConfig;
use crate::loader::scorer::Class;
use crate::loader::{ExpertLoader, GenTable, LoadOutcome, LoaderIo, TaskKind, GLOBAL_SCOPE};
use crate::memory::ThrottledCopier;
use crate::metrics::{CacheStats, LoaderStats};
use crate::model::ExpertStore;
use crate::predictor::Predictor;
use crate::remote::{FetchTier, TieredStore};
use crate::{ExpertKey, Precision};

/// One expert the barrier decided to execute: key, effective precision
/// class, and the per-row gate weights to apply.
pub type ExpertUse = (ExpertKey, Class, Vec<f32>);

/// One expert demanded of an ensure-resident barrier: the routing decision
/// plus the scorer's criticality (unimportance) score — plumbed through so
/// the facade's precision-floor decision sees it instead of re-deriving it
/// from gate probs at every call site. Lower score = more critical
/// (`loader/scorer.rs::Decision`); demands folded from several rows carry
/// the minimum (most critical) score.
pub type Demand = (ExpertKey, Class, Vec<f32>, f64);

/// One entry of a batched step's *merged* ensure-resident barrier: a
/// unique (expert, precision class) demanded by one or more rows of the
/// launch. [`ExpertResidency::acquire_merged`] probes/pins/loads it once
/// for the whole batch and the engine executes it once at launch width.
#[derive(Debug, Clone)]
pub struct MergedUse {
    pub key: ExpertKey,
    /// requested class going in; *effective* class coming out (a Lo
    /// request served by a resident Hi copy is upgraded, like `acquire`)
    pub class: Class,
    /// per-launch-row gate weights (zero = row not routed to this expert)
    pub gatew: Vec<f32>,
    /// demanding rows' launch indices (parallel to `seqs`)
    pub rows: Vec<usize>,
    /// demanding rows' sessions, for cache-record attribution
    pub seqs: Vec<Option<u64>>,
    /// minimum (most critical) scorer unimportance score across the
    /// demanding rows — the precision-floor input
    pub score: f64,
}

// ---------------------------------------------------------------------
// Tickets
// ---------------------------------------------------------------------

struct LoadStateInner {
    done: bool,
    /// push-subscribers (serving front-end wakeups); fired on completion
    waiters: Vec<Box<dyn FnOnce() + Send>>,
}

/// Shared completion state of one load task. Unlike the loader's done-set,
/// readiness is *non-consuming*: any number of tickets can observe it.
/// `task_id` is atomic because a `NoSlot` completion re-acquires under the
/// same state: the retry submits a fresh loader task and re-points the
/// shared state at it, so joiners keep promoting/joining the live task.
struct LoadState {
    task_id: AtomicU64,
    /// false once the state resolved WITHOUT the expert becoming resident
    /// (a `NoSlot` drop that exhausted its re-acquire budget) — readers
    /// then bypass the cache
    unfulfilled: AtomicBool,
    inner: Mutex<LoadStateInner>,
    cv: Condvar,
}

impl LoadState {
    fn new(task_id: u64) -> Arc<Self> {
        Arc::new(Self {
            task_id: AtomicU64::new(task_id),
            unfulfilled: AtomicBool::new(false),
            inner: Mutex::new(LoadStateInner { done: false, waiters: Vec::new() }),
            cv: Condvar::new(),
        })
    }

    fn task_id(&self) -> u64 {
        self.task_id.load(Ordering::SeqCst)
    }

    fn complete(&self, fulfilled: bool) {
        if !fulfilled {
            self.unfulfilled.store(true, Ordering::SeqCst);
        }
        let waiters = {
            let mut g = self.inner.lock().unwrap();
            g.done = true;
            std::mem::take(&mut g.waiters)
        };
        self.cv.notify_all();
        for w in waiters {
            w();
        }
    }

    fn is_done(&self) -> bool {
        self.inner.lock().unwrap().done
    }

    fn block(&self) {
        let mut g = self.inner.lock().unwrap();
        while !g.done {
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Timed variant of [`Self::block`]: true when the state resolved,
    /// false when `timeout` elapsed first (the watchdog's wedge signal).
    fn block_for(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        while !g.done {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
        }
        true
    }

    /// Register a wakeup; false (not registered) if already complete.
    fn subscribe(&self, cb: Box<dyn FnOnce() + Send>) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.done {
            return false;
        }
        g.waiters.push(cb);
        true
    }
}

/// Typed handle to one in-flight expert load. Clones share completion
/// state, so any number of sequences can wait on the same transfer.
#[derive(Clone)]
pub struct Ticket {
    key: ExpertKey,
    pool: Pool,
    precision: Precision,
    kind: TaskKind,
    state: Arc<LoadState>,
}

impl Ticket {
    pub fn key(&self) -> ExpertKey {
        self.key
    }

    pub fn pool(&self) -> Pool {
        self.pool
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn kind(&self) -> TaskKind {
        self.kind
    }

    /// Loader task id (diagnostics only — residency owns the lifecycle;
    /// a `NoSlot` re-acquire re-points the shared state at a fresh task).
    pub fn task_id(&self) -> u64 {
        self.state.task_id()
    }

    /// Non-consuming readiness poll.
    pub fn is_ready(&self) -> bool {
        self.state.is_done()
    }

    /// False when the load resolved WITHOUT the expert becoming resident
    /// (every re-acquire attempt found no evictable slot). Waiters still
    /// wake — execution then bypasses the cache and reads next-level
    /// memory directly — but must not treat the slot as live.
    pub fn is_fulfilled(&self) -> bool {
        !self.state.unfulfilled.load(Ordering::SeqCst)
    }

    /// Register a push wakeup, fired once when the load completes (on the
    /// scheduler thread). Returns false — and does NOT register — when the
    /// load already completed: the caller should not park on it.
    pub fn on_ready<F: FnOnce() + Send + 'static>(&self, cb: F) -> bool {
        self.state.subscribe(Box::new(cb))
    }
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket")
            .field("key", &self.key)
            .field("pool", &self.pool)
            .field("precision", &self.precision)
            .field("kind", &self.kind)
            .field("task_id", &self.state.task_id())
            .field("ready", &self.is_ready())
            .finish()
    }
}

/// The tickets one ensure-resident barrier waits on.
#[derive(Debug, Default)]
pub struct TicketSet {
    tickets: Vec<Ticket>,
}

impl TicketSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: Ticket) {
        self.tickets.push(t);
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn tickets(&self) -> &[Ticket] {
        &self.tickets
    }

    /// Non-consuming poll: true when every ticket's load has completed.
    pub fn all_ready(&self) -> bool {
        self.tickets.iter().all(|t| t.is_ready())
    }
}

// ---------------------------------------------------------------------
// Sessions
// ---------------------------------------------------------------------

/// RAII handle to one live sequence's residency state: per-sequence cache
/// records (LRU/LFU/LHU) and a private prefetch-generation scope. Dropping
/// the session retires both — on completion, error, or abort alike — so
/// the `begin_sequence_id`/`end_sequence_id` pairing can no longer be
/// forgotten.
pub struct SequenceSession {
    seq: u64,
    cache: Arc<Mutex<CacheManager>>,
    gens: GenTable,
}

impl SequenceSession {
    /// The sequence id (cache-record key and prefetch-generation scope).
    pub fn id(&self) -> u64 {
        self.seq
    }
}

impl Drop for SequenceSession {
    fn drop(&mut self) {
        self.cache.lock().unwrap().end_sequence_id(self.seq);
        // retire the generation scope: every queued prefetch of this
        // sequence becomes stale; the loader GCs the entry once its
        // prefetch lane drains
        self.gens.lock().unwrap().insert(self.seq, u64::MAX);
    }
}

// ---------------------------------------------------------------------
// The facade
// ---------------------------------------------------------------------

/// How many times a `NoSlot` completion re-acquires before the state
/// resolves unfulfilled (waiters then bypass the cache). A no-slot drop is
/// usually transient — pins release as soon as the pinning rows execute —
/// but a bounded budget keeps a pathologically pinned pool from wedging
/// its waiters forever.
const NOSLOT_REACQUIRES: u32 = 3;

/// The shared wait-set: (key, pool) of every load between submission and
/// completion.
type InflightMap = Arc<Mutex<HashMap<(ExpertKey, Pool), Arc<LoadState>>>>;

/// Exactly-once completion hook for one loader task: clear the wait-set
/// entry, then resolve the shared state (the loader-side done marker is
/// consumed so it cannot accumulate).
///
/// This is where the facade fixes the silent no-slot completion: a task
/// that finished [`LoadOutcome::NoSlot`] left the expert non-resident, so
/// instead of waking ticket waiters — who would then execute off a slot
/// that does not exist — the facade *re-acquires*: it submits a fresh
/// on-demand task for the same (expert, pool) under the same shared
/// state, re-points `task_id` at it (so joiners keep promoting the live
/// task), and installs this hook again with one less retry. Only when the
/// budget is exhausted does the state resolve unfulfilled
/// ([`Ticket::is_fulfilled`] = false); execution then bypasses the cache.
/// A free function (not a method) because it must re-install itself from
/// inside the completion callback, where no `&self` exists.
#[allow(clippy::too_many_arguments)]
fn install_completion(
    io: LoaderIo,
    inflight: InflightMap,
    key: ExpertKey,
    precision: Precision,
    upgrade_to: Option<Precision>,
    pool: Pool,
    kind: TaskKind,
    layer: u32,
    scope: u64,
    state: Arc<LoadState>,
    reacquires: u32,
) {
    let id = state.task_id();
    let io_retry = io.clone();
    io.on_complete_consume_outcome(id, move |_, outcome| {
        let mut fulfilled = outcome == LoadOutcome::Fulfilled;
        // Corrupt is NoSlot-shaped: the slot was quarantined, the expert is
        // not resident, and a fresh task re-fetches the store's clean copy
        // — the integrity layer's bounded self-heal rides the same
        // re-acquire machinery
        let heal = outcome == LoadOutcome::Corrupt;
        if (outcome == LoadOutcome::NoSlot || heal)
            && kind == TaskKind::OnDemand
            && reacquires > 0
        {
            // re-acquire: a fresh task gets a fresh reserve() attempt
            // (pins may have released since); a staged plan stays staged
            if let Some(new_id) =
                io_retry.submit_staged(key, precision, upgrade_to, pool, kind, layer, scope)
            {
                if heal {
                    io_retry.stats.lock().unwrap().integrity_refetches += 1;
                }
                state.task_id.store(new_id, Ordering::SeqCst);
                install_completion(
                    io_retry,
                    inflight,
                    key,
                    precision,
                    upgrade_to,
                    pool,
                    kind,
                    layer,
                    scope,
                    state,
                    reacquires - 1,
                );
                return;
            }
            // submit found the expert resident/incoming after all (a
            // concurrent load landed between the drop and the retry):
            // that IS fulfillment
            fulfilled = true;
        }
        {
            let mut map = inflight.lock().unwrap();
            let ours = map
                .get(&(key, pool))
                .map(|s| Arc::ptr_eq(s, &state))
                .unwrap_or(false);
            if ours {
                map.remove(&(key, pool));
            }
        }
        // NoSlot (out of retries) and Stale alike leave the expert
        // non-resident: waiters wake but must not trust the slot
        state.complete(fulfilled);
    });
}

/// The session-scoped residency facade: owns the loader + cache manager +
/// predictor interaction and is the only API the engine and coordinator
/// use to make experts resident.
pub struct ExpertResidency {
    loader: ExpertLoader,
    cache: Arc<Mutex<CacheManager>>,
    predictor: Predictor,
    /// shared wait-set; a second requester joins the existing entry's
    /// ticket instead of submitting a duplicate load
    inflight: InflightMap,
    gens: GenTable,
    next_seq: AtomicU64,
    hi: Precision,
    lo: Precision,
    /// next-level memory, now a tiered hierarchy: local DRAM shard →
    /// staged side-cache → peer shard servers → disk (tier byte sizes,
    /// cross-tier staging, and the remote counters merged into
    /// [`Self::loader_stats`])
    store: Arc<TieredStore>,
    /// shared link (arbiter queue depth = the link-pressure floor input)
    copier: Arc<ThrottledCopier>,
    /// progressive lo-bits-first streaming enabled (`PolicyConfig`)
    progressive: bool,
    /// frozen per-acquire choice (`--pin-precision`); None = dynamic
    pin: Option<Precision>,
    /// the scorer's T1 threshold: the Hi-class score band is `[0, t1]`,
    /// and the floor decision treats the band's upper half as
    /// lower-tier-tolerant
    score_t1: f64,
    /// the serving deadline policy reports TTFT urgency here; an urgent
    /// acquire lowers its precision floor to get usable bytes sooner
    deadline_urgent: AtomicBool,
    /// overload ladder stage 1 (coordinator admission-queue depth / SLO
    /// risk): while set, every hi-pool miss floors at the lo precision —
    /// precision sheds before requests do
    queue_pressure: AtomicBool,
    /// overload ladder stage 2: drop speculative prefetch planning so the
    /// link serves on-demand misses only
    prefetch_shed: AtomicBool,
    /// wedged-ticket watchdog period ([`IoConfig::watchdog_ms`]; zero
    /// disables): a ticket still unresolved after this long gets an
    /// idempotent re-submit — the loader's dedup makes the poke a no-op
    /// while the original task is merely slow
    watchdog: Duration,
}

impl ExpertResidency {
    /// Single-lane compat constructor (the pre-pipeline transfer
    /// serialization); the engine passes its configured [`IoConfig`]
    /// through [`Self::with_io`] instead.
    pub fn new(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
        predictor: Predictor,
        hi: Precision,
        lo: Precision,
    ) -> Self {
        Self::with_io(store, cache, copier, predictor, hi, lo, IoConfig::single_lane())
    }

    /// Build the facade over a loader running `io.lanes` transfer lanes
    /// at `io.chunk_bytes` preemption granularity. The store is treated
    /// as fully local (every expert in host DRAM).
    pub fn with_io(
        store: Arc<ExpertStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
        predictor: Predictor,
        hi: Precision,
        lo: Precision,
        io: IoConfig,
    ) -> Self {
        Self::with_tiered(
            Arc::new(TieredStore::local_only(store)),
            cache,
            copier,
            predictor,
            hi,
            lo,
            io,
        )
    }

    /// Build the facade over a [`TieredStore`] — the remote-capable
    /// hierarchy (local DRAM shard → staged side-cache → peer → disk).
    /// Next-level fetches route through the tiers, hi-pool floor planning
    /// becomes tier-aware, and the predictor's staging candidates pull
    /// peer-resident experts into local DRAM ahead of demand.
    #[allow(clippy::too_many_arguments)]
    pub fn with_tiered(
        store: Arc<TieredStore>,
        cache: Arc<Mutex<CacheManager>>,
        copier: Arc<ThrottledCopier>,
        predictor: Predictor,
        hi: Precision,
        lo: Precision,
        io: IoConfig,
    ) -> Self {
        let watchdog = Duration::from_millis(io.watchdog_ms);
        let loader = ExpertLoader::start_tiered(store.clone(), cache.clone(), copier.clone(), io);
        let gens = loader.gen_table();
        Self {
            loader,
            cache,
            predictor,
            inflight: Arc::new(Mutex::new(HashMap::new())),
            gens,
            next_seq: AtomicU64::new(1),
            hi,
            lo,
            store,
            copier,
            progressive: false,
            pin: None,
            score_t1: 0.6,
            deadline_urgent: AtomicBool::new(false),
            queue_pressure: AtomicBool::new(false),
            prefetch_shed: AtomicBool::new(false),
            watchdog,
        }
    }

    /// Set the precision scheduling mode: `pin` freezes every hi-pool
    /// fetch at one precision (no staging); `progressive` enables the
    /// lo-bits-first staged streaming (mutually exclusive — validated by
    /// `PolicyConfig::validate`; pin wins here if both are set). `t1` is
    /// the scorer's Hi-class threshold, the criticality scale of
    /// the floor decision.
    pub fn with_precision_mode(
        mut self,
        pin: Option<Precision>,
        progressive: bool,
        t1: f64,
    ) -> Self {
        self.pin = pin;
        self.progressive = progressive && pin.is_none();
        self.score_t1 = t1;
        self
    }

    /// Map a scorer class to (precision, pool) under the active config.
    pub fn class_target(&self, class: Class) -> (Precision, Pool) {
        match class {
            Class::Hi => (self.hi, Pool::Hi),
            Class::Lo | Class::Skip => (self.lo, Pool::Lo),
        }
    }

    /// Report TTFT-deadline urgency (the serving deadline policy's 75%
    /// budget trip). While set, hi-pool misses floor at the lo precision.
    pub fn set_deadline_urgent(&self, urgent: bool) {
        self.deadline_urgent.store(urgent, Ordering::Relaxed);
    }

    /// Overload ladder stage 1 (coordinator): while set, hi-pool misses
    /// floor at the lo precision regardless of criticality or link state.
    pub fn set_queue_pressure(&self, on: bool) {
        self.queue_pressure.store(on, Ordering::Relaxed);
    }

    /// Overload ladder stage 2 (coordinator): while set,
    /// [`Self::plan_prefetch`] cancels queued speculative work and plans
    /// nothing new — the link belongs to on-demand misses.
    pub fn set_prefetch_shed(&self, on: bool) {
        self.prefetch_shed.store(on, Ordering::Relaxed);
    }

    /// Current stage-2 signal (test observability).
    pub fn prefetch_shed_active(&self) -> bool {
        self.prefetch_shed.load(Ordering::Relaxed)
    }

    /// Plan the fetch for a hi-pool miss: the start (floor) precision and
    /// the background upgrade target, decided per acquire from
    ///
    /// * **criticality** — the scorer's unimportance score: within the Hi
    ///   class, a score in the upper half of the `[0, t1]` band marks an
    ///   expert whose contribution tolerates a briefly-lower tier;
    /// * **deadline slack** — TTFT urgency reported by the serving
    ///   deadline policy ([`Self::set_deadline_urgent`]);
    /// * **overload pressure** — the coordinator's admission-queue ladder
    ///   ([`Self::set_queue_pressure`]): a deep queue means every live
    ///   request's TTFT is at risk, so precision sheds fleet-wide before
    ///   any request is refused;
    /// * **link pressure** — busy lanes on the shared link arbiter: a miss
    ///   that would fair-share the link with other transfers reaches
    ///   usability far sooner at the lo byte count;
    /// * **serving tier** — a record whose hi bytes live on a *peer* (not
    ///   in the local DRAM shard) pays a network round-trip before the
    ///   PCIe copy even starts, so a peer-tier miss counts as pressured:
    ///   the lo floor crosses the network in a fraction of the bytes and
    ///   the hi upgrade streams behind it.
    ///
    /// A pinned precision freezes the choice; with progressive off the
    /// plan is always (hi, no upgrade) — the pre-progressive byte stream.
    fn plan_fetch(&self, key: ExpertKey, score: f64) -> (Precision, Option<Precision>) {
        if let Some(p) = self.pin {
            return (p, None);
        }
        if !self.progressive || self.lo.bits() >= self.hi.bits() {
            return (self.hi, None);
        }
        let urgent = self.deadline_urgent.load(Ordering::Relaxed);
        let overloaded = self.queue_pressure.load(Ordering::Relaxed);
        let pressured = self.copier.active_lanes() >= 1;
        let tolerant = score > 0.5 * self.score_t1;
        let remote = self.store.has_remote()
            && matches!(self.store.tier_of(key, self.hi), FetchTier::Peer | FetchTier::Disk);
        if urgent || overloaded || pressured || tolerant || remote {
            (self.lo, Some(self.hi))
        } else {
            (self.hi, None)
        }
    }

    // ---- sessions ----------------------------------------------------

    /// Register a live sequence: fresh per-sequence cache records and a
    /// private prefetch-generation scope, both retired when the returned
    /// session drops.
    pub fn begin_session(&self) -> SequenceSession {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        self.cache.lock().unwrap().begin_sequence_id(seq);
        SequenceSession { seq, cache: self.cache.clone(), gens: self.gens.clone() }
    }

    /// Batch-1 sequence reset (§3.4): wipes the merged sequence-level
    /// records. Must not be used while sessions are live.
    pub fn reset_batch1(&self) {
        self.cache.lock().unwrap().reset_sequence();
    }

    /// Number of live (registered) sequence sessions.
    pub fn live_sequences(&self) -> usize {
        self.cache.lock().unwrap().live_sequences()
    }

    // ---- the ensure-resident barrier ---------------------------------

    /// Make one layer's routed experts resident: probe/pin each demanded
    /// expert, submit (or join) on-demand loads for misses, and return the
    /// execution set plus the tickets to wait on. Does NOT wait — blocking
    /// vs suspension is the caller's policy. `seq` attributes cache-record
    /// traffic to a live session (None = the batch-1 global records).
    pub fn acquire(
        &self,
        layer: u32,
        demands: Vec<Demand>,
        seq: Option<u64>,
    ) -> (Vec<ExpertUse>, TicketSet) {
        let scope = seq.unwrap_or(GLOBAL_SCOPE);
        let mut waits = TicketSet::new();
        let mut uses: Vec<ExpertUse> = Vec::new();
        let mut cache = self.cache.lock().unwrap();
        cache.note_token_for(seq);
        for (key, class, gatew, score) in demands {
            if class == Class::Skip {
                let mut st = self.loader.stats.lock().unwrap();
                st.skipped += 1;
                continue;
            }
            let (c, eff_class) =
                self.acquire_one(cache, key, class, score, 1, layer, scope, &mut waits);
            cache = c;
            uses.push((key, eff_class, gatew));
        }
        drop(cache);
        (uses, waits)
    }

    /// The per-demand core both barriers share: probe (with hit/miss
    /// accounting), the Lo-request-served-by-a-resident-Hi-copy upgrade,
    /// one cache pin per demanding row, and the submit-or-join of the load
    /// on a miss. `m` is the demand's multiplicity — the number of rows
    /// behind it (1 on the solo path); rows beyond the first replicate the
    /// probe accounting and count as dedup joins of the shared task.
    /// Takes and returns the cache guard because a load submission must
    /// release it (lock order: never hold the cache lock into the loader).
    #[allow(clippy::too_many_arguments)]
    fn acquire_one<'a>(
        &'a self,
        mut cache: std::sync::MutexGuard<'a, CacheManager>,
        key: ExpertKey,
        class: Class,
        score: f64,
        m: usize,
        layer: u32,
        scope: u64,
        waits: &mut TicketSet,
    ) -> (std::sync::MutexGuard<'a, CacheManager>, Class) {
        let (_prec, pool) = self.class_target(class);
        let first_hit = cache.access(key, pool);
        let mut hit = first_hit;
        // a Lo request served by a resident Hi copy is a free upgrade
        let mut eff_class = class;
        if !first_hit && pool == Pool::Lo && cache.hi.contains_ready(key) {
            hit = true;
            eff_class = Class::Hi;
            cache.stats.hits_hi += 1;
            // undo the lo-miss penalty charged by access()
            cache.stats.misses_lo -= 1;
            cache.stats.miss_penalty -= cache.penalty_ratio();
        }
        // rows 2..m see the same outcome the instant after the first
        // probe; replicate the per-access accounting for them
        for _ in 1..m {
            if hit {
                match eff_class {
                    Class::Hi => cache.stats.hits_hi += 1,
                    _ => cache.stats.hits_lo += 1,
                }
            } else {
                match pool {
                    Pool::Hi => {
                        cache.stats.misses_hi += 1;
                        cache.stats.miss_penalty += 1.0;
                    }
                    Pool::Lo => {
                        cache.stats.misses_lo += 1;
                        cache.stats.miss_penalty += cache.penalty_ratio();
                    }
                }
            }
        }
        // one pin per demanding row, all released by that row's FFN
        // execution (solo, batched, or post-eviction solo)
        let mut pinned = true;
        for _ in 0..m {
            pinned = match eff_class {
                Class::Hi => cache.hi.pin(key),
                _ => cache.lo.pin(key),
            };
        }
        debug_assert!(!hit || pinned, "hit on {key:?} must pin a live slot");
        if !hit {
            drop(cache);
            let (prec, pool) = self.class_target(eff_class);
            // hi-pool misses consult the progressive plan (floor precision
            // + background upgrade); lo-pool slots are sized for lo only
            let (start, upgrade_to) = match pool {
                Pool::Hi => self.plan_fetch(key, score),
                Pool::Lo => (prec, None),
            };
            if let Some(t) = self.request_load(
                key, start, upgrade_to, pool, TaskKind::OnDemand, layer, scope,
            ) {
                waits.push(t);
            }
            // the other m-1 demanding rows joined the same task — the
            // in-batch share of the dedup accounting
            if m > 1 {
                let mut st = self.loader.stats.lock().unwrap();
                st.dedup_total += (m - 1) as u64;
                st.dedup_hits += (m - 1) as u64;
            }
            cache = self.cache.lock().unwrap();
        }
        (cache, eff_class)
    }

    /// The batched step's merged ensure-resident barrier: one call per
    /// (batch, layer). Each entry of `demands` is a unique
    /// (expert, class) with the rows that routed it; the facade
    ///
    /// * probes and (per demanding row) pins each expert exactly once,
    /// * submits — or joins — exactly one load task per unique cache-miss
    ///   expert, counting the in-batch duplicates as dedup joins
    ///   (`dedup_hits`/`dedup_total` account for every duplicate, the same
    ///   as a cross-sequence join on the solo path),
    /// * advances the token tick of every participating session once.
    ///
    /// Pin counts are per demanding row (they stack), so a row evicted
    /// from the batch mid-barrier can release exactly its own pins and
    /// the remaining rows keep theirs. Returns the execution set (classes
    /// upgraded where a Hi copy serves a Lo request) plus the tickets to
    /// wait on; like `acquire`, it never waits.
    pub fn acquire_merged(
        &self,
        layer: u32,
        demands: Vec<MergedUse>,
        batch_seqs: &[Option<u64>],
    ) -> (Vec<MergedUse>, TicketSet) {
        let mut waits = TicketSet::new();
        let mut uses: Vec<MergedUse> = Vec::with_capacity(demands.len());
        let mut cache = self.cache.lock().unwrap();
        for s in batch_seqs {
            cache.note_token_for(*s);
        }
        {
            let mut st = self.loader.stats.lock().unwrap();
            st.merged_acquires += 1;
            st.merged_unique +=
                demands.iter().filter(|d| d.class != Class::Skip).count() as u64;
            st.merged_demands += demands
                .iter()
                .filter(|d| d.class != Class::Skip)
                .map(|d| d.rows.len() as u64)
                .sum::<u64>();
        }
        let scope = batch_seqs.first().copied().flatten().unwrap_or(GLOBAL_SCOPE);
        for mut d in demands {
            let m = d.rows.len().max(1);
            if d.class == Class::Skip {
                self.loader.stats.lock().unwrap().skipped += m as u64;
                continue;
            }
            let (c, eff_class) =
                self.acquire_one(cache, d.key, d.class, d.score, m, layer, scope, &mut waits);
            cache = c;
            d.class = eff_class;
            uses.push(d);
        }
        drop(cache);
        (uses, waits)
    }

    /// The chunked-prefill ensure-resident barrier: one call per
    /// (chunk, layer). Each demand is a unique expert with the chunk's
    /// per-row gate weights and its row multiplicity — the number of
    /// chunk rows routed to it. Like [`Self::acquire`] it probes, pins
    /// (once per expert: the chunk executes each expert once at chunk
    /// width and releases exactly one pin), and submits-or-joins one load
    /// per unique miss; additionally the in-chunk sharing is counted in
    /// the prefill-merged ledger (`prefill_merged_*` in `LoaderStats`,
    /// surfaced under the `"serving"` report key — the blocking
    /// [`Self::acquire`] path never bumps these, so FCFS reports are
    /// unchanged). Never waits.
    pub fn acquire_chunk(
        &self,
        layer: u32,
        demands: Vec<(ExpertKey, Class, Vec<f32>, f64, usize)>,
        seq: Option<u64>,
    ) -> (Vec<ExpertUse>, TicketSet) {
        {
            let mut st = self.loader.stats.lock().unwrap();
            st.prefill_merged_acquires += 1;
            st.prefill_merged_unique +=
                demands.iter().filter(|d| d.1 != Class::Skip).count() as u64;
            st.prefill_merged_demands += demands
                .iter()
                .filter(|d| d.1 != Class::Skip)
                .map(|d| d.4 as u64)
                .sum::<u64>();
        }
        // delegate the probe/pin/load walk to `acquire` itself: the two
        // prefill paths share one implementation by construction, so a fix
        // to the pin/upgrade logic can never miss the chunked path
        let plain: Vec<Demand> = demands
            .into_iter()
            .map(|(key, class, gatew, score, _rows)| (key, class, gatew, score))
            .collect();
        self.acquire(layer, plain, seq)
    }

    /// Submit a load — or join the in-flight one for the same
    /// (expert, pool). Returns None when the expert is already resident.
    #[allow(clippy::too_many_arguments)]
    fn request_load(
        &self,
        key: ExpertKey,
        precision: Precision,
        upgrade_to: Option<Precision>,
        pool: Pool,
        kind: TaskKind,
        layer: u32,
        scope: u64,
    ) -> Option<Ticket> {
        let mut inflight = self.inflight.lock().unwrap();
        if kind == TaskKind::OnDemand {
            self.loader.stats.lock().unwrap().dedup_total += 1;
        }
        if let Some(state) = inflight.get(&(key, pool)) {
            let state = state.clone();
            drop(inflight);
            match kind {
                TaskKind::OnDemand => {
                    self.loader.stats.lock().unwrap().dedup_hits += 1;
                    // an on-demand arrival jumps a queued prefetch into
                    // the priority lane — and since the chunked pipeline,
                    // a *started* prefetch's remaining chunks are
                    // re-prioritized too (the Fig 9 penalty, removed)
                    self.loader.promote_to_ondemand(state.task_id());
                }
                TaskKind::Prefetch => {
                    // a re-planned prefetch joining its own previous-token
                    // task: re-stamp it with the requester's current
                    // generation so the planner's bump doesn't doom it
                    self.loader.refresh_prefetch(state.task_id(), scope);
                }
            }
            return Some(Ticket { key, pool, precision, kind, state });
        }
        let id =
            self.loader.submit_staged(key, precision, upgrade_to, pool, kind, layer, scope)?;
        let state = LoadState::new(id);
        inflight.insert((key, pool), state.clone());
        drop(inflight);
        install_completion(
            self.loader.io(),
            self.inflight.clone(),
            key,
            precision,
            upgrade_to,
            pool,
            kind,
            layer,
            scope,
            state.clone(),
            NOSLOT_REACQUIRES,
        );
        Some(Ticket { key, pool, precision, kind, state })
    }

    /// Block until every ticket in `waits` resolves; the blocked time is
    /// charged to the loader's `wait_time` (the unhidden-stall metric on
    /// the batch-1 path). Returns the wall time spent.
    ///
    /// With a nonzero [`IoConfig::watchdog_ms`] the block is supervised: a
    /// ticket still unresolved after a full watchdog period is presumed
    /// wedged (a completion lost to a fault, a lane stalled forever) and
    /// recovered via [`Self::recover_wedged`]; the wait then resumes. A
    /// slow-but-alive load tolerates the poke — re-submission dedups
    /// against the resident/incoming slot — so the watchdog can only add
    /// latency, never change what gets served.
    pub fn wait(&self, waits: &TicketSet) -> Duration {
        let t0 = Instant::now();
        for t in waits.tickets() {
            if self.watchdog.is_zero() {
                t.state.block();
            } else {
                while !t.state.block_for(self.watchdog) {
                    self.recover_wedged(t);
                }
            }
        }
        let waited = t0.elapsed();
        self.loader.stats.lock().unwrap().wait_time += waited;
        waited
    }

    /// Watchdog recovery for one wedged ticket: count the event, then
    /// re-submit the load under the same shared state. If the original
    /// task is alive the submit finds the expert incoming and returns
    /// None — the poke was a no-op; if the task (or its completion) was
    /// lost, the fresh on-demand task re-points the state and its
    /// completion hook resolves the ticket.
    fn recover_wedged(&self, t: &Ticket) {
        self.loader.stats.lock().unwrap().watchdog_recoveries += 1;
        if let Some(new_id) = self.loader.submit_staged(
            t.key,
            t.precision,
            None,
            t.pool,
            TaskKind::OnDemand,
            t.key.layer,
            GLOBAL_SCOPE,
        ) {
            t.state.task_id.store(new_id, Ordering::SeqCst);
            install_completion(
                self.loader.io(),
                self.inflight.clone(),
                t.key,
                t.precision,
                None,
                t.pool,
                TaskKind::OnDemand,
                t.key.layer,
                GLOBAL_SCOPE,
                t.state.clone(),
                NOSLOT_REACQUIRES,
            );
        }
    }

    // ---- post-barrier accessors (FFN execution path) -----------------

    /// Slot buffer of a resident expert (None if it was never committed —
    /// e.g. its load was dropped as stale — or was evicted under extreme
    /// pressure; callers then bypass the cache).
    pub fn buffer(&self, key: ExpertKey, pool: Pool) -> Option<Arc<Mutex<Vec<u8>>>> {
        let cache = self.cache.lock().unwrap();
        match pool {
            Pool::Hi => cache.hi.buffer(key),
            Pool::Lo => cache.lo.buffer(key),
        }
    }

    /// Snapshot the resident tier and its exact record bytes for a Ready
    /// expert. A progressive slot may hold a narrower record than the
    /// pool's native precision (as a prefix of the slot), so callers that
    /// execute must read (tier, bytes) as one atomic pair: the clone
    /// happens with the slot buffer locked under the cache lock — the
    /// same order `commit_upgrade` uses — so an in-place upgrade can
    /// never be observed half-applied. Returns None when the expert is
    /// not Ready (callers then bypass the cache as before).
    pub fn resident_record(&self, key: ExpertKey, pool: Pool) -> Option<(Precision, Vec<u8>)> {
        let mut cache = self.cache.lock().unwrap();
        // reads rotate across the primary and any hot-expert replicas
        // (DRAM-to-DRAM copies of the same bytes), spreading slot-lock
        // contention without changing what is read
        let (buf, tier) = cache.read_buffer_tier(key, pool)?;
        let prec = tier.unwrap_or(match pool {
            Pool::Hi => self.hi,
            Pool::Lo => self.lo,
        });
        let n = self.store.record_bytes(prec);
        let guard = buf.lock().unwrap();
        debug_assert!(guard.len() >= n, "slot smaller than resident record");
        Some((prec, guard[..n].to_vec()))
    }

    /// The grouped step's snapshot arena: one owned (tier, bytes) snapshot
    /// per unique (expert, pool) of a batch step, shared by every use that
    /// demanded it. `wants` may repeat a key (e.g. a Lo demand upgraded to
    /// a resident Hi copy colliding with a native Hi demand); repeats
    /// reuse the first copy and are counted as `snapshot_reuses`, actual
    /// clones as `snapshot_copies`. Absent entries mean the expert is not
    /// Ready — callers bypass the cache for those uses, exactly like a
    /// None from [`Self::resident_record`]. Each clone happens with the
    /// slot buffer locked under the one cache lock (the `commit_upgrade`
    /// order), and reads rotate across replicas like `resident_record`.
    pub fn snapshot_records(
        &self,
        wants: &[(ExpertKey, Pool)],
    ) -> HashMap<(ExpertKey, Pool), (Precision, Vec<u8>)> {
        let mut out: HashMap<(ExpertKey, Pool), (Precision, Vec<u8>)> = HashMap::new();
        let (mut copies, mut reuses) = (0u64, 0u64);
        let mut cache = self.cache.lock().unwrap();
        for &(key, pool) in wants {
            if out.contains_key(&(key, pool)) {
                reuses += 1;
                continue;
            }
            let Some((buf, tier)) = cache.read_buffer_tier(key, pool) else {
                continue;
            };
            let prec = tier.unwrap_or(match pool {
                Pool::Hi => self.hi,
                Pool::Lo => self.lo,
            });
            let n = self.store.record_bytes(prec);
            let guard = buf.lock().unwrap();
            debug_assert!(guard.len() >= n, "slot smaller than resident record");
            out.insert((key, pool), (prec, guard[..n].to_vec()));
            copies += 1;
        }
        drop(cache);
        let mut st = self.loader.stats.lock().unwrap();
        st.snapshot_copies += copies;
        st.snapshot_reuses += reuses;
        out
    }

    /// Fold one grouped FFN launch's execution counters into the loader
    /// ledger (surfaced under the `"serving"` report key only).
    pub fn note_grouped_exec(&self, launches: u64, rows: u64, dequant_reuses: u64) {
        let mut st = self.loader.stats.lock().unwrap();
        st.grouped_launches += launches;
        st.group_rows += rows;
        st.dequant_reuses += dequant_reuses;
    }

    /// Try to populate one read-replica of a hot Ready expert (bounded by
    /// the cache's replica budget; replicas only fill Free slots and are
    /// copied DRAM-to-DRAM, never fetched over the link).
    pub fn add_replica(&self, key: ExpertKey, pool: Pool) -> bool {
        self.cache.lock().unwrap().add_replica(key, pool)
    }

    /// Predictor heat probe: true when the expert's gate-score EMA marks
    /// it hot enough to be worth a read-replica.
    pub fn is_hot(&self, key: ExpertKey) -> bool {
        self.predictor.hot(key)
    }

    /// Record a realized use for the replacement policy, attributed to a
    /// live session (None = batch-1 records).
    pub fn note_use(&self, key: ExpertKey, pool: Pool, seq: Option<u64>) {
        self.cache.lock().unwrap().note_use_for(key, pool, seq);
    }

    /// Release the pin `acquire` took on an expert (after executing it, or
    /// when a suspended cursor is aborted).
    pub fn release(&self, key: ExpertKey, pool: Pool) {
        let mut cache = self.cache.lock().unwrap();
        let had_pin = match pool {
            Pool::Hi => cache.hi.unpin(key),
            Pool::Lo => cache.lo.unpin(key),
        };
        debug_assert!(had_pin, "unbalanced unpin for {key:?} in {pool:?}");
    }

    // ---- predictor (layer-level prefetching) -------------------------

    /// Predictor step: invalidate the scope's queued prefetches from the
    /// previous token, plan mixed-precision prefetches from the stacked
    /// gate output, and submit them under the scope's generation.
    pub fn plan_prefetch(
        &mut self,
        scope: u64,
        current_layer: u32,
        n_layers: u32,
        stacked: &[Vec<f32>],
    ) {
        self.loader.bump_prefetch_generation_for(scope);
        // Overload ladder stage 2: the generation bump above has already
        // invalidated this scope's queued speculative work; planning
        // nothing new hands the whole link to on-demand misses until the
        // coordinator clears the signal.
        if self.prefetch_shed.load(Ordering::Relaxed) {
            return;
        }
        // Cross-tier staging: the DRAM→HBM prefetch below only looks one
        // uncovered layer ahead, but a PEER→DRAM pull pays a network
        // round-trip — far too long to hide in that window. So every
        // peer-resident candidate over the whole stacked horizon is handed
        // to the tiered store's background stager (network link, prefetch
        // weight) ahead of demand; by the time the one-layer prefetch or
        // the demand miss arrives, the bytes are in the staged side-cache.
        if self.store.has_remote() {
            for (key, class) in
                self.predictor.stage_candidates(current_layer, n_layers, stacked)
            {
                let (prec, pool) = self.class_target(class);
                let hi_floor = match pool {
                    Pool::Hi => self.plan_fetch(key, f64::MAX).0,
                    Pool::Lo => prec,
                };
                self.store.stage_async(key, hi_floor);
            }
        }
        let mut cache = self.cache.lock().unwrap();
        let plan = self.predictor.plan(&mut cache, current_layer, n_layers, stacked);
        drop(cache);
        if let Some(plan) = plan {
            {
                let mut stats = self.loader.stats.lock().unwrap();
                stats.prefetch_total += plan.experts.len() as u64;
            }
            for (key, class) in plan.experts {
                if class != Class::Skip {
                    let (prec, pool) = self.class_target(class);
                    let _ = self.request_load(
                        key,
                        prec,
                        None,
                        pool,
                        TaskKind::Prefetch,
                        current_layer,
                        scope,
                    );
                }
            }
        }
    }

    /// Score the pending prediction of an executed layer and release its
    /// pins; pushes realized tracker hits into the loader stats (single
    /// source of truth for prefetch accounting).
    pub fn observe(&mut self, layer: u32, layer_probs_first: &[f32]) {
        let mut cache = self.cache.lock().unwrap();
        self.predictor.observe(&mut cache, layer, layer_probs_first);
        let hits = self.predictor.tracker.per_offset[0].0;
        drop(cache);
        self.loader.stats.lock().unwrap().prefetch_hits = hits;
    }

    /// Prefetch depth of the active predictor (0 = prefetching off).
    pub fn prefetch_depth(&self) -> usize {
        self.predictor.depth
    }

    // ---- introspection ------------------------------------------------

    /// Snapshot of the loader counters (report sync, benches), with the
    /// tiered store's remote counters folded in (zeros on a local-only
    /// store, so reports without a remote tier are unchanged).
    pub fn loader_stats(&self) -> LoaderStats {
        let mut s = self.loader.stats.lock().unwrap().clone();
        self.store.merge_into(&mut s);
        s
    }

    /// The tiered next-level store (tests, benches, engine bypass reads).
    pub fn store(&self) -> &Arc<TieredStore> {
        &self.store
    }

    /// Snapshot of the cache counters (report sync, benches).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.lock().unwrap().stats.clone()
    }

    /// Realized prefetch accuracy at layer-offset `offset` (Fig 7b).
    pub fn prefetch_accuracy(&self, offset: usize) -> f64 {
        self.predictor.tracker.accuracy(offset)
    }

    /// Shared cache handle (tests and figures; the request path goes
    /// through the facade's own methods).
    pub fn cache_handle(&self) -> Arc<Mutex<CacheManager>> {
        self.cache.clone()
    }

    /// True when no load is queued or mid-transfer (drains in benches).
    pub fn is_idle(&self) -> bool {
        self.loader.is_idle()
    }
}
