//! Serving metrics: the paper's two headline numbers (prefill latency,
//! decode tokens/s) plus the loader/cache counters behind the ablations.

use std::time::Duration;

use crate::util::json::{arr, num, obj, s, Json};

#[derive(Debug, Clone, Default)]
pub struct LoaderStats {
    /// on-demand expert loads by precision slot (f32, q8, q4, q2)
    pub ondemand_loads: [u64; 4],
    /// prefetch loads by precision slot
    pub prefetch_loads: [u64; 4],
    /// experts skipped by the T2 threshold
    pub skipped: u64,
    /// bytes actually moved across the simulated PCIe/SSD link
    pub bytes_loaded: u64,
    /// wall-time the decode loop spent blocked on on-demand loads
    pub wait_time: Duration,
    /// prefetch predictions that turned out correct / total
    pub prefetch_hits: u64,
    pub prefetch_total: u64,
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits_hi: u64,
    pub hits_lo: u64,
    pub misses_hi: u64,
    pub misses_lo: u64,
    pub evictions: u64,
    /// §3.4 miss *penalty*: hi miss = 1.0, lo miss = B_l/B_h
    pub miss_penalty: f64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let hits = (self.hits_hi + self.hits_lo) as f64;
        let total = hits + (self.misses_hi + self.misses_lo) as f64;
        if total == 0.0 {
            0.0
        } else {
            hits / total
        }
    }
}

/// One generation's timing record.
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// time spent inside PJRT execute calls (compute)
    pub compute_time: Duration,
    /// time spent blocked on expert loading
    pub load_wait_time: Duration,
}

impl RequestMetrics {
    pub fn decode_tps(&self) -> f64 {
        let t = self.decode_time.as_secs_f64();
        if t <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / t
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("prompt_tokens", num(self.prompt_tokens as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("prefill_s", num(self.prefill_time.as_secs_f64())),
            ("decode_s", num(self.decode_time.as_secs_f64())),
            ("decode_tps", num(self.decode_tps())),
            ("compute_s", num(self.compute_time.as_secs_f64())),
            ("load_wait_s", num(self.load_wait_time.as_secs_f64())),
        ])
    }
}

/// Aggregate over a run of requests, exported by `hobbit serve --report`.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub requests: Vec<RequestMetrics>,
    pub loader: LoaderStats,
    pub cache: CacheStats,
}

impl RunReport {
    pub fn mean_decode_tps(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.decode_tps()).sum::<f64>() / self.requests.len() as f64
    }

    pub fn mean_prefill_s(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.prefill_time.as_secs_f64()).sum::<f64>()
            / self.requests.len() as f64
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("mean_decode_tps", num(self.mean_decode_tps())),
            ("mean_prefill_s", num(self.mean_prefill_s())),
            ("cache_hit_ratio", num(self.cache.hit_ratio())),
            ("miss_penalty", num(self.cache.miss_penalty)),
            ("bytes_loaded", num(self.loader.bytes_loaded as f64)),
            ("skipped", num(self.loader.skipped as f64)),
            (
                "prefetch_accuracy",
                num(if self.loader.prefetch_total == 0 {
                    0.0
                } else {
                    self.loader.prefetch_hits as f64 / self.loader.prefetch_total as f64
                }),
            ),
            ("requests", arr(self.requests.iter().map(|r| r.to_json()).collect())),
            ("schema", s("hobbit.run_report.v1")),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_math() {
        let r = RequestMetrics {
            generated_tokens: 50,
            decode_time: Duration::from_secs_f64(2.0),
            ..Default::default()
        };
        assert!((r.decode_tps() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn hit_ratio() {
        let c = CacheStats { hits_hi: 6, hits_lo: 2, misses_hi: 1, misses_lo: 1, ..Default::default() };
        assert!((c.hit_ratio() - 0.8).abs() < 1e-9);
        assert_eq!(CacheStats::default().hit_ratio(), 0.0);
    }

    #[test]
    fn report_json_parses() {
        let mut rep = RunReport::default();
        rep.requests.push(RequestMetrics {
            prompt_tokens: 16,
            generated_tokens: 32,
            prefill_time: Duration::from_millis(100),
            decode_time: Duration::from_secs(1),
            ..Default::default()
        });
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_arr().unwrap().len(), 1);
    }
}
