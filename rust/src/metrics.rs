//! Serving metrics: the paper's two headline numbers (prefill latency,
//! decode tokens/s) plus the loader/cache counters behind the ablations.

use std::time::Duration;

use crate::util::json::{arr, num, obj, s, Json};

#[derive(Debug, Clone, Default)]
pub struct LoaderStats {
    /// on-demand expert loads by precision slot (f32, q8, q4, q2)
    pub ondemand_loads: [u64; 4],
    /// prefetch loads by precision slot
    pub prefetch_loads: [u64; 4],
    /// experts skipped by the T2 threshold
    pub skipped: u64,
    /// bytes actually moved across the simulated PCIe/SSD link
    pub bytes_loaded: u64,
    /// wall-time the decode loop spent blocked on on-demand loads
    pub wait_time: Duration,
    /// prefetch predictions that turned out correct / total
    pub prefetch_hits: u64,
    pub prefetch_total: u64,
    /// on-demand load requests that joined an already in-flight task for
    /// the same (expert, pool) instead of submitting a duplicate — the
    /// cross-sequence shared wait-set at work (serving metric; the FCFS
    /// report does not carry it)
    pub dedup_hits: u64,
    /// total on-demand load requests that reached the residency wait-set
    pub dedup_total: u64,
    /// merged ensure-resident barriers issued by batched decode: one per
    /// (batch, layer)
    pub merged_acquires: u64,
    /// unique (expert, class) entries across all merged acquires
    pub merged_unique: u64,
    /// per-row expert demands folded into merged acquires (>= unique;
    /// the gap is the in-batch load sharing)
    pub merged_demands: u64,
    /// merged ensure-resident barriers issued by chunked prefill: one per
    /// (chunk, layer). The blocking FCFS prefill never bumps these.
    pub prefill_merged_acquires: u64,
    /// unique experts across all chunked-prefill merged acquires
    pub prefill_merged_unique: u64,
    /// per-row expert demands folded into chunked-prefill acquires
    /// (>= unique; the gap is the in-chunk load sharing)
    pub prefill_merged_demands: u64,
    /// prefetch transfers that yielded mid-flight at a chunk checkpoint
    /// because on-demand work was waiting (partial progress kept)
    pub preemptions: u64,
    /// *started* prefetch transfers whose remaining chunks were
    /// re-prioritized to the on-demand weight by a join (promotion used to
    /// fail for started transfers — the Fig 9 penalty)
    pub inflight_promotions: u64,
    /// load tasks that completed WITHOUT a slot (every candidate pinned or
    /// mid-load): nothing was copied and the expert is not resident — the
    /// residency facade re-acquires instead of letting waiters execute on
    /// a stale slot
    pub noslot_drops: u64,
    /// Σ submit → committed of on-demand transfers (time-to-ready). A
    /// promoted prefetch restarts its clock at promotion, so this
    /// measures the joiner's wait, not the speculative lifetime.
    pub ondemand_ready: Duration,
    /// Σ submit → committed of prefetch transfers
    pub prefetch_ready: Duration,
    /// staged (lo-bits-first) loads: the floor record committed and a
    /// background upgrade continuation was enqueued. `ondemand_ready`
    /// then measures time-to-first-USABLE, not time-to-full-precision.
    pub progressive_loads: u64,
    /// upgrade continuations that landed (slot flipped to the wider tier
    /// in place)
    pub upgrades_committed: u64,
    /// upgrade continuations that aborted (slot evicted/refilled before
    /// the staged bytes landed — the narrower resident tier stays valid)
    pub upgrades_aborted: u64,
    /// records pulled from a peer over the network link class (demand +
    /// cross-tier staging)
    pub remote_fetches: u64,
    /// bytes pulled over the network link class
    pub remote_bytes: u64,
    /// transport retries spent on successful remote fetches
    pub remote_retries: u64,
    /// demand fetches a peer should have served but the local disk tier
    /// did (the degraded-tier counter: dead peer, bounded retries spent)
    pub peer_failovers: u64,
    /// fetches answered by the staged peer->DRAM side-cache — the
    /// cross-tier prefetch hits
    pub remote_staged_hits: u64,
    /// records read from the local disk failover tier
    pub disk_fetches: u64,
    /// records that failed checksum verification at any tier boundary
    /// (peer frame, staged side-cache, disk read, or cache commit)
    pub integrity_failures: u64,
    /// recovery fetches issued after an integrity failure — from the next
    /// tier down, or a fresh re-acquire after a corrupt commit
    pub integrity_refetches: u64,
    /// cache slots scrubbed and returned to the free list because their
    /// just-landed bytes failed commit verification (never served)
    pub quarantined_slots: u64,
    /// wedged tickets the residency watchdog recovered by re-submitting
    /// the load after a lane stalled past `IoConfig::watchdog_ms`
    pub watchdog_recoveries: u64,
    /// grouped expert launches issued by the ragged grouped FFN path:
    /// one per (expert group, chunk) — the O(unique experts) collapse
    pub grouped_launches: u64,
    /// routed rows carried by those grouped launches
    pub group_rows: u64,
    /// per-row dequants avoided by parsing each group's record once
    /// (`routed_rows - 1` summed over groups — the dequant-once win)
    pub dequant_reuses: u64,
    /// owned (tier, bytes) snapshots copied out of the cache by batch
    /// steps (one per unique (key, pool) per step with the arena)
    pub snapshot_copies: u64,
    /// snapshot reads served from the step's arena instead of re-copying
    /// under the cache lock
    pub snapshot_reuses: u64,
}

impl LoaderStats {
    /// On-demand transfers committed (all precisions).
    pub fn ondemand_count(&self) -> u64 {
        self.ondemand_loads.iter().sum()
    }

    /// Prefetch transfers committed (all precisions).
    pub fn prefetch_count(&self) -> u64 {
        self.prefetch_loads.iter().sum()
    }

    /// Mean submit → committed latency of on-demand transfers (ms).
    pub fn mean_ondemand_ready_ms(&self) -> f64 {
        let n = self.ondemand_count();
        if n == 0 {
            0.0
        } else {
            self.ondemand_ready.as_secs_f64() * 1e3 / n as f64
        }
    }

    /// Mean submit → committed latency of prefetch transfers (ms).
    pub fn mean_prefetch_ready_ms(&self) -> f64 {
        let n = self.prefetch_count();
        if n == 0 {
            0.0
        } else {
            self.prefetch_ready.as_secs_f64() * 1e3 / n as f64
        }
    }

    /// The transfer-pipeline counters as a JSON object — folded into the
    /// interleaved report's `"serving"` key (never the FCFS top level) and
    /// printed standalone by `bench_loader` under the same side key.
    pub fn pipeline_json(&self) -> Json {
        obj(vec![
            ("preemptions", num(self.preemptions as f64)),
            ("inflight_promotions", num(self.inflight_promotions as f64)),
            ("noslot_drops", num(self.noslot_drops as f64)),
            ("mean_ondemand_ready_ms", num(self.mean_ondemand_ready_ms())),
            ("mean_prefetch_ready_ms", num(self.mean_prefetch_ready_ms())),
            ("progressive_loads", num(self.progressive_loads as f64)),
            ("upgrades_committed", num(self.upgrades_committed as f64)),
            ("upgrades_aborted", num(self.upgrades_aborted as f64)),
            ("remote_fetches", num(self.remote_fetches as f64)),
            ("remote_bytes", num(self.remote_bytes as f64)),
            ("remote_retries", num(self.remote_retries as f64)),
            ("peer_failovers", num(self.peer_failovers as f64)),
            ("remote_staged_hits", num(self.remote_staged_hits as f64)),
            ("disk_fetches", num(self.disk_fetches as f64)),
            ("integrity_failures", num(self.integrity_failures as f64)),
            ("integrity_refetches", num(self.integrity_refetches as f64)),
            ("quarantined_slots", num(self.quarantined_slots as f64)),
            ("watchdog_recoveries", num(self.watchdog_recoveries as f64)),
            ("grouped_launches", num(self.grouped_launches as f64)),
            ("group_rows", num(self.group_rows as f64)),
            ("dequant_reuses", num(self.dequant_reuses as f64)),
            ("snapshot_copies", num(self.snapshot_copies as f64)),
            ("snapshot_reuses", num(self.snapshot_reuses as f64)),
        ])
    }
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits_hi: u64,
    pub hits_lo: u64,
    pub misses_hi: u64,
    pub misses_lo: u64,
    pub evictions: u64,
    /// §3.4 miss *penalty*: hi miss = 1.0, lo miss = B_l/B_h
    pub miss_penalty: f64,
    /// hot-expert read-replicas populated (DRAM-to-DRAM, never the link)
    pub replicas_created: u64,
    /// snapshot reads served by a replica slot instead of the primary
    pub replica_hits: u64,
    /// replica slots reclaimed (capacity pressure) or invalidated
    /// (primary evicted / upgraded / quarantined)
    pub replica_evictions: u64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let hits = (self.hits_hi + self.hits_lo) as f64;
        let total = hits + (self.misses_hi + self.misses_lo) as f64;
        if total == 0.0 {
            0.0
        } else {
            hits / total
        }
    }
}

/// One generation's timing record.
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    /// time spent inside PJRT execute calls (compute)
    pub compute_time: Duration,
    /// time spent blocked on expert loading
    pub load_wait_time: Duration,
}

impl RequestMetrics {
    pub fn decode_tps(&self) -> f64 {
        let t = self.decode_time.as_secs_f64();
        if t <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / t
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("prompt_tokens", num(self.prompt_tokens as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("prefill_s", num(self.prefill_time.as_secs_f64())),
            ("decode_s", num(self.decode_time.as_secs_f64())),
            ("decode_tps", num(self.decode_tps())),
            ("compute_s", num(self.compute_time.as_secs_f64())),
            ("load_wait_s", num(self.load_wait_time.as_secs_f64())),
        ])
    }
}

/// Bounded log-bucket latency histogram for tail percentiles.
///
/// Geometric buckets from [`Self::MIN_S`] with ratio [`Self::GROWTH`]
/// (~7.5% half-width relative error per bucket), covering 1 µs .. >1 h in
/// a fixed [`Self::BUCKETS`]-slot array — O(1) record, O(1) memory no
/// matter how many samples land, so the open-loop harness can stream
/// thousands of per-request TTFT / inter-token samples through it.
/// Quantiles return the geometric midpoint of the covering bucket.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; Self::BUCKETS],
    total: u64,
    sum: Duration,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { counts: [0; Self::BUCKETS], total: 0, sum: Duration::ZERO }
    }
}

impl LatencyHistogram {
    /// lower edge of bucket 0 (1 µs); anything smaller folds into it
    pub const MIN_S: f64 = 1e-6;
    /// geometric bucket ratio — ln(3600/1e-6)/ln(1.15) ≈ 158 buckets to
    /// span one hour, hence 160 slots (the last is the +inf overflow)
    pub const GROWTH: f64 = 1.15;
    pub const BUCKETS: usize = 160;

    fn index(d: Duration) -> usize {
        let s = d.as_secs_f64();
        if s <= Self::MIN_S {
            return 0;
        }
        let i = ((s / Self::MIN_S).ln() / Self::GROWTH.ln()).floor() as usize;
        i.min(Self::BUCKETS - 1)
    }

    /// geometric midpoint of bucket `i`, in seconds
    fn midpoint_s(i: usize) -> f64 {
        Self::MIN_S * Self::GROWTH.powf(i as f64 + 0.5)
    }

    pub fn record(&mut self, d: Duration) {
        self.counts[Self::index(d)] += 1;
        self.total += 1;
        self.sum += d;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum.as_secs_f64() / self.total as f64
        }
    }

    /// Quantile `q` in [0,1], in seconds (0.0 while empty). Nearest-rank
    /// over the bucket counts; the answer carries the bucket's ~±7.5%
    /// relative error, which is what makes the memory bound possible.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::midpoint_s(i);
            }
        }
        Self::midpoint_s(Self::BUCKETS - 1)
    }

    pub fn p50_s(&self) -> f64 {
        self.quantile_s(0.50)
    }

    pub fn p99_s(&self) -> f64 {
        self.quantile_s(0.99)
    }

    pub fn p999_s(&self) -> f64 {
        self.quantile_s(0.999)
    }

    /// Fold another histogram in (per-shard collection).
    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
    }
}

/// Interleaved-scheduler aggregates (queue wait, TTFT, aggregate decode
/// throughput, and the overlap ratio — the fraction of load-wait hidden by
/// other sequences' compute). Absent (None in [`RunReport`]) on the
/// paper-faithful batch-1 FCFS path, so that mode's report JSON is
/// byte-identical to the pre-scheduler format.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// requests completed by the interleaved scheduler
    pub completed: u64,
    /// tokens decoded across all completed requests
    pub decoded_tokens: u64,
    /// Σ submit → admission (prefill start) over completed requests
    pub queue_wait: Duration,
    /// Σ submit → first generated token over completed requests
    pub ttft: Duration,
    /// Σ per-sequence decode stall (ensure-resident barrier reach → clear),
    /// hidden or not
    pub total_stall: Duration,
    /// stall the scheduler could NOT hide: every live sequence was waiting
    /// on the link at once, so it blocked in `ExpertLoader::wait`
    pub unhidden_stall: Duration,
    /// wall time with at least one sequence queued or active
    pub busy_wall: Duration,
    /// batched decode steps launched (`--max-batch` > 1)
    pub batch_steps: u64,
    /// sequences carried by those steps (occupancy numerator)
    pub batch_rows: u64,
    /// launch slots wasted on padding to the compiled width {2, 4, 8}
    pub padded_slots: u64,
    /// rows evicted from a batch because their loads blocked while the
    /// rest of the group was runnable
    pub batch_evictions: u64,
    /// prefill slices executed by the chunked-admission path (one slice =
    /// one chunk boundary crossed or prefill completed)
    pub prefill_slices: u64,
    /// Σ prefill-chunk stall (ensure-resident barrier reach → clear),
    /// hidden by other sequences' decode or not
    pub prefill_stall: Duration,
    /// completed prefill chunks by launch width, parallel to
    /// `engine::PREFILL_CHUNKS` ([128, 16, 1])
    pub prefill_chunks: [u64; 3],
    /// admissions whose prefill errored: the request failed individually
    /// and serving kept running
    pub prefill_failures: u64,
    /// per-request submit → first token distribution (tail metrics; the
    /// `ttft` sum above stays for the legacy mean)
    pub ttft_hist: LatencyHistogram,
    /// per-token gap distribution within decode (2nd token onward)
    pub itl_hist: LatencyHistogram,
    /// completed requests whose TTFT met the configured SLO (all of them
    /// when no SLO is set)
    pub slo_met: u64,
    /// decoded tokens belonging to SLO-met requests — the goodput numerator
    pub slo_met_tokens: u64,
    /// submissions rejected by bounded-queue admission control (ladder
    /// stage 3 — the last resort)
    pub admission_rejects: u64,
    /// scheduler rounds spent with the precision-shed signal raised
    /// (ladder stage 1: progressive floor forced to the low tier)
    pub shed_precision_rounds: u64,
    /// scheduler rounds spent with the prefetch-shed signal raised
    /// (ladder stage 2: speculative link traffic dropped)
    pub shed_prefetch_rounds: u64,
    /// how batched decode executes experts: "grouped" (ragged grouped
    /// launches), "padded" (compiled-width per-expert launches), or
    /// "per-row" (s=1 fallback ladder)
    pub exec_mode: String,
}

impl SchedulerStats {
    /// Aggregate decode throughput: tokens decoded per busy wall second
    /// (across all interleaved sequences — the serving headline number).
    pub fn aggregate_decode_tps(&self) -> f64 {
        let t = self.busy_wall.as_secs_f64();
        if t <= 0.0 {
            0.0
        } else {
            self.decoded_tokens as f64 / t
        }
    }

    /// Fraction of total decode stall hidden by advancing other sequences:
    /// `1 - unhidden/total`. 0 when nothing stalled (or nothing was hidden).
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.total_stall.as_secs_f64();
        if total <= 0.0 {
            return 0.0;
        }
        (1.0 - self.unhidden_stall.as_secs_f64() / total).max(0.0)
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_wait.as_secs_f64() / self.completed as f64
        }
    }

    pub fn mean_ttft_s(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.ttft.as_secs_f64() / self.completed as f64
        }
    }

    /// Goodput under the TTFT SLO: decoded tokens of SLO-met requests per
    /// busy wall second. Equals `aggregate_decode_tps` when no SLO is
    /// configured (every completion counts as met).
    pub fn goodput_tps(&self) -> f64 {
        let t = self.busy_wall.as_secs_f64();
        if t <= 0.0 {
            0.0
        } else {
            self.slo_met_tokens as f64 / t
        }
    }

    /// Fraction of completed requests that met the TTFT SLO.
    pub fn slo_attainment(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.slo_met as f64 / self.completed as f64
        }
    }

    /// Mean sequences per batched decode step (1.0 when batching never
    /// engaged — occupancy > 1 is the "real FLOP sharing" signal).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batch_steps == 0 {
            1.0
        } else {
            self.batch_rows as f64 / self.batch_steps as f64
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("completed", num(self.completed as f64)),
            ("decoded_tokens", num(self.decoded_tokens as f64)),
            ("mean_queue_wait_s", num(self.mean_queue_wait_s())),
            ("mean_ttft_s", num(self.mean_ttft_s())),
            ("aggregate_decode_tps", num(self.aggregate_decode_tps())),
            ("overlap_ratio", num(self.overlap_ratio())),
            ("total_stall_s", num(self.total_stall.as_secs_f64())),
            ("unhidden_stall_s", num(self.unhidden_stall.as_secs_f64())),
            ("busy_wall_s", num(self.busy_wall.as_secs_f64())),
            ("batch_steps", num(self.batch_steps as f64)),
            ("batch_occupancy", num(self.batch_occupancy())),
            ("padded_slots", num(self.padded_slots as f64)),
            ("batch_evictions", num(self.batch_evictions as f64)),
            ("prefill_slices", num(self.prefill_slices as f64)),
            ("prefill_stall_ms", num(self.prefill_stall.as_secs_f64() * 1e3)),
            ("prefill_chunks_128", num(self.prefill_chunks[0] as f64)),
            ("prefill_chunks_16", num(self.prefill_chunks[1] as f64)),
            ("prefill_chunks_1", num(self.prefill_chunks[2] as f64)),
            ("prefill_failures", num(self.prefill_failures as f64)),
            // tail metrics + overload-control plane (serving key only; the
            // FCFS report never carries a SchedulerStats)
            ("ttft_p50_s", num(self.ttft_hist.p50_s())),
            ("ttft_p99_s", num(self.ttft_hist.p99_s())),
            ("ttft_p999_s", num(self.ttft_hist.p999_s())),
            ("itl_p50_s", num(self.itl_hist.p50_s())),
            ("itl_p99_s", num(self.itl_hist.p99_s())),
            ("itl_p999_s", num(self.itl_hist.p999_s())),
            ("goodput_tps", num(self.goodput_tps())),
            ("slo_attainment", num(self.slo_attainment())),
            ("admission_rejects", num(self.admission_rejects as f64)),
            ("shed_precision_rounds", num(self.shed_precision_rounds as f64)),
            ("shed_prefetch_rounds", num(self.shed_prefetch_rounds as f64)),
            ("exec_mode", s(&self.exec_mode)),
        ])
    }
}

/// Aggregate over a run of requests, exported by `hobbit serve --report`.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub requests: Vec<RequestMetrics>,
    pub loader: LoaderStats,
    pub cache: CacheStats,
    /// interleaved-scheduler aggregates; None on the batch-1 FCFS path
    pub scheduler: Option<SchedulerStats>,
}

impl RunReport {
    pub fn mean_decode_tps(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.decode_tps()).sum::<f64>() / self.requests.len() as f64
    }

    pub fn mean_prefill_s(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.prefill_time.as_secs_f64()).sum::<f64>()
            / self.requests.len() as f64
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("mean_decode_tps", num(self.mean_decode_tps())),
            ("mean_prefill_s", num(self.mean_prefill_s())),
            ("cache_hit_ratio", num(self.cache.hit_ratio())),
            ("miss_penalty", num(self.cache.miss_penalty)),
            ("bytes_loaded", num(self.loader.bytes_loaded as f64)),
            ("skipped", num(self.loader.skipped as f64)),
            (
                "prefetch_accuracy",
                num(if self.loader.prefetch_total == 0 {
                    0.0
                } else {
                    self.loader.prefetch_hits as f64 / self.loader.prefetch_total as f64
                }),
            ),
            ("requests", arr(self.requests.iter().map(|r| r.to_json()).collect())),
        ];
        // interleaved mode only: batch-1 FCFS reports stay byte-identical.
        // Cross-sequence dedup counters live in LoaderStats but are a
        // serving phenomenon, so they surface here.
        if let Some(sch) = &self.scheduler {
            let mut serving = sch.to_json();
            if let Json::Obj(m) = &mut serving {
                m.insert("dedup_hits".into(), num(self.loader.dedup_hits as f64));
                m.insert("dedup_total".into(), num(self.loader.dedup_total as f64));
                m.insert(
                    "merged_acquires".into(),
                    num(self.loader.merged_acquires as f64),
                );
                m.insert(
                    "merged_unique_experts".into(),
                    num(self.loader.merged_unique as f64),
                );
                m.insert(
                    "merged_demands".into(),
                    num(self.loader.merged_demands as f64),
                );
                m.insert(
                    "prefill_merged_acquires".into(),
                    num(self.loader.prefill_merged_acquires as f64),
                );
                m.insert(
                    "prefill_merged_unique".into(),
                    num(self.loader.prefill_merged_unique as f64),
                );
                m.insert(
                    "prefill_merged_demands".into(),
                    num(self.loader.prefill_merged_demands as f64),
                );
                // hot-expert replication counters live in CacheStats (the
                // cache owns replicas) but are a serving phenomenon: the
                // FCFS cache surface stays hit_ratio + miss_penalty only
                m.insert(
                    "replicas_created".into(),
                    num(self.cache.replicas_created as f64),
                );
                m.insert("replica_hits".into(), num(self.cache.replica_hits as f64));
                m.insert(
                    "replica_evictions".into(),
                    num(self.cache.replica_evictions as f64),
                );
                // the transfer-pipeline counters ride along (never at the
                // FCFS top level)
                if let Json::Obj(p) = self.loader.pipeline_json() {
                    for (k, v) in p {
                        m.insert(k, v);
                    }
                }
            }
            pairs.push(("serving", serving));
        }
        pairs.push(("schema", s("hobbit.run_report.v1")));
        obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_math() {
        let r = RequestMetrics {
            generated_tokens: 50,
            decode_time: Duration::from_secs_f64(2.0),
            ..Default::default()
        };
        assert!((r.decode_tps() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn hit_ratio() {
        let c = CacheStats { hits_hi: 6, hits_lo: 2, misses_hi: 1, misses_lo: 1, ..Default::default() };
        assert!((c.hit_ratio() - 0.8).abs() < 1e-9);
        assert_eq!(CacheStats::default().hit_ratio(), 0.0);
    }

    #[test]
    fn scheduler_stats_math() {
        let s = SchedulerStats {
            completed: 4,
            decoded_tokens: 80,
            queue_wait: Duration::from_secs(2),
            ttft: Duration::from_secs(4),
            total_stall: Duration::from_secs_f64(1.0),
            unhidden_stall: Duration::from_secs_f64(0.25),
            busy_wall: Duration::from_secs(8),
            ..Default::default()
        };
        assert!((s.aggregate_decode_tps() - 10.0).abs() < 1e-9);
        assert!((s.overlap_ratio() - 0.75).abs() < 1e-9);
        assert!((s.mean_queue_wait_s() - 0.5).abs() < 1e-9);
        assert!((s.mean_ttft_s() - 1.0).abs() < 1e-9);
        // degenerate cases stay finite
        let z = SchedulerStats::default();
        assert_eq!(z.aggregate_decode_tps(), 0.0);
        assert_eq!(z.overlap_ratio(), 0.0);
        assert_eq!(z.mean_ttft_s(), 0.0);
    }

    #[test]
    fn serving_section_only_in_interleaved_reports() {
        let mut rep = RunReport::default();
        rep.loader.dedup_hits = 3;
        rep.loader.dedup_total = 7;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("\"serving\""), "FCFS report grew a serving key");
        assert!(!fcfs.contains("dedup"), "FCFS report grew dedup keys");
        rep.scheduler = Some(SchedulerStats::default());
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert!(serving.get("overlap_ratio").is_some());
        assert_eq!(serving.get("dedup_hits").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(serving.get("dedup_total").unwrap().as_f64().unwrap(), 7.0);
    }

    #[test]
    fn batch_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        rep.loader.merged_acquires = 12;
        rep.loader.merged_unique = 20;
        rep.loader.merged_demands = 31;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("merged"), "FCFS report grew merged-acquire keys");
        assert!(!fcfs.contains("batch"), "FCFS report grew batch keys");
        rep.scheduler = Some(SchedulerStats {
            batch_steps: 4,
            batch_rows: 10,
            padded_slots: 3,
            batch_evictions: 1,
            ..Default::default()
        });
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("batch_steps").unwrap().as_f64().unwrap(), 4.0);
        assert!((serving.get("batch_occupancy").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(serving.get("padded_slots").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(serving.get("batch_evictions").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(serving.get("merged_acquires").unwrap().as_f64().unwrap(), 12.0);
        assert_eq!(serving.get("merged_unique_experts").unwrap().as_f64().unwrap(), 20.0);
        assert_eq!(serving.get("merged_demands").unwrap().as_f64().unwrap(), 31.0);
        // occupancy degenerates to 1.0 when batching never engaged
        assert_eq!(SchedulerStats::default().batch_occupancy(), 1.0);
    }

    #[test]
    fn prefill_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        rep.loader.prefill_merged_acquires = 9;
        rep.loader.prefill_merged_unique = 18;
        rep.loader.prefill_merged_demands = 40;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("prefill_merged"), "FCFS report grew prefill-merged keys");
        assert!(!fcfs.contains("prefill_slices"), "FCFS report grew prefill-slice keys");
        rep.scheduler = Some(SchedulerStats {
            prefill_slices: 5,
            prefill_stall: Duration::from_millis(12),
            prefill_chunks: [2, 1, 4],
            prefill_failures: 1,
            ..Default::default()
        });
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("prefill_slices").unwrap().as_f64().unwrap(), 5.0);
        assert!(
            (serving.get("prefill_stall_ms").unwrap().as_f64().unwrap() - 12.0).abs() < 1e-6
        );
        assert_eq!(serving.get("prefill_chunks_128").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(serving.get("prefill_chunks_16").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(serving.get("prefill_chunks_1").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(serving.get("prefill_failures").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(serving.get("prefill_merged_acquires").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(serving.get("prefill_merged_unique").unwrap().as_f64().unwrap(), 18.0);
        assert_eq!(serving.get("prefill_merged_demands").unwrap().as_f64().unwrap(), 40.0);
    }

    #[test]
    fn pipeline_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        rep.loader.preemptions = 5;
        rep.loader.inflight_promotions = 2;
        rep.loader.noslot_drops = 1;
        rep.loader.ondemand_loads = [4, 0, 0, 0];
        rep.loader.ondemand_ready = Duration::from_millis(40);
        rep.loader.prefetch_loads = [0, 2, 0, 0];
        rep.loader.prefetch_ready = Duration::from_millis(30);
        rep.loader.progressive_loads = 3;
        rep.loader.upgrades_committed = 2;
        rep.loader.upgrades_aborted = 1;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("preemptions"), "FCFS report grew pipeline keys");
        assert!(!fcfs.contains("noslot"), "FCFS report grew pipeline keys");
        assert!(!fcfs.contains("progressive"), "FCFS report grew progressive keys");
        assert!(!fcfs.contains("upgrades"), "FCFS report grew upgrade keys");
        rep.scheduler = Some(SchedulerStats::default());
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("progressive_loads").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(serving.get("upgrades_committed").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(serving.get("upgrades_aborted").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(serving.get("preemptions").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(serving.get("inflight_promotions").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(serving.get("noslot_drops").unwrap().as_f64().unwrap(), 1.0);
        assert!(
            (serving.get("mean_ondemand_ready_ms").unwrap().as_f64().unwrap() - 10.0).abs()
                < 1e-9
        );
        assert!(
            (serving.get("mean_prefetch_ready_ms").unwrap().as_f64().unwrap() - 15.0).abs()
                < 1e-9
        );
        // degenerate means stay finite
        assert_eq!(LoaderStats::default().mean_ondemand_ready_ms(), 0.0);
        assert_eq!(LoaderStats::default().mean_prefetch_ready_ms(), 0.0);
    }

    #[test]
    fn remote_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        rep.loader.remote_fetches = 11;
        rep.loader.remote_bytes = 4096;
        rep.loader.remote_retries = 2;
        rep.loader.peer_failovers = 1;
        rep.loader.remote_staged_hits = 5;
        rep.loader.disk_fetches = 3;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("remote"), "FCFS report grew remote keys");
        assert!(!fcfs.contains("peer_failovers"), "FCFS report grew failover keys");
        assert!(!fcfs.contains("disk_fetches"), "FCFS report grew disk keys");
        rep.scheduler = Some(SchedulerStats::default());
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("remote_fetches").unwrap().as_f64().unwrap(), 11.0);
        assert_eq!(serving.get("remote_bytes").unwrap().as_f64().unwrap(), 4096.0);
        assert_eq!(serving.get("remote_retries").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(serving.get("peer_failovers").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(serving.get("remote_staged_hits").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(serving.get("disk_fetches").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn integrity_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        rep.loader.integrity_failures = 3;
        rep.loader.integrity_refetches = 2;
        rep.loader.quarantined_slots = 1;
        rep.loader.watchdog_recoveries = 1;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("integrity"), "FCFS report grew integrity keys");
        assert!(!fcfs.contains("quarantined"), "FCFS report grew quarantine keys");
        assert!(!fcfs.contains("watchdog"), "FCFS report grew watchdog keys");
        rep.scheduler = Some(SchedulerStats::default());
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("integrity_failures").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(serving.get("integrity_refetches").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(serving.get("quarantined_slots").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(serving.get("watchdog_recoveries").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn grouped_and_replica_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        rep.loader.grouped_launches = 6;
        rep.loader.group_rows = 24;
        rep.loader.dequant_reuses = 18;
        rep.loader.snapshot_copies = 6;
        rep.loader.snapshot_reuses = 4;
        rep.cache.replicas_created = 3;
        rep.cache.replica_hits = 9;
        rep.cache.replica_evictions = 2;
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("grouped"), "FCFS report grew grouped keys");
        assert!(!fcfs.contains("replica"), "FCFS report grew replica keys");
        assert!(!fcfs.contains("dequant"), "FCFS report grew dequant keys");
        assert!(!fcfs.contains("snapshot"), "FCFS report grew snapshot keys");
        assert!(!fcfs.contains("exec_mode"), "FCFS report grew exec_mode key");
        rep.scheduler = Some(SchedulerStats {
            exec_mode: "grouped".into(),
            ..Default::default()
        });
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("grouped_launches").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(serving.get("group_rows").unwrap().as_f64().unwrap(), 24.0);
        assert_eq!(serving.get("dequant_reuses").unwrap().as_f64().unwrap(), 18.0);
        assert_eq!(serving.get("snapshot_copies").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(serving.get("snapshot_reuses").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(serving.get("replicas_created").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(serving.get("replica_hits").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(serving.get("replica_evictions").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(serving.get("exec_mode").unwrap().as_str().unwrap(), "grouped");
    }

    #[test]
    fn histogram_quantiles_match_known_uniform() {
        // 1..=1000 ms uniformly: p50 ≈ 500ms, p99 ≈ 990ms, p99.9 ≈ 1000ms,
        // each within the bucket's ~±7.5% relative error.
        let mut h = LatencyHistogram::default();
        for ms in 1..=1000u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 1000);
        let within = |got: f64, want: f64| (got - want).abs() / want < 0.08;
        assert!(within(h.p50_s(), 0.500), "p50={} want ~0.5", h.p50_s());
        assert!(within(h.p99_s(), 0.990), "p99={} want ~0.99", h.p99_s());
        assert!(within(h.p999_s(), 0.999), "p99.9={} want ~1.0", h.p999_s());
        assert!(within(h.mean_s(), 0.5005), "mean={} want ~0.5", h.mean_s());
    }

    #[test]
    fn histogram_tail_separates_from_body() {
        // 990 fast samples at 1ms + 10 slow at 2s: the mean hides the
        // tail, the histogram does not — this is the satellite's point.
        let mut h = LatencyHistogram::default();
        for _ in 0..990 {
            h.record(Duration::from_millis(1));
        }
        for _ in 0..10 {
            h.record(Duration::from_secs(2));
        }
        assert!(h.p50_s() < 0.002, "p50={} should sit in the body", h.p50_s());
        assert!(h.p999_s() > 1.8, "p99.9={} should sit in the tail", h.p999_s());
        // nearest-rank: rank ceil(0.99*1000)=990 is still a fast sample
        assert!(h.p99_s() < 0.002, "p99={} rank 990 is fast", h.p99_s());
    }

    #[test]
    fn histogram_bounds_and_merge() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.p50_s(), 0.0);
        h.record(Duration::ZERO); // underflow folds into bucket 0
        h.record(Duration::from_secs(100_000)); // overflow folds into the last
        assert_eq!(h.count(), 2);
        assert!(h.quantile_s(0.0) <= LatencyHistogram::MIN_S * LatencyHistogram::GROWTH);
        assert!(h.quantile_s(1.0) >= 3000.0);
        let mut a = LatencyHistogram::default();
        a.record(Duration::from_millis(10));
        let mut b = LatencyHistogram::default();
        b.record(Duration::from_millis(10));
        b.record(Duration::from_millis(10));
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.p50_s() - 0.010).abs() / 0.010 < 0.08);
    }

    #[test]
    fn goodput_and_slo_math() {
        let s = SchedulerStats {
            completed: 10,
            decoded_tokens: 100,
            slo_met: 6,
            slo_met_tokens: 60,
            busy_wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((s.goodput_tps() - 30.0).abs() < 1e-9);
        assert!((s.slo_attainment() - 0.6).abs() < 1e-9);
        assert_eq!(SchedulerStats::default().goodput_tps(), 0.0);
        assert_eq!(SchedulerStats::default().slo_attainment(), 0.0);
    }

    #[test]
    fn tail_and_overload_stats_surface_only_in_serving_section() {
        let mut rep = RunReport::default();
        let fcfs = rep.to_json().to_string();
        assert!(!fcfs.contains("ttft_p"), "FCFS report grew tail keys");
        assert!(!fcfs.contains("goodput"), "FCFS report grew goodput keys");
        assert!(!fcfs.contains("shed_"), "FCFS report grew ladder keys");
        assert!(!fcfs.contains("admission"), "FCFS report grew admission keys");
        let mut sch = SchedulerStats {
            admission_rejects: 4,
            shed_precision_rounds: 7,
            shed_prefetch_rounds: 2,
            slo_met_tokens: 50,
            busy_wall: Duration::from_secs(1),
            ..Default::default()
        };
        sch.ttft_hist.record(Duration::from_millis(100));
        sch.itl_hist.record(Duration::from_millis(20));
        rep.scheduler = Some(sch);
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        let serving = j.get("serving").unwrap();
        assert!((serving.get("ttft_p99_s").unwrap().as_f64().unwrap() - 0.1).abs() < 0.01);
        assert!((serving.get("itl_p50_s").unwrap().as_f64().unwrap() - 0.02).abs() < 0.002);
        assert_eq!(serving.get("goodput_tps").unwrap().as_f64().unwrap(), 50.0);
        assert_eq!(serving.get("admission_rejects").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(serving.get("shed_precision_rounds").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(serving.get("shed_prefetch_rounds").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn report_json_parses() {
        let mut rep = RunReport::default();
        rep.requests.push(RequestMetrics {
            prompt_tokens: 16,
            generated_tokens: 32,
            prefill_time: Duration::from_millis(100),
            decode_time: Duration::from_secs(1),
            ..Default::default()
        });
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_arr().unwrap().len(), 1);
    }
}
