//! The two-tier memory hierarchy (Fig 2): experts move from "next-level
//! memory" (the `ExpertStore`) into the expert cache across a
//! bandwidth-limited link.
//!
//! Two transfer engines implement the same accounting:
//!
//! * [`ThrottledCopier`] — the *real* path: performs the actual memcpy of
//!   the expert bytes and sleeps the remainder of `bytes/bandwidth +
//!   latency`, emulating PCIe/SSD at a configured (scaled) rate. Since the
//!   chunked pipeline, the copier is built on a [`LinkArbiter`]: any
//!   number of lanes may charge chunk-granular transfer time against ONE
//!   shared link budget, splitting `bytes_per_s` by weighted fair share —
//!   total bandwidth is conserved, and on-demand chunks carry a higher
//!   weight ([`ONDEMAND_WEIGHT`]) than prefetch chunks
//!   ([`PREFETCH_WEIGHT`]). A *chunk* is still non-preemptible (the
//!   cudaMemcpy observation of §3.3/Fig 9 applies per DMA call), but the
//!   loader's checkpoints between chunks turn the paper's misprediction
//!   penalty from O(expert bytes) into O(one chunk).
//! * [`VirtualClock`] — the simulator's time source: transfers charge
//!   virtual nanoseconds, no bytes move.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Fair-share weight of an on-demand lane: a decode stall outranks
/// speculation 4:1 when both are on the link at once.
pub const ONDEMAND_WEIGHT: f64 = 4.0;

/// Fair-share weight of a prefetch lane.
pub const PREFETCH_WEIGHT: f64 = 1.0;

/// Bandwidth model of the expert-loading link.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub bytes_per_s: f64,
    pub latency_s: f64,
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(self.latency_s + bytes as f64 / self.bytes_per_s)
    }

    /// Link model from a gigabit-per-second budget (the `--net-gbps`
    /// unit of the remote expert tier's network link class).
    pub fn from_gbps(gbps: f64, latency_s: f64) -> Self {
        Self { bytes_per_s: gbps * 1e9 / 8.0, latency_s }
    }
}

/// Shared-bandwidth arbiter over one link.
///
/// Each busy lane registers a [`LaneGrant`] with a weight; a chunk charged
/// by one lane takes `bytes / (bytes_per_s * weight / Σ active weights)`,
/// so concurrent lanes *split* the link instead of each modeling a private
/// full-rate copy — N lanes move N records in the same wall time one lane
/// moves them serially (bandwidth conservation), while the weighted split
/// lets on-demand chunks squeeze prefetch chunks without starving them.
/// The share is sampled at chunk-charge time; chunks are small relative
/// to lane churn, so the approximation error is bounded by one chunk.
pub struct LinkArbiter {
    link: LinkModel,
    /// grant id -> weight of every lane currently mid-task
    active: Mutex<HashMap<u64, f64>>,
    next_grant: AtomicU64,
}

impl LinkArbiter {
    pub fn new(link: LinkModel) -> Self {
        Self { link, active: Mutex::new(HashMap::new()), next_grant: AtomicU64::new(1) }
    }

    pub fn link(&self) -> LinkModel {
        self.link
    }

    /// Register a busy lane at `weight`; dropping the grant retires it.
    pub fn begin(&self, weight: f64) -> LaneGrant<'_> {
        let id = self.next_grant.fetch_add(1, Ordering::Relaxed);
        self.active.lock().unwrap().insert(id, weight.max(1e-9));
        LaneGrant { arb: self, id }
    }

    fn share_of(&self, id: u64) -> f64 {
        let active = self.active.lock().unwrap();
        let mine = active.get(&id).copied().unwrap_or(1.0);
        let total: f64 = active.values().sum();
        if total <= 0.0 {
            1.0
        } else {
            mine / total
        }
    }

    fn set_weight(&self, id: u64, weight: f64) {
        if let Some(w) = self.active.lock().unwrap().get_mut(&id) {
            *w = weight.max(1e-9);
        }
    }

    fn retire(&self, id: u64) {
        self.active.lock().unwrap().remove(&id);
    }

    /// Number of lanes currently mid-task (the link-pressure signal the
    /// residency facade feeds its precision-floor decision).
    pub fn active_lanes(&self) -> usize {
        self.active.lock().unwrap().len()
    }
}

/// One busy lane's registration with the arbiter (RAII: dropping frees
/// the lane's bandwidth share for the others).
pub struct LaneGrant<'a> {
    arb: &'a LinkArbiter,
    id: u64,
}

impl LaneGrant<'_> {
    /// This lane's fair share of the link at this instant (0, 1].
    pub fn share(&self) -> f64 {
        self.arb.share_of(self.id)
    }

    /// Re-weight the lane mid-task (a started prefetch promoted to
    /// on-demand re-prioritizes its remaining chunks).
    pub fn set_weight(&self, weight: f64) {
        self.arb.set_weight(self.id, weight);
    }

    /// Link-time budget of a `bytes` chunk at the current fair share
    /// (excludes the per-transfer setup latency).
    pub fn chunk_time(&self, bytes: usize) -> Duration {
        let bw = self.arb.link.bytes_per_s * self.share();
        Duration::from_secs_f64(bytes as f64 / bw.max(1e-9))
    }
}

impl Drop for LaneGrant<'_> {
    fn drop(&mut self) {
        self.arb.retire(self.id);
    }
}

/// Real-path transfer engine: copies bytes and enforces the link rate
/// through the shared [`LinkArbiter`].
pub struct ThrottledCopier {
    pub link: LinkModel,
    arbiter: LinkArbiter,
    bytes_moved: AtomicU64,
    transfers: AtomicU64,
}

impl ThrottledCopier {
    pub fn new(link: LinkModel) -> Self {
        Self {
            link,
            arbiter: LinkArbiter::new(link),
            bytes_moved: AtomicU64::new(0),
            transfers: AtomicU64::new(0),
        }
    }

    /// Copy `src` into `dst` at the modeled link rate, as ONE chunk on one
    /// lane: blocking and non-preemptible (the pre-pipeline cudaMemcpy
    /// semantics — the loader's chunked path uses [`Self::lane`] +
    /// [`Self::charge_chunk`] instead). Returns the wall time spent.
    pub fn transfer(&self, src: &[u8], dst: &mut [u8]) -> Duration {
        assert_eq!(src.len(), dst.len());
        let t0 = Instant::now();
        let grant = self.arbiter.begin(ONDEMAND_WEIGHT);
        dst.copy_from_slice(src);
        let budget =
            Duration::from_secs_f64(self.link.latency_s) + grant.chunk_time(src.len());
        let elapsed = t0.elapsed();
        if elapsed < budget {
            std::thread::sleep(budget - elapsed);
        }
        drop(grant);
        self.bytes_moved.fetch_add(src.len() as u64, Ordering::Relaxed);
        self.transfers.fetch_add(1, Ordering::Relaxed);
        t0.elapsed()
    }

    /// Register a busy lane at `weight` for a chunked transfer.
    pub fn lane(&self, weight: f64) -> LaneGrant<'_> {
        self.arbiter.begin(weight)
    }

    /// Sleep the fixed per-transfer setup latency (DMA setup / syscall);
    /// charged once per transfer start or preemption resume.
    pub fn charge_latency(&self) {
        if self.link.latency_s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(self.link.latency_s));
        }
    }

    /// Charge one already-copied chunk of `bytes` against the shared link
    /// budget: sleeps the remainder of the lane's fair-share time beyond
    /// `spent` (the wall time the memcpy itself took) and accounts the
    /// bytes. Called WITHOUT the destination slot's lock held, so cache
    /// readers of other slots never block behind a modeled PCIe stall.
    pub fn charge_chunk(&self, grant: &LaneGrant<'_>, bytes: usize, spent: Duration) {
        let budget = grant.chunk_time(bytes);
        if spent < budget {
            std::thread::sleep(budget - spent);
        }
        self.bytes_moved.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Hold one lane busy for `d` without moving any bytes — the fault
    /// plan's injected I/O-lane stall. Registering a real grant (instead
    /// of a bare sleep) makes the stall visible to every link-pressure
    /// consumer: [`Self::active_lanes`] rises and other lanes' fair share
    /// shrinks for the duration, exactly like a wedged DMA engine still
    /// holding the link.
    pub fn stall_lane(&self, weight: f64, d: Duration) {
        let _grant = self.arbiter.begin(weight);
        std::thread::sleep(d);
    }

    /// Count one completed (possibly multi-chunk, possibly resumed)
    /// transfer.
    pub fn note_transfer(&self) {
        self.transfers.fetch_add(1, Ordering::Relaxed);
    }

    /// Lanes currently mid-transfer on the shared link (queue-pressure
    /// proxy: more busy lanes = less fair-share bandwidth for a new miss).
    pub fn active_lanes(&self) -> usize {
        self.arbiter.active_lanes()
    }

    pub fn bytes_moved(&self) -> u64 {
        self.bytes_moved.load(Ordering::Relaxed)
    }

    pub fn transfers(&self) -> u64 {
        self.transfers.load(Ordering::Relaxed)
    }
}

/// Virtual time source for the discrete-event simulator. Thread-safe so
/// sim components can share it; stores nanoseconds.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::Relaxed))
    }

    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Advance to `t` if it is in the future.
    pub fn advance_to(&self, t: Duration) {
        let t_ns = t.as_nanos() as u64;
        let mut cur = self.now_ns.load(Ordering::Relaxed);
        while t_ns > cur {
            match self.now_ns.compare_exchange(cur, t_ns, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }
}

/// A pinned arena of cache slots: sized at startup (the paper's expert
/// cache is pre-allocated GPU memory), handed out by slot index. Slots of
/// one pool all have identical record size.
pub struct SlotArena {
    buf: Vec<u8>,
    slot_bytes: usize,
    slots: usize,
}

impl SlotArena {
    pub fn new(slots: usize, slot_bytes: usize) -> Self {
        // u32 backing for 4-byte alignment of f32 views into slots
        let words = (slots * slot_bytes + 3) / 4;
        let mut v32 = vec![0u32; words];
        let buf = unsafe {
            let ptr = v32.as_mut_ptr() as *mut u8;
            let cap = v32.capacity() * 4;
            std::mem::forget(v32);
            Vec::from_raw_parts(ptr, slots * slot_bytes, cap)
        };
        Self { buf, slot_bytes, slots }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    pub fn slot(&self, i: usize) -> &[u8] {
        assert!(i < self.slots);
        &self.buf[i * self.slot_bytes..(i + 1) * self.slot_bytes]
    }

    pub fn slot_mut(&mut self, i: usize) -> &mut [u8] {
        assert!(i < self.slots);
        &mut self.buf[i * self.slot_bytes..(i + 1) * self.slot_bytes]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_math() {
        let l = LinkModel { bytes_per_s: 1e9, latency_s: 1e-3 };
        let t = l.transfer_time(1_000_000);
        assert!((t.as_secs_f64() - 0.002).abs() < 1e-9);
    }

    #[test]
    fn throttled_copy_moves_bytes_and_enforces_rate() {
        let c = ThrottledCopier::new(LinkModel { bytes_per_s: 100e6, latency_s: 0.0 });
        let src = vec![7u8; 1_000_000]; // 10 ms at 100 MB/s
        let mut dst = vec![0u8; 1_000_000];
        let t = c.transfer(&src, &mut dst);
        assert_eq!(dst, src);
        assert!(t.as_secs_f64() >= 0.009, "took {t:?}");
        assert_eq!(c.bytes_moved(), 1_000_000);
        assert_eq!(c.transfers(), 1);
    }

    #[test]
    fn arbiter_fair_share_math() {
        let arb = LinkArbiter::new(LinkModel { bytes_per_s: 1e6, latency_s: 0.0 });
        let a = arb.begin(ONDEMAND_WEIGHT);
        assert!((a.share() - 1.0).abs() < 1e-12, "lone lane owns the link");
        let b = arb.begin(PREFETCH_WEIGHT);
        assert!((a.share() - 0.8).abs() < 1e-12, "4:1 weighted split");
        assert!((b.share() - 0.2).abs() < 1e-12);
        // shares always sum to 1: total bandwidth is conserved
        assert!((a.share() + b.share() - 1.0).abs() < 1e-12);
        // a chunk charged at 20% share takes 5x the full-rate time
        let full = b.chunk_time(1000).as_secs_f64();
        assert!((full - 0.005).abs() < 1e-9, "got {full}");
        // promotion re-weights in place
        b.set_weight(ONDEMAND_WEIGHT);
        assert!((b.share() - 0.5).abs() < 1e-12);
        drop(a);
        assert!((b.share() - 1.0).abs() < 1e-12, "retired lane frees its share");
    }

    #[test]
    fn charge_chunk_sleeps_shared_budget() {
        let c = ThrottledCopier::new(LinkModel { bytes_per_s: 1e6, latency_s: 0.0 });
        let lane = c.lane(PREFETCH_WEIGHT);
        let t0 = Instant::now();
        c.charge_chunk(&lane, 10_000, Duration::ZERO); // 10 ms at 1 MB/s
        assert!(t0.elapsed().as_secs_f64() >= 0.009);
        assert_eq!(c.bytes_moved(), 10_000);
        assert_eq!(c.transfers(), 0, "chunks are not transfers");
        c.note_transfer();
        assert_eq!(c.transfers(), 1);
    }

    #[test]
    fn stall_lane_occupies_the_link() {
        let c = Arc::new(ThrottledCopier::new(LinkModel { bytes_per_s: 1e9, latency_s: 0.0 }));
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.stall_lane(ONDEMAND_WEIGHT, Duration::from_millis(250));
        });
        let t0 = Instant::now();
        while c.active_lanes() == 0 && t0.elapsed() < Duration::from_secs(2) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(c.active_lanes(), 1, "a stalled lane holds the link");
        h.join().unwrap();
        assert_eq!(c.active_lanes(), 0, "the stall retires its grant");
        assert_eq!(c.bytes_moved(), 0, "a stall moves no bytes");
    }

    #[test]
    fn virtual_clock_advances() {
        let clk = VirtualClock::new();
        clk.advance(Duration::from_millis(5));
        clk.advance_to(Duration::from_millis(3)); // no-op, in the past
        assert_eq!(clk.now(), Duration::from_millis(5));
        clk.advance_to(Duration::from_millis(9));
        assert_eq!(clk.now(), Duration::from_millis(9));
    }

    #[test]
    fn arena_slots_disjoint_and_aligned() {
        let mut a = SlotArena::new(3, 10);
        a.slot_mut(1).fill(0xAB);
        assert!(a.slot(0).iter().all(|&b| b == 0));
        assert!(a.slot(1).iter().all(|&b| b == 0xAB));
        assert!(a.slot(2).iter().all(|&b| b == 0));
        assert_eq!(a.slot(0).as_ptr() as usize % 4, 0);
    }
}
