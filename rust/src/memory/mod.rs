//! The two-tier memory hierarchy (Fig 2): experts move from "next-level
//! memory" (the `ExpertStore`) into the expert cache across a
//! bandwidth-limited link.
//!
//! Two transfer engines implement the same accounting:
//!
//! * [`ThrottledCopier`] — the *real* path: performs the actual memcpy of
//!   the expert bytes and sleeps the remainder of `bytes/bandwidth +
//!   latency`, emulating PCIe/SSD at a configured (scaled) rate. Transfers
//!   are **non-preemptible once started**, matching the paper's
//!   cudaMemcpy observation (§3.3, Fig 9) — the source of misprediction
//!   penalties.
//! * [`VirtualClock`] — the simulator's time source: transfers charge
//!   virtual nanoseconds, no bytes move.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bandwidth model of the expert-loading link.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub bytes_per_s: f64,
    pub latency_s: f64,
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(self.latency_s + bytes as f64 / self.bytes_per_s)
    }
}

/// Real-path transfer engine: copies bytes and enforces the link rate.
pub struct ThrottledCopier {
    pub link: LinkModel,
    bytes_moved: AtomicU64,
    transfers: AtomicU64,
}

impl ThrottledCopier {
    pub fn new(link: LinkModel) -> Self {
        Self { link, bytes_moved: AtomicU64::new(0), transfers: AtomicU64::new(0) }
    }

    /// Copy `src` into `dst` at the modeled link rate. Blocking and
    /// non-preemptible (cudaMemcpy semantics). Returns the wall time spent.
    pub fn transfer(&self, src: &[u8], dst: &mut [u8]) -> Duration {
        assert_eq!(src.len(), dst.len());
        let t0 = Instant::now();
        let budget = self.link.transfer_time(src.len());
        dst.copy_from_slice(src);
        let elapsed = t0.elapsed();
        if elapsed < budget {
            std::thread::sleep(budget - elapsed);
        }
        self.bytes_moved.fetch_add(src.len() as u64, Ordering::Relaxed);
        self.transfers.fetch_add(1, Ordering::Relaxed);
        t0.elapsed()
    }

    pub fn bytes_moved(&self) -> u64 {
        self.bytes_moved.load(Ordering::Relaxed)
    }

    pub fn transfers(&self) -> u64 {
        self.transfers.load(Ordering::Relaxed)
    }
}

/// Virtual time source for the discrete-event simulator. Thread-safe so
/// sim components can share it; stores nanoseconds.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::Relaxed))
    }

    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Advance to `t` if it is in the future.
    pub fn advance_to(&self, t: Duration) {
        let t_ns = t.as_nanos() as u64;
        let mut cur = self.now_ns.load(Ordering::Relaxed);
        while t_ns > cur {
            match self.now_ns.compare_exchange(cur, t_ns, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }
}

/// A pinned arena of cache slots: sized at startup (the paper's expert
/// cache is pre-allocated GPU memory), handed out by slot index. Slots of
/// one pool all have identical record size.
pub struct SlotArena {
    buf: Vec<u8>,
    slot_bytes: usize,
    slots: usize,
}

impl SlotArena {
    pub fn new(slots: usize, slot_bytes: usize) -> Self {
        // u32 backing for 4-byte alignment of f32 views into slots
        let words = (slots * slot_bytes + 3) / 4;
        let mut v32 = vec![0u32; words];
        let buf = unsafe {
            let ptr = v32.as_mut_ptr() as *mut u8;
            let cap = v32.capacity() * 4;
            std::mem::forget(v32);
            Vec::from_raw_parts(ptr, slots * slot_bytes, cap)
        };
        Self { buf, slot_bytes, slots }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    pub fn slot(&self, i: usize) -> &[u8] {
        assert!(i < self.slots);
        &self.buf[i * self.slot_bytes..(i + 1) * self.slot_bytes]
    }

    pub fn slot_mut(&mut self, i: usize) -> &mut [u8] {
        assert!(i < self.slots);
        &mut self.buf[i * self.slot_bytes..(i + 1) * self.slot_bytes]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_math() {
        let l = LinkModel { bytes_per_s: 1e9, latency_s: 1e-3 };
        let t = l.transfer_time(1_000_000);
        assert!((t.as_secs_f64() - 0.002).abs() < 1e-9);
    }

    #[test]
    fn throttled_copy_moves_bytes_and_enforces_rate() {
        let c = ThrottledCopier::new(LinkModel { bytes_per_s: 100e6, latency_s: 0.0 });
        let src = vec![7u8; 1_000_000]; // 10 ms at 100 MB/s
        let mut dst = vec![0u8; 1_000_000];
        let t = c.transfer(&src, &mut dst);
        assert_eq!(dst, src);
        assert!(t.as_secs_f64() >= 0.009, "took {t:?}");
        assert_eq!(c.bytes_moved(), 1_000_000);
        assert_eq!(c.transfers(), 1);
    }

    #[test]
    fn virtual_clock_advances() {
        let clk = VirtualClock::new();
        clk.advance(Duration::from_millis(5));
        clk.advance_to(Duration::from_millis(3)); // no-op, in the past
        assert_eq!(clk.now(), Duration::from_millis(5));
        clk.advance_to(Duration::from_millis(9));
        assert_eq!(clk.now(), Duration::from_millis(9));
    }

    #[test]
    fn arena_slots_disjoint_and_aligned() {
        let mut a = SlotArena::new(3, 10);
        a.slot_mut(1).fill(0xAB);
        assert!(a.slot(0).iter().all(|&b| b == 0));
        assert!(a.slot(1).iter().all(|&b| b == 0xAB));
        assert!(a.slot(2).iter().all(|&b| b == 0));
        assert_eq!(a.slot(0).as_ptr() as usize % 4, 0);
    }
}
