//! Minimal host-side f32 tensor for coordinator logic (residual adds,
//! top-k over gate probs, sampling). All heavy math runs in the AOT HLO
//! artifacts; this exists so L3 never needs a BLAS.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row view for a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let cols = *self.shape.last().unwrap();
        &self.data[r * cols..(r + 1) * cols]
    }

    /// In-place elementwise add.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, c: f32) {
        for a in &mut self.data {
            *a *= c;
        }
    }

    /// L2 norm of the whole tensor.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }
}

/// Softmax over a slice (numerically stable), returning a new Vec.
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|e| e / s).collect()
}

/// Indices and values of the k largest entries, descending.
pub fn topk(xs: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx.into_iter().take(k).map(|i| (i, xs[i])).collect()
}

pub fn argmax(xs: &[f32]) -> usize {
    topk(xs, 1)[0].0
}

/// Cross-entropy (nats) of `target` under `logits`.
pub fn cross_entropy(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = (logits.iter().map(|x| ((*x as f64) - m).exp()).sum::<f64>()).ln() + m;
    lse - logits[target] as f64
}

/// KL(p || q) of two softmax distributions given their logits.
pub fn kl_from_logits(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    let p = softmax(p_logits);
    let q = softmax(q_logits);
    p.iter()
        .zip(&q)
        .filter(|(pi, _)| **pi > 0.0)
        .map(|(pi, qi)| *pi as f64 * ((*pi as f64) / (*qi as f64).max(1e-30)).ln())
        .sum()
}

/// Sample from logits with temperature; t == 0 is greedy.
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut crate::util::rng::Rng) -> usize {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let scaled: Vec<f32> = logits.iter().map(|x| x / temperature).collect();
    let probs = softmax(&scaled);
    let weights: Vec<f64> = probs.iter().map(|p| *p as f64).collect();
    rng.weighted(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_large_inputs() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn topk_descending() {
        let t = topk(&[0.1, 0.9, 0.5, 0.7], 3);
        assert_eq!(t.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn cross_entropy_of_peaked_logits_small() {
        let ce = cross_entropy(&[10.0, -10.0], 0);
        assert!(ce < 1e-6);
        let ce_bad = cross_entropy(&[10.0, -10.0], 1);
        assert!(ce_bad > 10.0);
    }

    #[test]
    fn kl_zero_for_identical() {
        let l = [0.3, -1.0, 2.0];
        assert!(kl_from_logits(&l, &l).abs() < 1e-9);
        assert!(kl_from_logits(&l, &[0.0, 0.0, 0.0]) > 0.0);
    }

    #[test]
    fn tensor_add() {
        let mut a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![10.0, 20.0, 30.0, 40.0]);
        a.add_assign(&b);
        assert_eq!(a.data, vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(a.row(1), &[33.0, 44.0]);
    }

    #[test]
    fn greedy_sampling() {
        let mut rng = crate::util::rng::Rng::new(0);
        assert_eq!(sample_logits(&[0.0, 5.0, 1.0], 0.0, &mut rng), 1);
    }
}
