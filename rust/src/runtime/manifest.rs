//! Artifact manifest (`artifacts/<model>/manifest.json`) parsing, plus
//! decode s-variant resolution: which batched launch widths the artifact
//! set actually carries, and how a batch of n sequences pads to them.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Batched-decode launch widths the AOT compiler may emit
/// (`attn/gate/expert_*_s{2,4,8}`); a batch of n pads to the smallest one
/// that fits ([`pad_batch_width`]).
pub const DECODE_BATCH_WIDTHS: [usize; 3] = [2, 4, 8];

/// Largest decode batch one *padded-width* launch can carry (the PR 3
/// per-row-per-expert mode). Grouped execution has no such ceiling — see
/// [`MAX_GROUPED_BATCH`].
pub const MAX_DECODE_BATCH: usize = 8;

/// Expert-group launch widths the AOT compiler may emit for ragged
/// grouped execution (`expert_*_s{2..64}`): a group of g routed rows pads
/// to the smallest one that fits; oversized groups chunk at the largest.
/// Supersets [`DECODE_BATCH_WIDTHS`] so padded-width artifact sets keep
/// working as group launchers.
pub const GROUPED_WIDTHS: [usize; 6] = [2, 4, 8, 16, 32, 64];

/// Largest decode batch the grouped execution path admits. Not a launch
/// width: grouped mode sorts the batch's (token, expert) pairs by expert
/// and launches per *group*, so the batch width only bounds bookkeeping
/// (per-row KV/cursor state), not compiled artifact shapes.
pub const MAX_GROUPED_BATCH: usize = 64;

/// Smallest compiled-size launch width that fits a batch of `n` runnable
/// sequences (the padding rule of batched decode). None when `n` exceeds
/// [`MAX_DECODE_BATCH`] or is not a real batch (n < 2).
pub fn pad_batch_width(n: usize) -> Option<usize> {
    if n < 2 {
        return None;
    }
    DECODE_BATCH_WIDTHS.iter().copied().find(|&w| w >= n)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "uint8" => Ok(DType::U8),
            other => Err(format!("unsupported dtype '{other}'")),
        }
    }

    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<(Vec<usize>, DType)>,
    pub outputs: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    /// raw model section (config/mod.rs parses it into ModelConfig)
    pub model: Json,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// raw per-record checksum section, preserved verbatim when present
    /// (`model::integrity::IntegrityTable::from_json` parses it); older
    /// artifact sets predate it.
    pub integrity: Option<Json>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self, String> {
        let j = Json::parse(text)?;
        let model = j.get("model").cloned().ok_or("manifest missing 'model'")?;
        let integrity = j.get("integrity").cloned();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("artifact {name} missing file"))?
                .to_string();
            let mut inputs = Vec::new();
            for inp in a.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape: Vec<usize> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("input missing shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dtype = DType::parse(
                    inp.get("dtype").and_then(Json::as_str).ok_or("input missing dtype")?,
                )?;
                inputs.push((shape, dtype));
            }
            let outputs = a.get("outputs").and_then(Json::as_usize).unwrap_or(1);
            artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
        }
        Ok(Self { model, artifacts, integrity })
    }

    /// Names of all artifacts used in decode (S = 1) for a given prefetch
    /// depth and precision pair — what the engine precompiles at startup.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    /// Whether the `{base}_s{s}` variant of an artifact is present.
    pub fn has_variant(&self, base: &str, s: usize) -> bool {
        self.artifacts.contains_key(&format!("{base}_s{s}"))
    }

    /// Which batched-decode launch widths this artifact set fully covers:
    /// a width counts only when *every* unit of the decode step exists at
    /// that width — the gate stacks `gate_p{1..=stack_p}_s{w}`, both
    /// precision classes of the expert FFN, and the LM head. (Attention is
    /// per-row even in a batched step: each sequence has its own KV cache
    /// and position, which the `attn_s{w}` signature cannot express.)
    /// Widths missing any unit fall back to s=1 launches at runtime — the
    /// merged residency acquire still happens once per (batch, layer).
    pub fn decode_batch_widths(
        &self,
        stack_p: usize,
        ffn_prefix: &str,
        hi: &str,
        lo: &str,
    ) -> Vec<usize> {
        GROUPED_WIDTHS
            .iter()
            .copied()
            .filter(|&w| {
                (1..=stack_p.max(1)).all(|p| self.has_variant(&format!("gate_p{p}"), w))
                    && self.has_variant(&format!("{ffn_prefix}_{hi}"), w)
                    && self.has_variant(&format!("{ffn_prefix}_{lo}"), w)
                    && self.has_variant("head", w)
            })
            .collect()
    }

    /// Which expert-group launch widths this artifact set carries: only the
    /// FFN units matter (a group launch feeds one expert's record a slab of
    /// sorted tokens — gate and head shapes are irrelevant), but *both*
    /// precision classes must exist so a group never changes width when the
    /// residency tier flips. Groups bigger than every compiled width chunk
    /// at the largest one; an empty result means grouped launches fall back
    /// to bit-identical s=1 per-row launches.
    pub fn grouped_expert_widths(&self, ffn_prefix: &str, hi: &str, lo: &str) -> Vec<usize> {
        GROUPED_WIDTHS
            .iter()
            .copied()
            .filter(|&w| {
                self.has_variant(&format!("{ffn_prefix}_{hi}"), w)
                    && self.has_variant(&format!("{ffn_prefix}_{lo}"), w)
            })
            .collect()
    }

    /// Raw model section for config parsing.
    pub fn model_json(&self) -> Json {
        Json::Obj(
            [("model".to_string(), self.model.clone())].into_iter().collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"{
      "model": {"name": "m"},
      "artifacts": {
        "attn_s1": {"file": "attn_s1.hlo.txt",
          "inputs": [{"shape": [1, 256], "dtype": "float32"},
                     {"shape": [], "dtype": "int32"}],
          "outputs": 3},
        "expert_q8_s1": {"file": "expert_q8_s1.hlo.txt",
          "inputs": [{"shape": [256, 512], "dtype": "uint8"}],
          "outputs": 1}
      }}"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SRC).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts["attn_s1"];
        assert_eq!(a.outputs, 3);
        assert_eq!(a.inputs[0], (vec![1, 256], DType::F32));
        assert_eq!(a.inputs[1], (vec![], DType::I32));
        assert_eq!(m.artifacts["expert_q8_s1"].inputs[0].1, DType::U8);
    }

    #[test]
    fn integrity_section_is_carried_through() {
        let m = Manifest::parse(SRC).unwrap();
        assert!(m.integrity.is_none(), "seed manifests predate integrity");
        let with = SRC.replacen(
            "\"model\"",
            "\"integrity\": {\"algo\": \"fnv1a64\", \"records\": {}}, \"model\"",
            1,
        );
        let m = Manifest::parse(&with).unwrap();
        let sec = m.integrity.expect("integrity preserved");
        assert_eq!(sec.get("algo").and_then(Json::as_str), Some("fnv1a64"));
    }

    #[test]
    fn scalar_shape_is_empty_vec() {
        let m = Manifest::parse(SRC).unwrap();
        assert!(m.artifacts["attn_s1"].inputs[1].0.is_empty());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::U8.size(), 1);
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn pad_width_resolution() {
        assert_eq!(pad_batch_width(2), Some(2));
        assert_eq!(pad_batch_width(3), Some(4));
        assert_eq!(pad_batch_width(4), Some(4));
        assert_eq!(pad_batch_width(5), Some(8));
        assert_eq!(pad_batch_width(8), Some(8));
        // not a batch / beyond the largest compiled width
        assert_eq!(pad_batch_width(0), None);
        assert_eq!(pad_batch_width(1), None);
        assert_eq!(pad_batch_width(9), None);
    }

    fn variant_manifest(names: &[&str]) -> Manifest {
        let arts: Vec<String> = names
            .iter()
            .map(|n| format!(r#""{n}": {{"file": "{n}.hlo.txt", "inputs": [], "outputs": 1}}"#))
            .collect();
        let src = format!(r#"{{"model": {{"name": "m"}}, "artifacts": {{{}}}}}"#, arts.join(","));
        Manifest::parse(&src).unwrap()
    }

    #[test]
    fn batch_width_requires_full_decode_set() {
        // a typical seed artifact set: s1/s16/s128 only -> no batched widths
        let m = variant_manifest(&[
            "gate_p1_s1", "gate_p2_s1", "expert_fast_f32_s1", "expert_fast_q8_s1", "head_s1",
            "head_s16", "head_s128",
        ]);
        assert!(m.decode_batch_widths(2, "expert_fast", "f32", "q8").is_empty());
        assert!(m.has_variant("head", 16));
        assert!(!m.has_variant("gate_p2", 4));

        // a full s4 decode set resolves exactly {4}
        let m = variant_manifest(&[
            "gate_p1_s4", "gate_p2_s4", "expert_fast_f32_s4", "expert_fast_q8_s4", "head_s4",
        ]);
        assert_eq!(m.decode_batch_widths(2, "expert_fast", "f32", "q8"), vec![4]);

        // a width missing one gate depth of the stack is not usable
        let m = variant_manifest(&[
            "gate_p2_s4", "expert_fast_f32_s4", "expert_fast_q8_s4", "head_s4",
        ]);
        assert!(m.decode_batch_widths(2, "expert_fast", "f32", "q8").is_empty());
    }

    #[test]
    fn decode_widths_extend_past_legacy_ceiling() {
        // a full s16 decode set resolves {16}: the padded path is no
        // longer artificially capped at the legacy {2,4,8} ladder
        let m = variant_manifest(&[
            "gate_p1_s16", "gate_p2_s16", "expert_fast_f32_s16", "expert_fast_q8_s16",
            "head_s16",
        ]);
        assert_eq!(m.decode_batch_widths(2, "expert_fast", "f32", "q8"), vec![16]);
    }

    #[test]
    fn grouped_expert_widths_need_only_ffn_pairs() {
        // expert-only variants resolve grouped widths without gate/head
        let m = variant_manifest(&[
            "expert_fast_f32_s4", "expert_fast_q8_s4", "expert_fast_f32_s32",
            "expert_fast_q8_s32", "head_s1",
        ]);
        assert_eq!(m.grouped_expert_widths("expert_fast", "f32", "q8"), vec![4, 32]);

        // one precision class alone is not usable: a tier flip mid-step
        // must never change the launch width
        let m = variant_manifest(&["expert_fast_f32_s8", "head_s8", "gate_p1_s8"]);
        assert!(m.grouped_expert_widths("expert_fast", "f32", "q8").is_empty());
    }

    #[test]
    fn grouped_batch_ceiling_covers_width_ladder() {
        assert_eq!(GROUPED_WIDTHS.last().copied(), Some(MAX_GROUPED_BATCH));
        // the legacy padded ladder is a prefix of the grouped ladder
        assert_eq!(&GROUPED_WIDTHS[..3], &DECODE_BATCH_WIDTHS);
        assert!(MAX_GROUPED_BATCH > MAX_DECODE_BATCH);
    }
}
