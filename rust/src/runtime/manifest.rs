//! Artifact manifest (`artifacts/<model>/manifest.json`) parsing.

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "uint8" => Ok(DType::U8),
            other => Err(format!("unsupported dtype '{other}'")),
        }
    }

    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<(Vec<usize>, DType)>,
    pub outputs: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    /// raw model section (config/mod.rs parses it into ModelConfig)
    pub model: Json,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self, String> {
        let j = Json::parse(text)?;
        let model = j.get("model").cloned().ok_or("manifest missing 'model'")?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("artifact {name} missing file"))?
                .to_string();
            let mut inputs = Vec::new();
            for inp in a.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape: Vec<usize> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("input missing shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dtype = DType::parse(
                    inp.get("dtype").and_then(Json::as_str).ok_or("input missing dtype")?,
                )?;
                inputs.push((shape, dtype));
            }
            let outputs = a.get("outputs").and_then(Json::as_usize).unwrap_or(1);
            artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
        }
        Ok(Self { model, artifacts })
    }

    /// Names of all artifacts used in decode (S = 1) for a given prefetch
    /// depth and precision pair — what the engine precompiles at startup.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    /// Raw model section for config parsing.
    pub fn model_json(&self) -> Json {
        Json::Obj(
            [("model".to_string(), self.model.clone())].into_iter().collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"{
      "model": {"name": "m"},
      "artifacts": {
        "attn_s1": {"file": "attn_s1.hlo.txt",
          "inputs": [{"shape": [1, 256], "dtype": "float32"},
                     {"shape": [], "dtype": "int32"}],
          "outputs": 3},
        "expert_q8_s1": {"file": "expert_q8_s1.hlo.txt",
          "inputs": [{"shape": [256, 512], "dtype": "uint8"}],
          "outputs": 1}
      }}"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SRC).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts["attn_s1"];
        assert_eq!(a.outputs, 3);
        assert_eq!(a.inputs[0], (vec![1, 256], DType::F32));
        assert_eq!(a.inputs[1], (vec![], DType::I32));
        assert_eq!(m.artifacts["expert_q8_s1"].inputs[0].1, DType::U8);
    }

    #[test]
    fn scalar_shape_is_empty_vec() {
        let m = Manifest::parse(SRC).unwrap();
        assert!(m.artifacts["attn_s1"].inputs[1].0.is_empty());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::U8.size(), 1);
        assert!(DType::parse("float64").is_err());
    }
}
