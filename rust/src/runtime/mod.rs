//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! exposes typed execute helpers to the engine. One compiled executable per
//! (artifact name); Python is never on this path.

mod manifest;

pub use manifest::{
    pad_batch_width, ArtifactSpec, DType, Manifest, DECODE_BATCH_WIDTHS, GROUPED_WIDTHS,
    MAX_DECODE_BATCH, MAX_GROUPED_BATCH,
};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compiled-artifact registry for one model.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    execs: HashMap<String, PjRtLoadedExecutable>,
    /// cumulative wall time inside PJRT execute calls
    pub compute_time: std::cell::Cell<Duration>,
    /// execute-call count per artifact (perf accounting)
    pub calls: std::cell::RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Open `artifacts/<model>` and compile nothing yet (lazy per-artifact
    /// compilation keeps startup proportional to what a run actually uses).
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        let manifest_path = artifact_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = PjRtClient::cpu()?;
        Ok(Self {
            client,
            dir: artifact_dir.to_path_buf(),
            manifest,
            execs: HashMap::new(),
            compute_time: std::cell::Cell::new(Duration::ZERO),
            calls: std::cell::RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and memoize) one artifact.
    pub fn ensure(&mut self, name: &str) -> Result<()> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Compile a set of artifacts up front (used at engine startup so the
    /// request path never JITs).
    pub fn ensure_all<'a, I: IntoIterator<Item = &'a str>>(&mut self, names: I) -> Result<()> {
        for n in names {
            self.ensure(n)?;
        }
        Ok(())
    }

    /// Execute an artifact; returns the decomposed output tuple. Accepts
    /// owned literals or borrows (`&[&Literal]`) so precomputed weight
    /// literals are never deep-cloned on the request path.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> Result<Vec<Literal>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not compiled (call ensure first)"))?;
        if let Some(spec) = self.manifest.artifacts.get(name) {
            if spec.inputs.len() != args.len() {
                bail!(
                    "artifact '{name}' expects {} inputs, got {}",
                    spec.inputs.len(),
                    args.len()
                );
            }
        }
        let t0 = Instant::now();
        let result = exe.execute::<L>(args)?;
        let mut root = result[0][0].to_literal_sync()?;
        let outs = root.decompose_tuple()?;
        self.compute_time.set(self.compute_time.get() + t0.elapsed());
        *self.calls.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        Ok(outs)
    }

    /// Number of compiled artifacts (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.execs.len()
    }
}

// --------------------------------------------------------------------------
// Literal construction helpers
// --------------------------------------------------------------------------

/// f32 literal with the given dims from a host slice.
pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_f32: dims {:?} need {n} elements, got {}", dims, data.len());
    }
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

/// u8 literal (packed quantized codes).
pub fn lit_u8(dims: &[usize], data: &[u8]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_u8: dims {:?} need {n} bytes, got {}", dims, data.len());
    }
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::U8, dims, data)?)
}

/// s32 scalar literal (positions).
pub fn lit_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Copy a literal's f32 payload out to a Vec.
pub fn lit_to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lit_f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&[2, 3], &data).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit_to_f32(&lit).unwrap(), data);
    }

    #[test]
    fn lit_f32_rejects_bad_dims() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn lit_u8_roundtrip() {
        let data = vec![0u8, 127, 128, 255];
        let lit = lit_u8(&[4], &data).unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), data);
    }

    #[test]
    fn lit_scalar() {
        let lit = lit_i32(42);
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 42);
    }
}
