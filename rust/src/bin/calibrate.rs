//! Calibration utility: grid-search Eq. 3 weights minimizing mixed-precision
//! cache miss penalty on the synthetic calibration trace set (paper §3.4:
//! "we determine suitable values by minimizing the mixed precision expert
//! cache miss penalties on a calibration dataset").
use hobbit::cache::Policy;
use hobbit::trace::replay::{replay, ReplayConfig};
use hobbit::trace::{generate, TraceGenConfig};

fn main() {
    for (name, gen, cfg) in [
        ("mixtral-4090", TraceGenConfig::mixtral_like(),
         ReplayConfig { hi_capacity: 43, lo_capacity: 55, ..Default::default() }),
        ("mixtral-orin", TraceGenConfig::mixtral_like(),
         ReplayConfig { hi_capacity: 16, lo_capacity: 24, ..Default::default() }),
        ("phi-4090", TraceGenConfig::phi_like(),
         ReplayConfig { hi_capacity: 90, lo_capacity: 110, ..Default::default() }),
        ("phi-orin", TraceGenConfig::phi_like(),
         ReplayConfig { hi_capacity: 34, lo_capacity: 50, ..Default::default() }),
    ] {
        let ts = generate(&gen, 6, 96);
        let rand = replay(&ts, Policy::Random { seed: 1 }, &cfg).penalty;
        let lru = replay(&ts, Policy::Lru, &cfg).penalty;
        let lfu = replay(&ts, Policy::LfuSeq, &cfg).penalty;
        let lhu = replay(&ts, Policy::Lhu, &cfg).penalty;
        let fld = replay(&ts, Policy::Fld, &cfg).penalty;
        println!("{name}: rand {rand:.0} lru {lru:.0} lfu {lfu:.0} lhu {lhu:.0} fld {fld:.0}");
        let mut best = (f64::MAX, [0.0; 4]);
        let steps = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7];
        for &a in &steps { for &b in &steps { for &c in &steps {
            let d: f64 = 1.0 - a - b - c;
            if d < -1e-9 || d > 0.7 { continue; }
            let w = [a, b, c, d.max(0.0)];
            let p = replay(&ts, Policy::Multidim { w }, &cfg).penalty;
            if p < best.0 { best = (p, w); }
        }}}
        println!("  best multidim {:.0} at {:?}", best.0, best.1);
    }
}
