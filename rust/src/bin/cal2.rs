use hobbit::cache::Policy;
use hobbit::trace::replay::{replay, ReplayConfig};
use hobbit::trace::{generate, TraceGenConfig};
fn main() {
    let cands: [[f64;4];6] = [
        [0.7,0.0,0.1,0.2],[0.6,0.1,0.1,0.2],[0.55,0.1,0.15,0.2],
        [0.5,0.15,0.15,0.2],[0.65,0.05,0.1,0.2],[0.6,0.05,0.15,0.2]];
    for (name, gen, cfg) in [
        ("mixtral-4090", TraceGenConfig::mixtral_like(), ReplayConfig { hi_capacity: 43, lo_capacity: 55, ..Default::default() }),
        ("mixtral-orin", TraceGenConfig::mixtral_like(), ReplayConfig { hi_capacity: 16, lo_capacity: 24, ..Default::default() }),
        ("phi-4090", TraceGenConfig::phi_like(), ReplayConfig { hi_capacity: 90, lo_capacity: 110, ..Default::default() }),
        ("phi-orin", TraceGenConfig::phi_like(), ReplayConfig { hi_capacity: 34, lo_capacity: 50, ..Default::default() }),
    ] {
        let ts = generate(&gen, 6, 96);
        print!("{name}:");
        for w in cands { print!(" {:?}={:.0}", w, replay(&ts, Policy::Multidim{w}, &cfg).penalty); }
        println!();
    }
}
