use xla::*;
fn main() -> anyhow::Result<()> {
    let client = PjRtClient::cpu()?;
    for s in [1usize, 128] {
        let proto = HloModuleProto::from_text_file(&format!("/tmp/ffn_jnp_s{s}.hlo.txt"))?;
        let exe = client.compile(&XlaComputation::from_proto(&proto))?;
        let x = Literal::vec1(&vec![0.1f32; s*256]).reshape(&[s as i64,256])?;
        let w1 = Literal::vec1(&vec![0.01f32; 256*512]).reshape(&[256,512])?;
        let w3 = w1.clone();
        let w2 = Literal::vec1(&vec![0.01f32; 512*256]).reshape(&[512,256])?;
        let gw = Literal::vec1(&vec![1.0f32; s]);
        let args = [&x,&w1,&w3,&w2,&gw];
        for _ in 0..5 { exe.execute::<&Literal>(&args)?; }
        let t0 = std::time::Instant::now();
        let iters = 50;
        for _ in 0..iters { let r = exe.execute::<&Literal>(&args)?; let _ = r[0][0].to_literal_sync()?; }
        println!("jnp ffn s={s}: {:.3} ms/call", t0.elapsed().as_secs_f64()/iters as f64*1e3);
    }
    Ok(())
}
