//! Group quantization, byte-compatible with `python/compile/quantize.py`.
//!
//! Layout contract for W[rows, cols] quantized along rows with group G:
//!   scales f32[rows/G, cols]
//!   q8: i8 (two's complement, stored as u8) [rows, cols]
//!   q4: u8[rows/2, cols], element (r,c) = (packed[r/2,c] >> 4*(r%2)) & 0xF,
//!       value = nibble - 8
//!   q2: u8[rows/4, cols], element (r,c) = (packed[r/4,c] >> 2*(r%4)) & 0x3,
//!       value = (field - 2) + 0.5   (symmetric 4-level grid)
//!
//! The rust side quantizes only in tests/tools (the build step exports the
//! packed experts); at runtime it *dequantizes* for verification and the
//! CPU-assist compute mode (§4, Fig 13).

use crate::Precision;

/// Max representable code magnitude per format.
fn qmax(p: Precision) -> f32 {
    match p {
        Precision::Q8 => 127.0,
        Precision::Q4 => 7.0,
        Precision::Q2 => 1.5,
        Precision::F32 => panic!("f32 is not quantized"),
    }
}

/// Per-(group, col) scales.
pub fn group_scales(w: &[f32], rows: usize, cols: usize, group: usize, p: Precision) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(rows % group, 0);
    let ngroups = rows / group;
    let mut scales = vec![0.0f32; ngroups * cols];
    for g in 0..ngroups {
        for c in 0..cols {
            let mut amax = 0.0f32;
            for r in g * group..(g + 1) * group {
                amax = amax.max(w[r * cols + c].abs());
            }
            let s = amax / qmax(p);
            scales[g * cols + c] = if s == 0.0 { 1.0 } else { s };
        }
    }
    scales
}

/// Quantize + pack. Returns (packed bytes, scales).
pub fn quantize(w: &[f32], rows: usize, cols: usize, group: usize, p: Precision) -> (Vec<u8>, Vec<f32>) {
    let scales = group_scales(w, rows, cols, group, p);
    let code = |r: usize, c: usize| -> i32 {
        let s = scales[(r / group) * cols + c];
        let q = w[r * cols + c] / s;
        // numpy's np.round rounds half-to-even; match it bit-for-bit
        match p {
            Precision::Q2 => (q - 0.5).round_ties_even().clamp(-2.0, 1.0) as i32,
            _ => q.round_ties_even().clamp(-qmax(p), qmax(p)) as i32,
        }
    };
    let packed = match p {
        Precision::Q8 => {
            let mut out = vec![0u8; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    out[r * cols + c] = (code(r, c) as i8) as u8;
                }
            }
            out
        }
        Precision::Q4 => {
            let mut out = vec![0u8; rows / 2 * cols];
            for r in 0..rows {
                for c in 0..cols {
                    let u = (code(r, c) + 8) as u8;
                    out[(r / 2) * cols + c] |= u << (4 * (r % 2));
                }
            }
            out
        }
        Precision::Q2 => {
            let mut out = vec![0u8; rows / 4 * cols];
            for r in 0..rows {
                for c in 0..cols {
                    let u = (code(r, c) + 2) as u8;
                    out[(r / 4) * cols + c] |= u << (2 * (r % 4));
                }
            }
            out
        }
        Precision::F32 => panic!("f32 is not quantized"),
    };
    (packed, scales)
}

/// Dequantize packed codes + scales back to f32.
pub fn dequantize(
    packed: &[u8],
    scales: &[f32],
    rows: usize,
    cols: usize,
    group: usize,
    p: Precision,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let code = match p {
                Precision::Q8 => packed[r * cols + c] as i8 as f32,
                Precision::Q4 => ((packed[(r / 2) * cols + c] >> (4 * (r % 2))) & 0xF) as f32 - 8.0,
                Precision::Q2 => {
                    ((packed[(r / 4) * cols + c] >> (2 * (r % 4))) & 0x3) as f32 - 2.0 + 0.5
                }
                Precision::F32 => panic!("f32 is not quantized"),
            };
            out[r * cols + c] = code * scales[(r / group) * cols + c];
        }
    }
    out
}

/// Packed byte count of a [rows, cols] matrix (codes only, no scales).
pub fn packed_bytes(rows: usize, cols: usize, p: Precision) -> usize {
    match p {
        Precision::F32 => rows * cols * 4,
        Precision::Q8 => rows * cols,
        Precision::Q4 => rows / 2 * cols,
        Precision::Q2 => rows / 4 * cols,
    }
}

/// Scale float count of a [rows, cols] matrix.
pub fn scale_count(rows: usize, cols: usize, group: usize) -> usize {
    rows / group * cols
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_mini::check;
    use crate::util::rng::Rng;

    fn rand_w(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn q8_roundtrip_error_bound() {
        let mut rng = Rng::new(1);
        let (rows, cols, g) = (128, 16, 64);
        let w = rand_w(&mut rng, rows, cols, 0.05);
        let (packed, scales) = quantize(&w, rows, cols, g, Precision::Q8);
        let wd = dequantize(&packed, &scales, rows, cols, g, Precision::Q8);
        for r in 0..rows {
            for c in 0..cols {
                let step = scales[(r / g) * cols + c];
                assert!((wd[r * cols + c] - w[r * cols + c]).abs() <= step * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn error_ordering_q8_q4_q2() {
        let mut rng = Rng::new(2);
        let (rows, cols, g) = (256, 32, 64);
        let w = rand_w(&mut rng, rows, cols, 0.05);
        let mut errs = vec![];
        for p in [Precision::Q8, Precision::Q4, Precision::Q2] {
            let (packed, scales) = quantize(&w, rows, cols, g, p);
            let wd = dequantize(&packed, &scales, rows, cols, g, p);
            let e: f32 = wd.iter().zip(&w).map(|(a, b)| (a - b).abs()).sum::<f32>() / w.len() as f32;
            errs.push(e);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn packed_sizes() {
        assert_eq!(packed_bytes(256, 512, Precision::F32), 256 * 512 * 4);
        assert_eq!(packed_bytes(256, 512, Precision::Q8), 256 * 512);
        assert_eq!(packed_bytes(256, 512, Precision::Q4), 128 * 512);
        assert_eq!(packed_bytes(256, 512, Precision::Q2), 64 * 512);
    }

    #[test]
    fn zero_weights_finite() {
        let w = vec![0.0f32; 64 * 4];
        let (packed, scales) = quantize(&w, 64, 4, 64, Precision::Q2);
        let wd = dequantize(&packed, &scales, 64, 4, 64, Precision::Q2);
        assert!(wd.iter().all(|x| x.is_finite() && x.abs() <= 0.5));
    }

    #[test]
    fn prop_roundtrip_within_half_step() {
        check("quant roundtrip within half step", |rng| {
            let rows = [64, 128, 256][rng.below(3)];
            let cols = 1 + rng.below(12);
            let group = [32, 64][rng.below(2)];
            let p = [Precision::Q8, Precision::Q4, Precision::Q2][rng.below(3)];
            let scale = (rng.f32() * 2.0).max(1e-3);
            let w = rand_w(rng, rows, cols, scale);
            let (packed, scales) = quantize(&w, rows, cols, group, p);
            prop_assert!(packed.len() == packed_bytes(rows, cols, p));
            let wd = dequantize(&packed, &scales, rows, cols, group, p);
            for r in 0..rows {
                for c in 0..cols {
                    let step = scales[(r / group) * cols + c];
                    let err = (wd[r * cols + c] - w[r * cols + c]).abs();
                    prop_assert!(
                        err <= step * 0.5 + 1e-5 * scale,
                        "err {err} > half step {step} at ({r},{c}) fmt {p:?}"
                    );
                }
            }
            Ok(())
        });
    }
}
