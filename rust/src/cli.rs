//! Hand-rolled argument parsing (clap is not in the offline vendor set).
//! Flags are `--name value` or `--flag`; positional args fill in order.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse raw argv (after the subcommand). `bool_flags` names flags
    /// that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    out.flags.insert(name.to_string(), "true".into());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.insert(name.to_string(), "true".into());
                    } else {
                        out.flags.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.insert(name.to_string(), "true".into());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

pub const USAGE: &str = "\
hobbit — mixed-precision expert offloading for fast MoE inference
(reproduction of the HOBBIT paper; see DESIGN.md)

USAGE:
  hobbit <command> [options]

COMMANDS:
  serve       start the TCP serving front-end
              --addr 127.0.0.1:7077  --model mixtral-tiny  --artifacts artifacts
              --hardware rtx4090|orin|rtx4090+cpu  --max-conns N
              --interleaved (continuous serving: overlap one sequence's
              expert loads with other sequences' decode)  --max-active N
              --policy rr|sjf|token-budget|deadline (interleaved fairness:
              round-robin, shortest-remaining-tokens first, rr with a
              per-round decode-token quantum set by --token-budget N, or
              TTFT-deadline-aware prefill priority with the budget set by
              --ttft-deadline-ms N [500];
              cache-policy names still work here too, e.g. --policy lru)
              --max-batch N (true batched decode: gang up to N runnable
              sequences into one ragged grouped step — each layer's FFN
              runs as one grouped pass, dequantizing every unique expert
              once — with ONE merged expert acquire per layer; requires
              --interleaved, N <= 64)
              --no-grouped (legacy padded execution: launches padded to
              the nearest compiled width in {2,4,8}; caps --max-batch at 8)
              --max-replicas N (hot-expert read replication: up to N
              DRAM-to-DRAM read replicas per cache pool for predictor-hot
              experts demanded by several rows; snapshot reads rotate
              across replicas. 0 = off [default])
              --no-chunked-prefill (run each admission's whole prefill
              blocking instead of slicing it into 128/16/1 chunks that
              interleave with live decode)  --prefill-first (give prefill
              slices the engine before decode work each round)
              --io-lanes N (parallel expert-transfer lanes splitting the
              link bandwidth by weighted fair share [2])
              --io-chunk-bytes N (transfer preemption granularity: a
              prefetch yields to on-demand work between chunks [262144])
              --progressive (stream hi-pool misses low-bits-first: the
              expert is usable at the lo tier while the hi record upgrades
              it in place from the prefetch lane)
              --pin-precision f32|q8|q4|q2 (freeze the per-acquire fetch
              precision; excludes --progressive)
              --shard SPEC (experts resident in this node's DRAM, as flat
              indices: 'all', 'none', or ranges '0-31,48,64-95')
              --peers host:port=SPEC;host:port=SPEC (peer shard servers;
              requires --shard; local+peer shards must partition the
              model's experts disjointly and completely)
              --net-gbps G (modeled network link bandwidth for peer
              fetches — a second link class, independent of the PCIe
              budget [1])
              --admission-limit N (bound the interleaved admission queue;
              requests beyond N get a typed rejection instead of waiting)
              --slo-ttft-ms N (TTFT service objective; drives goodput
              accounting and the ladder's SLO-risk precision shed)
              --no-ladder (disable graceful degradation: keep full
              precision/prefetch under pressure; admission bound still
              applies)
              --client-timeout-ms N (per-connection read timeout [30000])
              --max-conn-threads N (bound on live reader threads; over-
              capacity connects get an error line, not a thread [256])
  shard-serve run one expert shard server (the peer side of --peers)
              --weights DIR (weight directory with manifest.json)
              --shard SPEC [all]  --addr 127.0.0.1:0
              --net-chunk-bytes N (streaming chunk size [65536])
              --fault-plan SEED:SPEC (serve deliberately corrupted or
              truncated replies, for integrity testing)
  generate    run one generation from the CLI
              --model M --artifacts DIR --prompt TEXT --max-new N --temp T
              --hardware H --no-dynamic --no-prefetch --policy P
              --fault-plan SEED:SPEC (deterministic fault injection at the
              tier boundaries: flip@disk#N, flip@peer#N, trunc@peer#N,
              flip@xfer#N, stall@xfer#N:MS, tear@upgrade#N; '#*' = every
              occurrence. Corruption is detected, quarantined, and healed
              by re-fetch — logits stay byte-identical)
  verify-weights
              scan a weight directory's expert records against the
              manifest checksums (exit 1 on any mismatch)
              --weights DIR  --verbose (print PASS lines too)
  figures     regenerate the paper's tables/figures
              --fig 3a|3b|5|7|9|10|11|14|15|16|17a|17b|18a|18b|table3 | --all
              --artifacts DIR --model M
  sim         run one simulator configuration
              --system hobbit|mo|mi|tf|ll|fd --hardware rtx4090|orin
              --model mixtral|phi --prompt-len N --tokens N
  selfcheck   artifact + weights + PJRT round-trip sanity check
              --artifacts DIR --model M
  help        print this help
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["all", "no-dynamic"])
    }

    #[test]
    fn values_and_bools() {
        let a = parse("--model mixtral-tiny --all --max-new 32 pos1");
        assert_eq!(a.get("model"), Some("mixtral-tiny"));
        assert!(a.has("all"));
        assert_eq!(a.get_usize("max-new", 0), 32);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("model", "m"), "m");
        assert_eq!(a.get_f64("temp", 0.5), 0.5);
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("--no-dynamic");
        assert!(a.has("no-dynamic"));
    }
}
