//! Open-loop trace-driven workload generation: the production traffic
//! harness behind the overload-control plane.
//!
//! Closed-loop load generators (N clients, each waiting for its response
//! before sending the next request) self-throttle: when the server slows
//! down, offered load drops with it, so overload behavior is unmeasurable
//! by construction. This module is **open-loop**: arrivals follow a
//! nonhomogeneous Poisson process whose rate the server cannot influence —
//! requests keep arriving on schedule whether or not earlier ones
//! finished, exactly like real user traffic. Combined with heavy-tailed
//! (log-normal) prompt/output lengths and diurnal rate modulation, this is
//! the workload shape that exposes queue growth, tail-latency blowups, and
//! the degradation ladder's engagement points.
//!
//! * [`generate_trace`] — deterministic arrival trace from a
//!   [`WorkloadConfig`] (Poisson thinning against the diurnal envelope,
//!   log-normal lengths; same seed → same trace, so A/B runs of
//!   ladder-on vs ladder-off see byte-identical offered load).
//! * [`drive`] — replay a trace open-loop against a
//!   [`Coordinator`] in interleaved mode: due arrivals are submitted via
//!   [`Coordinator::try_submit`] (typed rejections are *counted*, never
//!   retried — shed load is shed), the scheduler is stepped non-blocking,
//!   and the whole run is bounded by a wall-clock deadline so a wedged
//!   scheduler shows up as `hit_wall` instead of a hung test.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{Coordinator, GenerationResult, Request};
use crate::util::rng::Rng;

/// Shape of the offered load. Defaults model a modest bursty service:
/// 4 req/s mean with ±50% diurnal swing, ~32-token prompts and ~16-token
/// outputs with a heavy log-normal tail.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// mean arrival rate (requests/second of *trace* time)
    pub mean_rps: f64,
    /// diurnal modulation amplitude in [0, 1): instantaneous rate is
    /// `mean_rps * (1 + burstiness * sin(2π t / period))`
    pub burstiness: f64,
    /// diurnal period (seconds of trace time)
    pub diurnal_period_s: f64,
    /// trace length (seconds of trace time)
    pub duration_s: f64,
    /// log-normal prompt length: mean tokens and log-space sigma
    pub prompt_mean: f64,
    pub prompt_sigma: f64,
    /// hard cap on sampled prompt tokens (model max_seq guards the rest)
    pub prompt_max: usize,
    /// log-normal output budget: mean tokens and log-space sigma
    pub output_mean: f64,
    pub output_sigma: f64,
    pub output_max: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            mean_rps: 4.0,
            burstiness: 0.5,
            diurnal_period_s: 60.0,
            duration_s: 30.0,
            prompt_mean: 32.0,
            prompt_sigma: 0.8,
            prompt_max: 256,
            output_mean: 16.0,
            output_sigma: 0.6,
            output_max: 128,
            seed: 0x0B5E55ED,
        }
    }
}

impl WorkloadConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.mean_rps <= 0.0 || !self.mean_rps.is_finite() {
            return Err("mean_rps must be > 0".into());
        }
        if !(0.0..1.0).contains(&self.burstiness) {
            return Err("burstiness must be in [0,1)".into());
        }
        if self.diurnal_period_s <= 0.0 || self.duration_s <= 0.0 {
            return Err("diurnal period and duration must be > 0".into());
        }
        if self.prompt_mean < 1.0 || self.output_mean < 1.0 {
            return Err("mean lengths must be >= 1 token".into());
        }
        if self.prompt_sigma < 0.0 || self.output_sigma < 0.0 {
            return Err("length sigmas must be >= 0".into());
        }
        if self.prompt_max == 0 || self.output_max == 0 {
            return Err("length caps must be >= 1".into());
        }
        Ok(())
    }

    /// Peak instantaneous rate of the diurnal envelope (the thinning
    /// majorant).
    pub fn peak_rps(&self) -> f64 {
        self.mean_rps * (1.0 + self.burstiness)
    }
}

/// One arrival in the trace (times are trace-relative seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalEvent {
    pub at_s: f64,
    pub prompt_tokens: usize,
    pub max_new_tokens: usize,
}

/// A generated arrival trace, sorted by time.
#[derive(Debug, Clone, Default)]
pub struct OpenLoopTrace {
    pub events: Vec<ArrivalEvent>,
}

impl OpenLoopTrace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Log-normal sample with the requested *linear-space* mean: for
/// `X = exp(mu + sigma N)`, `E[X] = exp(mu + sigma²/2)`, so
/// `mu = ln(mean) − sigma²/2` keeps the configured mean while the sigma
/// controls how heavy the tail is.
fn lognormal(rng: &mut Rng, mean: f64, sigma: f64) -> f64 {
    let mu = mean.ln() - sigma * sigma / 2.0;
    (mu + sigma * rng.normal()).exp()
}

fn sample_len(rng: &mut Rng, mean: f64, sigma: f64, max: usize) -> usize {
    (lognormal(rng, mean, sigma).round() as usize).clamp(1, max)
}

/// Generate a bursty open-loop arrival trace: a nonhomogeneous Poisson
/// process (thinning against the [`WorkloadConfig::peak_rps`] majorant)
/// under the diurnal rate envelope, with log-normal heavy-tailed
/// prompt/output lengths per arrival. Deterministic in the seed.
pub fn generate_trace(cfg: &WorkloadConfig) -> OpenLoopTrace {
    let mut rng = Rng::new(cfg.seed);
    let peak = cfg.peak_rps();
    let mut events = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Exp(peak) inter-arrival for the homogeneous majorant process
        let u: f64 = rng.f64();
        t += -(1.0 - u).max(1e-300).ln() / peak;
        if t >= cfg.duration_s {
            break;
        }
        let rate = cfg.mean_rps
            * (1.0
                + cfg.burstiness
                    * (2.0 * std::f64::consts::PI * t / cfg.diurnal_period_s).sin());
        // thinning: keep with probability rate(t)/peak
        if rng.f64() * peak <= rate {
            events.push(ArrivalEvent {
                at_s: t,
                prompt_tokens: sample_len(
                    &mut rng,
                    cfg.prompt_mean,
                    cfg.prompt_sigma,
                    cfg.prompt_max,
                ),
                max_new_tokens: sample_len(
                    &mut rng,
                    cfg.output_mean,
                    cfg.output_sigma,
                    cfg.output_max,
                ),
            });
        }
    }
    OpenLoopTrace { events }
}

/// A deterministic prompt string that the byte-level tokenizer encodes to
/// exactly `tokens` ids (BOS + one id per byte): `tokens - 1` printable
/// non-whitespace ASCII chars, varied by `salt` so requests differ.
pub fn prompt_text(tokens: usize, salt: u64) -> String {
    let n = tokens.saturating_sub(1);
    (0..n).map(|i| (33 + ((salt as usize + i * 7) % 94)) as u8 as char).collect()
}

/// Replay knobs for [`drive`].
#[derive(Debug, Clone)]
pub struct DriveOptions {
    /// wall seconds per trace second (< 1 compresses the trace so tests
    /// replay a long diurnal window in milliseconds of wall time)
    pub time_scale: f64,
    /// hard wall-clock bound on the whole replay, drain included: a
    /// wedged scheduler surfaces as [`DriveReport::hit_wall`], not a hang
    pub max_wall: Duration,
    /// request ids are `id_base + event index`
    pub id_base: u64,
    /// sampling temperature of every generated request (0.0 = greedy)
    pub temperature: f32,
}

impl Default for DriveOptions {
    fn default() -> Self {
        Self {
            time_scale: 1.0,
            max_wall: Duration::from_secs(600),
            id_base: 1,
            temperature: 0.0,
        }
    }
}

/// What one open-loop replay did. `submitted + rejected` always equals
/// the number of due arrivals, and every submitted request is accounted
/// for as completed, failed, or still in flight when the wall hit.
#[derive(Debug, Default)]
pub struct DriveReport {
    pub submitted: usize,
    /// typed admission rejections (shed load; never retried)
    pub rejected: usize,
    /// per-request prefill failures surfaced by the coordinator
    pub failed: usize,
    /// completions, in completion order
    pub results: Vec<GenerationResult>,
    /// deepest admission-queue depth observed (bounded-queue invariant)
    pub max_queue_depth: usize,
    /// the wall-clock bound fired before the live set drained
    pub hit_wall: bool,
    /// wall time spent replaying
    pub wall: Duration,
}

/// Replay `trace` open-loop against an interleaved coordinator: submit
/// each arrival at its scheduled (scaled) time regardless of completions,
/// step the scheduler without blocking, and drain after the last arrival.
/// Never calls the blocking `step` — when every live sequence stalls on
/// the link and no arrival is due, it parks briefly instead, exactly like
/// the serving front-end's event loop.
pub fn drive(
    coord: &mut Coordinator,
    trace: &OpenLoopTrace,
    opts: &DriveOptions,
) -> Result<DriveReport> {
    let start = Instant::now();
    let mut rep = DriveReport::default();
    let mut next = 0usize;
    while next < trace.events.len() || coord.has_work() {
        if start.elapsed() >= opts.max_wall {
            rep.hit_wall = true;
            break;
        }
        let now_s = start.elapsed().as_secs_f64();
        while next < trace.events.len()
            && trace.events[next].at_s * opts.time_scale <= now_s
        {
            let ev = &trace.events[next];
            let req = Request {
                id: opts.id_base + next as u64,
                prompt: prompt_text(ev.prompt_tokens, next as u64),
                max_new_tokens: ev.max_new_tokens,
                temperature: opts.temperature,
            };
            match coord.try_submit(req) {
                Ok(()) => rep.submitted += 1,
                Err(_) => rep.rejected += 1,
            }
            next += 1;
        }
        rep.max_queue_depth = rep.max_queue_depth.max(coord.pending());
        rep.results.extend(coord.step_nonblocking()?);
        rep.failed += coord.take_failures().len();
        // park only when nothing is runnable: every live sequence stalled
        // on the link, or the live set is empty and the next arrival is
        // in the future
        let idle = if coord.has_work() {
            coord.all_stalled()
        } else {
            next < trace.events.len()
        };
        if idle {
            let next_due = trace
                .events
                .get(next)
                .map(|e| e.at_s * opts.time_scale - start.elapsed().as_secs_f64())
                .unwrap_or(f64::INFINITY);
            let park = next_due.clamp(0.0, 250e-6);
            if park > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(park));
            }
        }
    }
    coord.sync_report();
    rep.wall = start.elapsed();
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_in_seed() {
        let cfg = WorkloadConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert!(!a.is_empty());
        assert_eq!(a.events, b.events);
        let c = generate_trace(&WorkloadConfig { seed: 7, ..cfg });
        assert_ne!(a.events, c.events, "different seeds must differ");
    }

    #[test]
    fn arrival_count_tracks_mean_rate() {
        // thousands of arrivals: 50 rps * 60 s ≈ 3000 expected; Poisson
        // sd ≈ 55, so ±15% is a ~8σ envelope — deterministic in practice
        let cfg = WorkloadConfig {
            mean_rps: 50.0,
            duration_s: 60.0,
            ..Default::default()
        };
        let tr = generate_trace(&cfg);
        let expect = cfg.mean_rps * cfg.duration_s;
        let got = tr.len() as f64;
        assert!(
            (got - expect).abs() / expect < 0.15,
            "got {got} arrivals, expected ~{expect}"
        );
    }

    #[test]
    fn trace_is_sorted_and_bounded() {
        let cfg = WorkloadConfig { mean_rps: 20.0, duration_s: 30.0, ..Default::default() };
        let tr = generate_trace(&cfg);
        let mut last = 0.0;
        for ev in &tr.events {
            assert!(ev.at_s >= last && ev.at_s < cfg.duration_s);
            last = ev.at_s;
            assert!((1..=cfg.prompt_max).contains(&ev.prompt_tokens));
            assert!((1..=cfg.output_max).contains(&ev.max_new_tokens));
        }
    }

    #[test]
    fn lengths_are_heavy_tailed() {
        let cfg = WorkloadConfig {
            mean_rps: 100.0,
            duration_s: 60.0,
            prompt_sigma: 1.0,
            prompt_max: 100_000,
            ..Default::default()
        };
        let tr = generate_trace(&cfg);
        let mut lens: Vec<usize> = tr.events.iter().map(|e| e.prompt_tokens).collect();
        lens.sort_unstable();
        let p50 = lens[lens.len() / 2];
        let p99 = lens[lens.len() * 99 / 100];
        // log-normal with sigma 1: p99/p50 = exp(2.33 * sigma) ≈ 10
        assert!(
            p99 as f64 / p50 as f64 > 3.0,
            "tail not heavy: p50={p50} p99={p99}"
        );
        // the configured mean survives the sampling
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!(
            (mean - cfg.prompt_mean).abs() / cfg.prompt_mean < 0.25,
            "mean drifted: {mean} vs {}",
            cfg.prompt_mean
        );
    }

    #[test]
    fn burstiness_modulates_local_rate() {
        // with burstiness 0.9 and period = duration, the first half-period
        // (rising sine) must carry measurably more arrivals than the
        // second (falling below mean)
        let cfg = WorkloadConfig {
            mean_rps: 50.0,
            burstiness: 0.9,
            diurnal_period_s: 40.0,
            duration_s: 40.0,
            ..Default::default()
        };
        let tr = generate_trace(&cfg);
        let half = cfg.duration_s / 2.0;
        let first = tr.events.iter().filter(|e| e.at_s < half).count();
        let second = tr.len() - first;
        assert!(
            first as f64 > 1.2 * second as f64,
            "no burst: first={first} second={second}"
        );
    }

    #[test]
    fn prompt_text_encodes_to_exact_token_count() {
        let tok = crate::tokenizer::Tokenizer::new();
        for want in [1usize, 2, 17, 64] {
            let text = prompt_text(want, 3);
            assert_eq!(tok.encode(&text).len(), want, "tokens for {want}");
        }
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        WorkloadConfig::default().validate().unwrap();
        let bad = |f: fn(&mut WorkloadConfig)| {
            let mut c = WorkloadConfig::default();
            f(&mut c);
            c.validate().is_err()
        };
        assert!(bad(|c| c.mean_rps = 0.0));
        assert!(bad(|c| c.burstiness = 1.0));
        assert!(bad(|c| c.duration_s = 0.0));
        assert!(bad(|c| c.prompt_mean = 0.5));
        assert!(bad(|c| c.output_max = 0));
        assert!(bad(|c| c.prompt_sigma = -0.1));
    }
}
