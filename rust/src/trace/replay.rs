//! Cache-policy replay: drive a `CacheManager` with the access stream of a
//! gating trace (no bytes, no clock) and report miss penalties. This is
//! the engine behind Fig 11 (LFU vs LHU per-expert) and Fig 18 (policy
//! comparison, model-level vs sequence-level).

use crate::cache::{CacheManager, Policy, Pool};
use crate::loader::scorer::{self, Class};
use crate::ExpertKey;

use super::TraceSet;

#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub top_k: usize,
    pub t1: f64,
    pub t2: f64,
    /// mixed-precision decisions on (HOBBIT) or everything-hi (baselines)
    pub dynamic: bool,
    pub hi_capacity: usize,
    pub lo_capacity: usize,
    /// miss-penalty ratio B_l/B_h
    pub penalty_ratio: f64,
    /// reset records at sequence boundaries (sequence-level policies)
    pub seq_level: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            top_k: 2,
            t1: 0.6,
            t2: 0.9,
            dynamic: true,
            hi_capacity: 16,
            lo_capacity: 24,
            penalty_ratio: 0.25,
            seq_level: true,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ReplayResult {
    pub accesses: u64,
    pub hits: u64,
    pub misses_hi: u64,
    pub misses_lo: u64,
    pub penalty: f64,
    /// per-(layer, expert) miss counts [hi, lo]
    pub per_expert_misses: Vec<[u64; 2]>,
    pub per_expert_hits: Vec<u64>,
}

impl ReplayResult {
    pub fn hit_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// Replay `traces` under `policy`.
pub fn replay(traces: &TraceSet, policy: Policy, cfg: &ReplayConfig) -> ReplayResult {
    let first = traces.seqs.first().expect("empty trace set");
    let (n_layers, n_experts) = (first.n_layers, first.n_experts);
    let mut cache = CacheManager::new(
        n_layers,
        n_experts,
        cfg.hi_capacity,
        0,
        cfg.lo_capacity,
        0,
        policy,
        cfg.penalty_ratio,
    );
    let mut res = ReplayResult {
        per_expert_misses: vec![[0, 0]; (n_layers * n_experts) as usize],
        per_expert_hits: vec![0; (n_layers * n_experts) as usize],
        ..Default::default()
    };

    for trace in &traces.seqs {
        if cfg.seq_level {
            cache.reset_sequence();
        }
        for t in 0..trace.n_tokens {
            cache.records.note_token();
            for l in 0..trace.n_layers {
                let ev = trace.event(t, l);
                let decisions =
                    scorer::decide(&ev.probs, cfg.top_k, cfg.t1, cfg.t2, cfg.dynamic);
                for d in decisions {
                    if d.class == Class::Skip {
                        continue;
                    }
                    let key = ExpertKey::new(l, d.expert);
                    let idx = key.index(n_experts) as usize;
                    let pool = match d.class {
                        Class::Hi => Pool::Hi,
                        _ => Pool::Lo,
                    };
                    res.accesses += 1;
                    let mut hit = cache.access(key, pool);
                    if !hit && pool == Pool::Lo && cache.hi.contains_ready(key) {
                        // free upgrade from the hi pool
                        hit = true;
                        cache.stats.misses_lo -= 1;
                        cache.stats.miss_penalty -= cfg.penalty_ratio;
                    }
                    if hit {
                        res.hits += 1;
                        res.per_expert_hits[idx] += 1;
                    } else {
                        match pool {
                            Pool::Hi => {
                                res.misses_hi += 1;
                                res.penalty += 1.0;
                                res.per_expert_misses[idx][0] += 1;
                            }
                            Pool::Lo => {
                                res.misses_lo += 1;
                                res.penalty += cfg.penalty_ratio;
                                res.per_expert_misses[idx][1] += 1;
                            }
                        }
                        if let Some(r) = cache.reserve(key, pool, l) {
                            let _ = r;
                            cache.commit(key, pool);
                        }
                    }
                    cache.note_use(key, pool);
                }
            }
        }
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, TraceGenConfig};

    fn traces() -> TraceSet {
        let cfg = TraceGenConfig { n_layers: 8, n_experts: 8, ..TraceGenConfig::mixtral_like() };
        generate(&cfg, 4, 48)
    }

    #[test]
    fn policies_beat_random_on_penalty() {
        let ts = traces();
        let cfg = ReplayConfig { hi_capacity: 24, lo_capacity: 24, ..Default::default() };
        let rand = replay(&ts, Policy::Random { seed: 3 }, &cfg);
        let lru = replay(&ts, Policy::Lru, &cfg);
        let multi = replay(&ts, Policy::Multidim { w: [0.65, 0.05, 0.10, 0.20] }, &cfg);
        assert!(lru.penalty < rand.penalty, "LRU {} !< random {}", lru.penalty, rand.penalty);
        assert!(
            multi.penalty <= lru.penalty * 1.02,
            "multidim {} not competitive with LRU {}",
            multi.penalty,
            lru.penalty
        );
    }

    #[test]
    fn bigger_cache_fewer_misses() {
        let ts = traces();
        let small = replay(
            &ts,
            Policy::Lru,
            &ReplayConfig { hi_capacity: 8, lo_capacity: 8, ..Default::default() },
        );
        let large = replay(
            &ts,
            Policy::Lru,
            &ReplayConfig { hi_capacity: 48, lo_capacity: 48, ..Default::default() },
        );
        assert!(large.penalty < small.penalty);
        assert!(large.hit_ratio() > small.hit_ratio());
    }

    #[test]
    fn full_cache_no_misses_after_warmup() {
        let ts = traces();
        // capacity covers every (layer, expert): only cold misses remain
        let r = replay(
            &ts,
            Policy::Lru,
            &ReplayConfig { hi_capacity: 64, lo_capacity: 64, ..Default::default() },
        );
        assert!((r.misses_hi + r.misses_lo) <= 64 * ts.seqs.len() as u64);
    }

    #[test]
    fn accounting_consistent() {
        let ts = traces();
        let r = replay(&ts, Policy::LfuSeq, &ReplayConfig::default());
        assert_eq!(r.accesses, r.hits + r.misses_hi + r.misses_lo);
        let per_expert: u64 = r.per_expert_misses.iter().map(|m| m[0] + m[1]).sum();
        assert_eq!(per_expert, r.misses_hi + r.misses_lo);
    }
}
