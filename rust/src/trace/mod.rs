//! Gating traces: the routing behaviour of a MoE model, as (token, layer)
//! → gate distribution. Three sources:
//!
//! * **captured** from the real engine (`from_capture`);
//! * **synthetic** from a generative model calibrated to the paper's
//!   Fig 10 statistics (sequence-level expert preferences + temporal
//!   correlation between consecutive tokens + cross-layer smoothness);
//! * parsed from a JSON file (capture/replay across runs).
//!
//! Traces feed the cache replayer (Fig 11/18) and the paper-scale
//! discrete-event simulator (Fig 14-17).

pub mod replay;

use crate::engine::RoutingObs;
use crate::tensor::{softmax, topk};
use crate::util::json::{arr, num, obj, Json};
use crate::util::rng::Rng;

/// Gate distribution of one (token, layer).
#[derive(Debug, Clone)]
pub struct GateEvent {
    pub token: u32,
    pub layer: u32,
    pub probs: Vec<f32>,
}

impl GateEvent {
    pub fn top_k(&self, k: usize) -> Vec<(usize, f32)> {
        topk(&self.probs, k)
    }
}

/// One sequence: events ordered token-major, layer-minor.
#[derive(Debug, Clone)]
pub struct SeqTrace {
    pub n_layers: u32,
    pub n_experts: u32,
    pub n_tokens: u32,
    pub events: Vec<GateEvent>,
}

impl SeqTrace {
    pub fn event(&self, token: u32, layer: u32) -> &GateEvent {
        let i = (token * self.n_layers + layer) as usize;
        let e = &self.events[i];
        debug_assert_eq!((e.token, e.layer), (token, layer));
        e
    }
}

#[derive(Debug, Clone, Default)]
pub struct TraceSet {
    pub seqs: Vec<SeqTrace>,
}

/// Generative model parameters, defaults calibrated so the synthetic
/// traces reproduce the paper's Fig 10 measurements on Mixtral-8x7B:
/// top-1 reuse probability ≈ 0.4-0.6 (> theoretical 0.25) and clear
/// sequence-level expert preferences.
#[derive(Debug, Clone)]
pub struct TraceGenConfig {
    pub n_layers: u32,
    pub n_experts: u32,
    pub top_k: usize,
    /// Dirichlet concentration of per-(seq, layer) expert preferences;
    /// smaller = stronger sequence-level skew (Fig 10b).
    pub pref_alpha: f64,
    /// AR(1) coefficient of the token-level latent; larger = stronger
    /// consecutive-token reuse (Fig 10a).
    pub temporal_rho: f64,
    /// scale of the token latent relative to the preference logits.
    pub latent_scale: f64,
    /// per-layer noise on the shared latent; smaller = more cross-layer
    /// similarity (higher prefetch accuracy, Fig 7).
    pub layer_noise: f64,
    pub seed: u64,
}

impl TraceGenConfig {
    pub fn mixtral_like() -> Self {
        Self {
            n_layers: 32,
            n_experts: 8,
            top_k: 2,
            pref_alpha: 0.8,
            temporal_rho: 0.85,
            latent_scale: 1.2,
            layer_noise: 0.35,
            seed: 7,
        }
    }

    pub fn phi_like() -> Self {
        Self { n_experts: 16, ..Self::mixtral_like() }
    }

    /// Tiny-model shape (for replaying against the real engine's configs).
    pub fn tiny(n_layers: u32, n_experts: u32, top_k: usize) -> Self {
        Self { n_layers, n_experts, top_k, ..Self::mixtral_like() }
    }
}

/// Generate `n_seqs` sequences of `n_tokens` each.
pub fn generate(cfg: &TraceGenConfig, n_seqs: usize, n_tokens: u32) -> TraceSet {
    let mut rng = Rng::new(cfg.seed);
    let e = cfg.n_experts as usize;
    let mut seqs = Vec::with_capacity(n_seqs);
    for _ in 0..n_seqs {
        // per-(seq, layer) preference logits from a Dirichlet draw
        let prefs: Vec<Vec<f64>> = (0..cfg.n_layers)
            .map(|_| {
                rng.dirichlet(cfg.pref_alpha, e)
                    .into_iter()
                    .map(|p| (p.max(1e-6)).ln())
                    .collect()
            })
            .collect();
        // shared token latent (drives cross-layer similarity)
        let mut u = vec![0.0f64; e];
        let mut events = Vec::with_capacity((n_tokens * cfg.n_layers) as usize);
        for t in 0..n_tokens {
            let r = cfg.temporal_rho;
            for ui in u.iter_mut() {
                *ui = r * *ui + (1.0 - r * r).sqrt() * rng.normal();
            }
            for l in 0..cfg.n_layers {
                let logits: Vec<f32> = (0..e)
                    .map(|i| {
                        (prefs[l as usize][i]
                            + cfg.latent_scale * (u[i] + cfg.layer_noise * rng.normal()))
                            as f32
                    })
                    .collect();
                events.push(GateEvent { token: t, layer: l, probs: softmax(&logits) });
            }
        }
        seqs.push(SeqTrace {
            n_layers: cfg.n_layers,
            n_experts: cfg.n_experts,
            n_tokens,
            events,
        });
    }
    TraceSet { seqs }
}

/// Build a trace from engine capture (decode steps only form a clean
/// token-major stream when capture started at token 0 of a sequence).
pub fn from_capture(routes: &[RoutingObs], n_layers: u32, n_experts: u32) -> SeqTrace {
    let mut events: Vec<GateEvent> = routes
        .iter()
        .map(|r| GateEvent {
            token: r.token as u32,
            layer: r.layer,
            probs: r.probs.clone(),
        })
        .collect();
    events.sort_by_key(|e| (e.token, e.layer));
    // renumber tokens densely (prefill rows may share layer sweeps)
    let mut n_tokens = 0u32;
    let mut last = u32::MAX;
    for ev in &mut events {
        if ev.token != last {
            last = ev.token;
            ev.token = n_tokens;
            n_tokens += 1;
        } else {
            ev.token = n_tokens - 1;
        }
    }
    SeqTrace { n_layers, n_experts, n_tokens, events }
}

// ---------------------------------------------------------------------------
// Fig 10 statistics
// ---------------------------------------------------------------------------

/// Probability that the current token's top-1 expert (per layer) is reused
/// among the next token's top-k (Fig 10a, "top1" series).
pub fn top1_reuse_prob(trace: &SeqTrace, k: usize) -> f64 {
    let mut hits = 0u64;
    let mut total = 0u64;
    for t in 0..trace.n_tokens.saturating_sub(1) {
        for l in 0..trace.n_layers {
            let cur = trace.event(t, l).top_k(1)[0].0;
            let next: Vec<usize> =
                trace.event(t + 1, l).top_k(k).iter().map(|x| x.0).collect();
            total += 1;
            if next.contains(&cur) {
                hits += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Probability that at least one of the current token's top-k experts is
/// reused in the next token's top-k (Fig 10a, "any" series).
pub fn any_reuse_prob(trace: &SeqTrace, k: usize) -> f64 {
    let mut hits = 0u64;
    let mut total = 0u64;
    for t in 0..trace.n_tokens.saturating_sub(1) {
        for l in 0..trace.n_layers {
            let cur: Vec<usize> = trace.event(t, l).top_k(k).iter().map(|x| x.0).collect();
            let next: Vec<usize> =
                trace.event(t + 1, l).top_k(k).iter().map(|x| x.0).collect();
            total += 1;
            if cur.iter().any(|c| next.contains(c)) {
                hits += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Per-(layer, expert) selection frequency of one sequence (Fig 10b rows).
pub fn selection_frequency(trace: &SeqTrace, k: usize) -> Vec<Vec<f64>> {
    let e = trace.n_experts as usize;
    let mut freq = vec![vec![0.0; e]; trace.n_layers as usize];
    for t in 0..trace.n_tokens {
        for l in 0..trace.n_layers {
            for (i, _) in trace.event(t, l).top_k(k) {
                freq[l as usize][i] += 1.0;
            }
        }
    }
    for row in &mut freq {
        let s: f64 = row.iter().sum();
        if s > 0.0 {
            for v in row.iter_mut() {
                *v /= s;
            }
        }
    }
    freq
}

// ---------------------------------------------------------------------------
// (de)serialization
// ---------------------------------------------------------------------------

pub fn trace_to_json(t: &SeqTrace) -> Json {
    obj(vec![
        ("n_layers", num(t.n_layers as f64)),
        ("n_experts", num(t.n_experts as f64)),
        ("n_tokens", num(t.n_tokens as f64)),
        (
            "events",
            arr(t.events
                .iter()
                .map(|e| {
                    arr(vec![
                        num(e.token as f64),
                        num(e.layer as f64),
                        arr(e.probs.iter().map(|p| num(*p as f64)).collect()),
                    ])
                })
                .collect()),
        ),
    ])
}

pub fn trace_from_json(j: &Json) -> Result<SeqTrace, String> {
    let g = |k: &str| j.get(k).and_then(Json::as_usize).ok_or(format!("missing {k}"));
    let events = j
        .get("events")
        .and_then(Json::as_arr)
        .ok_or("missing events")?
        .iter()
        .map(|e| -> Result<GateEvent, String> {
            Ok(GateEvent {
                token: e.idx(0).and_then(Json::as_usize).ok_or("bad token")? as u32,
                layer: e.idx(1).and_then(Json::as_usize).ok_or("bad layer")? as u32,
                probs: e
                    .idx(2)
                    .and_then(Json::as_arr)
                    .ok_or("bad probs")?
                    .iter()
                    .filter_map(Json::as_f64)
                    .map(|x| x as f32)
                    .collect(),
            })
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(SeqTrace {
        n_layers: g("n_layers")? as u32,
        n_experts: g("n_experts")? as u32,
        n_tokens: g("n_tokens")? as u32,
        events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TraceGenConfig {
        TraceGenConfig { n_layers: 4, n_experts: 8, ..TraceGenConfig::mixtral_like() }
    }

    #[test]
    fn generate_shapes() {
        let ts = generate(&small(), 2, 10);
        assert_eq!(ts.seqs.len(), 2);
        let t = &ts.seqs[0];
        assert_eq!(t.events.len(), 40);
        let e = t.event(3, 2);
        assert_eq!((e.token, e.layer), (3, 2));
        let s: f32 = e.probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn temporal_locality_exceeds_theory() {
        // Fig 10a: top-1 reuse must beat the uniform-theory 2/8 = 0.25
        let ts = generate(&small(), 3, 64);
        let p: f64 =
            ts.seqs.iter().map(|s| top1_reuse_prob(s, 2)).sum::<f64>() / ts.seqs.len() as f64;
        assert!(p > 0.30, "top1 reuse {p} not above theoretical 0.25");
        let pa: f64 =
            ts.seqs.iter().map(|s| any_reuse_prob(s, 2)).sum::<f64>() / ts.seqs.len() as f64;
        assert!(pa > p, "any-reuse must exceed top1 reuse");
    }

    #[test]
    fn sequences_have_distinct_preferences() {
        // Fig 10b: different sequences prefer different experts
        let ts = generate(&small(), 2, 64);
        let f0 = selection_frequency(&ts.seqs[0], 2);
        let f1 = selection_frequency(&ts.seqs[1], 2);
        let mut diff = 0.0;
        for l in 0..4 {
            for e in 0..8 {
                diff += (f0[l][e] - f1[l][e]).abs();
            }
        }
        assert!(diff > 0.3, "sequence preference distributions too similar: {diff}");
    }

    #[test]
    fn json_roundtrip() {
        let ts = generate(&small(), 1, 3);
        let j = trace_to_json(&ts.seqs[0]);
        let t2 = trace_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(t2.n_tokens, 3);
        assert_eq!(t2.events.len(), ts.seqs[0].events.len());
        assert!((t2.event(1, 1).probs[0] - ts.seqs[0].event(1, 1).probs[0]).abs() < 1e-6);
    }
}
