//! Discrete-event simulator of MoE expert-offloading serving at **paper
//! scale**: Mixtral-8x7B / Phi-MoE byte sizes over RTX-4090 (PCIe 4.0) and
//! Jetson-Orin (SSD-bound) links. The real path (engine/) proves the
//! system end-to-end on the tiny models; this simulator regenerates the
//! paper's evaluation figures in the paper's own regime, where an expert
//! transfer costs tens of milliseconds and loading dominates (Fig 3a).
//!
//! The model has two serialized resources — the accelerator ("GPU") and
//! the expert-loading link — and replays gating traces through the same
//! `CacheManager`/`scorer` logic as the real engine. Transfers are
//! non-preemptible (cudaMemcpy semantics): an on-demand miss arriving
//! behind an in-flight prefetch waits it out, which is exactly the
//! misprediction penalty of Fig 9.

pub mod des;
pub mod params;

pub use des::{simulate_decode, simulate_prefill, DecodeResult, PrefillResult, SimSystem};
pub use params::{SimHardware, SimModel};
