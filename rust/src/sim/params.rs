//! Paper-scale parameters. Sources: the paper's own measurements (§2.2:
//! "loading a layer of Mixtral-8x7B from CPU memory via PCIe 4.0 takes
//! ~80 ms, computing the same layer on an RTX 4090 ~3 ms"; §5.1 hardware)
//! and the model cards (Table 1).

use crate::Precision;

/// Model at paper scale (Table 1).
#[derive(Debug, Clone)]
pub struct SimModel {
    pub name: String,
    pub n_layers: u32,
    pub n_experts: u32,
    pub top_k: usize,
    /// parameters of one expert
    pub expert_params: f64,
}

impl SimModel {
    /// Mixtral-8x7B: 45B total, 96% experts over 32 layers x 8 experts
    /// -> ~169M params/expert.
    pub fn mixtral_8x7b() -> Self {
        Self {
            name: "Mixtral-8x7B".into(),
            n_layers: 32,
            n_experts: 8,
            top_k: 2,
            expert_params: 45e9 * 0.96 / (32.0 * 8.0),
        }
    }

    /// Phi-MoE: 42B total, 96% experts over 32 layers x 16 experts
    /// -> ~79M params/expert (Table 1: smaller experts, twice as many).
    pub fn phi_moe() -> Self {
        Self {
            name: "Phi-MoE".into(),
            n_layers: 32,
            n_experts: 16,
            top_k: 2,
            expert_params: 42e9 * 0.96 / (32.0 * 16.0),
        }
    }

    /// On-wire bytes of one expert at a precision class. The sim maps the
    /// paper's fp16/int8/int4/int2 ladder directly (bits/8 per param).
    pub fn expert_bytes(&self, p: Precision) -> f64 {
        // paper precision ladder: F32 slot = fp16 (2 B), Q8 slot = int4 in
        // the fp16 group; when the int8 group is simulated the caller maps
        // hi=Q8(int8: 1 B), lo=Q2(int2: 0.25 B).
        let bytes_per_param = match p {
            Precision::F32 => 2.0, // fp16 role
            Precision::Q8 => 0.5,  // int4 role (fp16 group) / int8 = 1.0 in int8 group
            Precision::Q4 => 0.5,
            Precision::Q2 => 0.25,
        };
        self.expert_params * bytes_per_param
    }

    /// Bytes with an explicit bits-per-param (the int8 group uses 8/2).
    pub fn expert_bytes_bits(&self, bits: f64) -> f64 {
        self.expert_params * bits / 8.0
    }
}

/// Hardware profile at paper scale (§5.1).
#[derive(Debug, Clone)]
pub struct SimHardware {
    pub name: String,
    /// expert-loading link bandwidth (B/s): PCIe 4.0 ~26 GB/s effective on
    /// the 4090; ~2.5 GB/s effective SSD/unified-memory path on Orin.
    pub load_bw: f64,
    pub load_latency: f64,
    /// attention + gating compute per layer per token (s)
    pub attn_time: f64,
    /// one expert FFN per token (s)
    pub expert_time: f64,
    /// one expert FFN on the CPU (cooperative mode / Fiddler)
    pub cpu_expert_time: f64,
    /// GPU memory available for the expert cache (bytes)
    pub cache_bytes: f64,
    /// prefill compute for a whole layer with S tokens (s per token, batched)
    pub prefill_token_time: f64,
}

impl SimHardware {
    /// RTX 4090, float16 group: 24 GB GPU memory; paper: compute ~3 ms per
    /// layer (2 experts + attn) per token, loading a full layer ~80 ms.
    pub fn rtx4090() -> Self {
        Self {
            name: "RTX4090".into(),
            load_bw: 26e9,
            load_latency: 50e-6,
            attn_time: 0.9e-3,
            expert_time: 1.05e-3,
            cpu_expert_time: 5e-3, // §5.4: HOBBIT's CPU path ~5 ms/expert
            // 24 GB minus non-expert weights (~3.5 GB fp16) and activations
            cache_bytes: 18e9,
            prefill_token_time: 0.12e-3,
        }
    }

    /// Jetson AGX Orin, int8 group: 32 GB unified; SSD-bound loading
    /// (~2.5 GB/s effective), ~5x slower compute.
    pub fn orin() -> Self {
        Self {
            name: "JetsonOrin".into(),
            load_bw: 2.5e9,
            load_latency: 200e-6,
            attn_time: 4.5e-3,
            expert_time: 5.0e-3,
            cpu_expert_time: 12e-3,
            // 32 GB unified minus CPU side, non-expert weights, activations
            cache_bytes: 14e9,
            prefill_token_time: 0.6e-3,
        }
    }

    /// How many hi/lo experts fit the cache given a split and byte sizes.
    pub fn cache_capacity(&self, hi_bytes: f64, lo_bytes: f64, lo_frac: f64) -> (usize, usize) {
        let hi = (self.cache_bytes * (1.0 - lo_frac) / hi_bytes).floor() as usize;
        let lo = (self.cache_bytes * lo_frac / lo_bytes).floor() as usize;
        (hi.max(1), lo.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixtral_expert_size_matches_paper() {
        let m = SimModel::mixtral_8x7b();
        // paper §2.2: a full fp16 layer (8 experts) loads in ~80 ms at 32 GB/s
        // -> layer ~2.6 GB -> expert ~330 MB
        let fp16 = m.expert_bytes(Precision::F32);
        assert!((2.5e8..4.2e8).contains(&fp16), "expert fp16 bytes {fp16}");
        let layer_load_s = 8.0 * fp16 / 32e9;
        assert!((0.06..0.11).contains(&layer_load_s), "layer load {layer_load_s}");
    }

    #[test]
    fn phi_experts_smaller_but_more() {
        let m = SimModel::phi_moe();
        let x = SimModel::mixtral_8x7b();
        assert!(m.expert_params < x.expert_params);
        assert_eq!(m.n_experts, 16);
    }

    #[test]
    fn loading_dominates_on_both_platforms() {
        // Fig 3a: per-layer on-demand load time >> compute time
        for hw in [SimHardware::rtx4090(), SimHardware::orin()] {
            let m = SimModel::mixtral_8x7b();
            let bytes = if hw.name == "JetsonOrin" {
                m.expert_bytes_bits(8.0)
            } else {
                m.expert_bytes(Precision::F32)
            };
            let load = 2.0 * (bytes / hw.load_bw + hw.load_latency);
            let compute = hw.attn_time + 2.0 * hw.expert_time;
            let frac = load / (load + compute);
            assert!(frac > 0.8, "{}: load fraction {frac}", hw.name);
        }
    }

    #[test]
    fn cache_capacity_math() {
        let hw = SimHardware::rtx4090();
        let m = SimModel::mixtral_8x7b();
        let (hi, lo) = hw.cache_capacity(
            m.expert_bytes(Precision::F32),
            m.expert_bytes(Precision::Q8),
            0.2,
        );
        assert!(hi >= 40, "hi capacity {hi}"); // ~43 of 256 experts resident
        assert!(lo >= hi, "lo pool should fit more (smaller) experts");
    }
}
